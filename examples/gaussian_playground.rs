//! Quick interactive check: quantize i.i.d. Gaussian sequences with each code and
//! report MSE (the Table 1 setting, reduced sample count).
use qtip::codes::build_code;
use qtip::trellis::{quantize_tail_biting, Trellis, Viterbi, ViterbiWorkspace};
use qtip::util::rng::Rng;
use qtip::util::stats::mse;

fn main() {
    let t_len = 256;
    let n_seqs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    for (name, l, k, v) in [
        ("1mad", 16u32, 2u32, 1u32),
        ("3inst", 16, 2, 1),
        ("lut", 16, 2, 1),
        ("hyb", 16, 2, 2),
    ] {
        let code = build_code(name, l, v, 0xC0DE);
        let values = code.materialize();
        let trellis = Trellis::new(l, k, v);
        let vit = Viterbi::new(trellis, &values);
        let mut rng = Rng::new(1);
        let mut ws = ViterbiWorkspace::new();
        let mut total = 0.0;
        let start = std::time::Instant::now();
        for _ in 0..n_seqs {
            let seq = rng.gauss_vec(t_len);
            let sol = quantize_tail_biting(&vit, &seq, &mut ws);
            let dec = vit.decode(&sol.states);
            total += mse(&dec, &seq);
        }
        println!(
            "{name:>6} L={l} k={k} V={v}: MSE {:.4}  ({:.2} s, {} seqs)",
            total / n_seqs as f64,
            start.elapsed().as_secs_f64(),
            n_seqs
        );
    }
}
