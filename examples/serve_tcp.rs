//! Network serving demo: quantize the trained nano model, expose it over the
//! newline-JSON TCP protocol, and drive it with concurrent in-process clients —
//! concurrent so the continuous batcher fuses their decode rounds and each
//! packed weight tile is decoded once per round for the whole batch.
//!
//!     cargo run --release --example serve_tcp

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;

use qtip::coordinator::{quantize_model_qtip, ServerConfig, ServerHandle, TcpFrontend};
use qtip::hessian::collect_hessians;
use qtip::model::{split_corpus, Transformer, WeightStore};
use qtip::quant::QtipConfig;
use qtip::util::threadpool::ExecPool;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let ws = WeightStore::load(&dir, "nano")
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    let mut model = Transformer::from_store(&ws);

    // Quick 2-bit quantization.
    let holdout = std::fs::read(dir.join("corpus_holdout.bin"))?;
    let (calib, _) = split_corpus(&holdout, 0.5);
    let seqs: Vec<Vec<u16>> = calib
        .chunks(128)
        .take(12)
        .map(|c| c.iter().map(|&b| b as u16).collect())
        .collect();
    let hs = collect_hessians(&model, &seqs);
    let cfg = QtipConfig { l: 12, k: 2, v: 1, tx: 16, ty: 16, code: "3inst".into(), seed: 7 };
    let report = quantize_model_qtip(&mut model, &hs, &cfg, &ExecPool::new(0), |_| {});
    model.ensure_caches();
    println!("model quantized ({:.2}x); starting TCP front-end...", report.compression_ratio());

    let server = Arc::new(ServerHandle::spawn(Arc::new(model), ServerConfig::default()));
    let fe = TcpFrontend::spawn(server, "127.0.0.1:0")?;
    println!("listening on {}", fe.addr);

    // Drive it like concurrent external clients: submitting in parallel lets
    // the batcher admit all three into the same fused decode rounds.
    let addr = fe.addr;
    let clients: Vec<_> = ["fn quantize(", "let trellis = ", "## QTIP"]
        .iter()
        .enumerate()
        .map(|(i, prompt)| {
            let prompt = prompt.to_string();
            std::thread::spawn(move || -> anyhow::Result<String> {
                let mut s = TcpStream::connect(addr)?;
                writeln!(
                    s,
                    r#"{{"prompt": "{prompt}", "max_new_tokens": 40, "temperature": 0.7, "seed": {i}}}"#
                )?;
                let mut line = String::new();
                BufReader::new(s).read_line(&mut line)?;
                Ok(line.trim().to_string())
            })
        })
        .collect();
    for (i, c) in clients.into_iter().enumerate() {
        let line = c.join().expect("client thread panicked")?;
        println!("client {i} <- {line}");
    }
    fe.shutdown();
    println!("done.");
    Ok(())
}
