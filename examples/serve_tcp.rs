//! Network serving demo: quantize the trained nano model, expose it over the
//! newline-JSON TCP protocol, and drive it with in-process clients.
//!
//!     cargo run --release --example serve_tcp

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;

use qtip::coordinator::{quantize_model_qtip, ServerConfig, ServerHandle, TcpFrontend};
use qtip::hessian::collect_hessians;
use qtip::model::{split_corpus, Transformer, WeightStore};
use qtip::quant::QtipConfig;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let ws = WeightStore::load(&dir, "nano")
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    let mut model = Transformer::from_store(&ws);

    // Quick 2-bit quantization.
    let holdout = std::fs::read(dir.join("corpus_holdout.bin"))?;
    let (calib, _) = split_corpus(&holdout, 0.5);
    let seqs: Vec<Vec<u16>> = calib
        .chunks(128)
        .take(12)
        .map(|c| c.iter().map(|&b| b as u16).collect())
        .collect();
    let hs = collect_hessians(&model, &seqs);
    let cfg = QtipConfig { l: 12, k: 2, v: 1, tx: 16, ty: 16, code: "3inst".into(), seed: 7 };
    let report = quantize_model_qtip(&mut model, &hs, &cfg, 1, |_| {});
    model.ensure_caches();
    println!("model quantized ({:.2}x); starting TCP front-end...", report.compression_ratio());

    let server = Arc::new(ServerHandle::spawn(Arc::new(model), ServerConfig::default()));
    let fe = TcpFrontend::spawn(server, "127.0.0.1:0")?;
    println!("listening on {}", fe.addr);

    // Drive it like an external client would.
    for (i, prompt) in ["fn quantize(", "let trellis = ", "## QTIP"].iter().enumerate() {
        let mut s = TcpStream::connect(fe.addr)?;
        writeln!(
            s,
            r#"{{"prompt": "{prompt}", "max_new_tokens": 40, "temperature": 0.7, "seed": {i}}}"#
        )?;
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line)?;
        println!("client {i} <- {}", line.trim());
    }
    fe.shutdown();
    println!("done.");
    Ok(())
}
