//! **The end-to-end driver** (DESIGN.md "End-to-end validation"): exercises all
//! three layers on a real small workload and reports the paper's headline
//! metrics. Recorded in EXPERIMENTS.md §E2E.
//!
//!     cargo run --release --example e2e_quantize_eval_serve
//!
//! Pipeline: load the JAX-trained nano LM (trained at `make artifacts` on the
//! repository's own source corpus, loss curve in the manifest) → calibrate
//! Hessians in Rust → QTIP-quantize every decoder linear (RHT + BlockLDLQ +
//! tail-biting 3INST trellis) → evaluate held-out perplexity + zeroshot proxies
//! fp32 vs 2-bit → verify the native fused decoder against the AOT Pallas/XLA
//! artifact through PJRT → serve batched generation requests and report
//! latency/throughput.

use std::path::Path;
use std::sync::Arc;

use qtip::coordinator::{quantize_model_qtip, GenRequest, ServerConfig, ServerHandle};
use qtip::eval::{perplexity, zeroshot_suite};
use qtip::hessian::collect_hessians;
use qtip::model::{split_corpus, Transformer, WeightStore};
use qtip::quant::QtipConfig;
use qtip::runtime::{PjrtRuntime, Registry};
use qtip::util::rng::Rng;
use qtip::util::threadpool::ExecPool;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    println!("== QTIP end-to-end driver ==\n");

    // --- Layer 2 artifact: the trained model ---
    let ws = WeightStore::load(&dir, "nano")
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    if let Some(meta) = ws.meta.get("loss_curve").and_then(|c| c.as_arr()) {
        let first = &meta[0];
        let last = &meta[meta.len() - 1];
        println!(
            "training loss curve (JAX, build time): step {} loss {:.3} -> step {} loss {:.3}",
            first.as_arr().unwrap()[0],
            first.as_arr().unwrap()[1].as_f64().unwrap(),
            last.as_arr().unwrap()[0],
            last.as_arr().unwrap()[1].as_f64().unwrap()
        );
    }
    let model = Transformer::from_store(&ws);
    println!(
        "model: {} ({} params, {} layers, d={})\n",
        ws.config.name,
        ws.config.total_params(),
        ws.config.n_layers,
        ws.config.d_model
    );

    // --- Calibration + evaluation data (held-out source corpus) ---
    let holdout = std::fs::read(dir.join("corpus_holdout.bin"))?;
    let (calib_bytes, eval_bytes) = split_corpus(&holdout, 0.5);
    let calib: Vec<Vec<u16>> = calib_bytes
        .chunks(128)
        .take(24)
        .map(|c| c.iter().map(|&b| b as u16).collect())
        .collect();

    // --- fp32 baseline ---
    let eval_tokens = 2048;
    let base = perplexity(&model, eval_bytes, eval_tokens);
    let base_zs = zeroshot_suite(&model, eval_bytes, 24, 7);
    println!(
        "fp32  : ppl {:.3} | zeroshot next-byte {:.3} copy {:.3} bracket {:.3}",
        base.ppl, base_zs.next_byte_acc, base_zs.copy_acc, base_zs.bracket_acc
    );

    // --- Quantize (L3 pipeline) ---
    let cfg = QtipConfig { l: 12, k: 2, v: 1, tx: 16, ty: 16, code: "3inst".into(), seed: 7 };
    let hessians = collect_hessians(&model, &calib);
    let mut qmodel = Transformer::from_store(&ws);
    let t = std::time::Instant::now();
    let report = quantize_model_qtip(&mut qmodel, &hessians, &cfg, &ExecPool::new(0), |l| {
        eprintln!("  quantized {} ({}x{}) proxy {:.5}", l.name, l.rows, l.cols, l.metrics.relative_proxy);
    });
    println!(
        "\nquantized {} layers in {:.1}s: {:.2}x compression, mean rel-proxy {:.5}",
        report.layers.len(),
        t.elapsed().as_secs_f64(),
        report.compression_ratio(),
        report.mean_relative_proxy()
    );

    // --- Quality after quantization ---
    qmodel.ensure_caches();
    let qppl = perplexity(&qmodel, eval_bytes, eval_tokens);
    let qzs = zeroshot_suite(&qmodel, eval_bytes, 24, 7);
    println!(
        "2-bit : ppl {:.3} | zeroshot next-byte {:.3} copy {:.3} bracket {:.3}",
        qppl.ppl, qzs.next_byte_acc, qzs.copy_acc, qzs.bracket_acc
    );

    // --- Cross-layer parity: native fused decode vs AOT Pallas artifact ---
    let reg = Registry::open(&dir)?;
    let rt = PjrtRuntime::cpu()?;
    // nano's attention matrices are 128x128; find the matching L=16 artifact and
    // re-quantize one layer at L=16 for the check.
    if let Some(info) = reg.find_decode_matvec(128, 128, "3inst", 2) {
        let exe = reg.load_decode_matvec(&rt, info)?;
        let w0 = ws.get("l0.q");
        let h0 = &hessians.by_layer["l0.q"];
        let cfg16 = QtipConfig { l: 16, ..cfg.clone() };
        let qm = qtip::quant::quantize_matrix_qtip(w0, h0, &cfg16).qm;
        let mut rng = Rng::new(1);
        let x = rng.gauss_vec(128);
        let y_native = qm.matvec(&x);
        let y_pjrt = exe.matvec(&qm, &x)?;
        let maxdiff = y_native
            .iter()
            .zip(&y_pjrt)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max);
        println!("\nPJRT parity (l0.q @ L=16): native vs Pallas-AOT max diff {maxdiff:.2e}");
        assert!(maxdiff < 1e-3, "three-layer parity violated");
    }

    // --- Serve batched requests over the quantized model ---
    println!("\nserving 6 batched generation requests (quantized decode path)...");
    let server = ServerHandle::spawn(
        Arc::new(qmodel),
        ServerConfig { max_batch: 3, kv_budget_bytes: 64 << 20, ..Default::default() },
    );
    let prompts = ["fn main() {", "pub struct ", "import numpy", "## Usage", "let mut x = ", "def train("];
    let rxs: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            server.submit(GenRequest {
                id: i as u64,
                prompt: p.to_string(),
                max_new_tokens: 48,
                temperature: 0.7,
                top_k: 30,
                seed: i as u64 + 1,
            })
        })
        .collect();
    for rx in rxs {
        let r = rx.recv()?;
        let preview: String = r
            .text
            .chars()
            .map(|c| if c == '\n' { '¶' } else { c })
            .take(46)
            .collect();
        println!(
            "  [req {}] ttft {:>6.1} ms | {:>6.1} tok/s | {preview:?}",
            r.id,
            r.ttft * 1e3,
            r.decode_tok_per_sec
        );
    }
    let stats = server.shutdown();
    println!(
        "\nserved {} requests / {} tokens; aggregate decode throughput {:.1} tok/s (peak batch {})",
        stats.completed,
        stats.total_generated_tokens,
        stats.throughput_tok_per_sec(),
        stats.peak_batch
    );
    println!("\n== e2e driver complete: all three layers verified on a real workload ==");
    Ok(())
}
