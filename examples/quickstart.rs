//! Quickstart: quantize a single weight matrix with QTIP and inspect the result.
//!
//!     cargo run --release --example quickstart
//!
//! Walks the full per-matrix pipeline on synthetic data: RHT incoherence
//! processing → BlockLDLQ with tail-biting trellis coding (3INST computed code)
//! → packed 2-bit artifact → fused decode-matvec.

use qtip::quant::{quantize_matrix_qtip, QtipConfig};
use qtip::util::matrix::Matrix;
use qtip::util::rng::Rng;
use qtip::util::stats::mse;

fn main() {
    // A synthetic "layer": correlated weights + a realistic activation Hessian.
    let (m, n) = (128usize, 256usize);
    let mut rng = Rng::new(42);
    let w = Matrix::gaussian(m, n, 0.02, &mut rng);
    let acts = Matrix::gaussian(n, 2 * n, 1.0, &mut rng);
    let mut h = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for t in 0..2 * n {
                s += acts.at(i, t) * acts.at(j, t);
            }
            *h.at_mut(i, j) = s / (2 * n) as f32;
        }
    }

    // The paper's configuration, scaled down to L=12 for a fast demo
    // (L=16 is the headline setting; try it with `--release` patience).
    let cfg = QtipConfig {
        l: 12,
        k: 2,
        v: 1,
        tx: 16,
        ty: 16,
        code: "3inst".into(),
        seed: 7,
    };
    println!("quantizing {m}x{n} to {} bits/weight (code={}, L={})...", cfg.k, cfg.code, cfg.l);
    let res = quantize_matrix_qtip(&w, &h, &cfg);

    println!("  relative proxy loss : {:.5}", res.metrics.relative_proxy);
    println!("  normalized MSE      : {:.5}", res.metrics.mse);
    println!(
        "  artifact size       : {} bytes (fp32 was {} — {:.1}x smaller)",
        res.qm.size_bytes(),
        m * n * 4,
        (m * n * 4) as f64 / res.qm.size_bytes() as f64
    );

    // The decode path: fused trellis-decode matvec vs explicit reconstruction.
    let x = rng.gauss_vec(n);
    let y_fused = res.qm.matvec(&x);
    let y_rec = res.qm.reconstruct_w().matvec(&x);
    println!(
        "  fused vs reconstructed matvec max diff: {:.2e}",
        y_fused
            .iter()
            .zip(&y_rec)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0, f64::max)
    );
    let y_exact = w.matvec(&x);
    println!(
        "  end-to-end output MSE vs fp32: {:.3e} (output var {:.3e})",
        mse(&y_fused, &y_exact),
        qtip::util::stats::variance(&y_exact)
    );
}
