"""L2 model/trainer sanity: architecture invariants, loss descent on a toy
pattern, and export-format integrity."""

import json

import jax
import numpy as np
import pytest

from compile import train


CFG = dict(vocab=256, d_model=32, n_layers=2, n_heads=2, d_ff=64, max_seq=64)


def toy_params(seed=0):
    return train.init_params(CFG, jax.random.PRNGKey(seed))


def test_forward_shape_and_finite():
    p = toy_params()
    toks = np.arange(2 * 16).reshape(2, 16).astype(np.int32) % 256
    logits = train.forward(p, toks, CFG)
    assert logits.shape == (2, 16, 256)
    assert np.isfinite(np.asarray(logits)).all()


def test_initial_loss_near_uniform():
    p = toy_params()
    toks = np.random.default_rng(0).integers(0, 256, (4, 33)).astype(np.int32)
    loss = float(train.loss_fn(p, toks, CFG))
    assert abs(loss - np.log(256)) < 1.5


def test_causality():
    p = toy_params(1)
    a = np.array([[1, 2, 3, 4, 5]], np.int32)
    b = np.array([[1, 2, 3, 4, 250]], np.int32)
    la = np.asarray(train.forward(p, a, CFG))
    lb = np.asarray(train.forward(p, b, CFG))
    np.testing.assert_allclose(la[0, :4], lb[0, :4], atol=1e-5)


def test_loss_decreases_on_repetitive_data():
    # A trivially learnable stream: repeated byte pattern.
    cfg_key = "micro"
    cfg = train.CONFIGS[cfg_key]
    params = train.init_params(cfg, jax.random.PRNGKey(2))
    m = jax.tree.map(np.zeros_like, params)
    v = jax.tree.map(np.zeros_like, params)
    pattern = (b"qtip! " * 2000)
    data = np.frombuffer(pattern, np.uint8)
    gen = train.batches(data, 4, cfg["max_seq"], np.random.default_rng(0))
    losses = []
    for step in range(8):
        toks = next(gen)
        params, m, v, loss = train.train_step(params, m, v, toks, step, 3e-3, cfg_key)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses


def test_rope_adjacent_pair_convention():
    # rope() must rotate pairs (2i, 2i+1) — position 0 is identity.
    x = np.random.default_rng(3).standard_normal((1, 2, 1, 8)).astype(np.float32)
    import jax.numpy as jnp

    out = np.asarray(train.rope(jnp.asarray(x), jnp.array([0, 1])))
    np.testing.assert_allclose(out[0, 0], x[0, 0], atol=1e-6)
    # Position 1, pair 0 rotates by angle 1.
    a, b = x[0, 1, 0, 0], x[0, 1, 0, 1]
    c, s = np.cos(1.0), np.sin(1.0)
    np.testing.assert_allclose(out[0, 1, 0, 0], a * c - b * s, rtol=1e-5)
    np.testing.assert_allclose(out[0, 1, 0, 1], a * s + b * c, rtol=1e-5)


def test_export_roundtrip(tmp_path):
    p = toy_params(4)
    cfg = dict(CFG)
    train.export(p, cfg, "testmodel", tmp_path, meta=dict(steps=0))
    manifest = json.loads((tmp_path / "model_testmodel.json").read_text())
    blob = np.fromfile(tmp_path / "model_testmodel.bin", np.float32)
    total = sum(int(np.prod(t["shape"])) for t in manifest["tensors"])
    assert len(blob) == total
    # Offsets are contiguous and ordered.
    off = 0
    for t in manifest["tensors"]:
        assert t["offset"] == off
        off += int(np.prod(t["shape"]))
    # Spot-check one tensor's bytes.
    t0 = next(t for t in manifest["tensors"] if t["name"] == "l0.q")
    arr = blob[t0["offset"] : t0["offset"] + 32 * 32].reshape(32, 32)
    np.testing.assert_allclose(arr, np.asarray(p["l0.q"]), atol=0)


def test_tensor_names_match_rust_convention():
    names = train.tensor_names(CFG)
    assert names[0] == "tok_emb"
    assert names[-2:] == ["out_norm", "head"]
    assert "l0.attn_norm" in names and "l1.down" in names
    assert len(names) == 1 + 2 * 9 + 2
