"""Code-decoder correctness: jnp implementations vs the independent numpy
oracle, plus distributional and golden-vector pins."""

import json
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import codes, ref

ARTIFACTS = Path(__file__).resolve().parent.parent.parent / "artifacts"


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=0, max_value=2**16 - 1))
def test_onemad_matches_ref(state):
    got = float(np.asarray(codes.onemad_decode(np.array([state], np.uint32)))[0])
    want = float(ref.onemad_ref(state))
    assert got == pytest.approx(want, abs=1e-6)


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=0, max_value=2**16 - 1))
def test_threeinst_matches_ref(state):
    got = float(np.asarray(codes.threeinst_decode(np.array([state], np.uint32)))[0])
    want = float(ref.threeinst_ref(state))
    assert got == pytest.approx(want, abs=1e-6)


@settings(max_examples=100, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**16 - 1),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hyb_matches_ref(state, lut_seed):
    rng = np.random.default_rng(lut_seed)
    q = 9
    lut = rng.standard_normal((1 << q, 2)).astype(np.float32)
    got = np.asarray(codes.hyb_decode(np.array([state], np.uint32), lut, q))[0]
    want = ref.hyb_ref(state, lut, q)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_large_states_wrap():
    # u32 wrap-around must hold for the largest states (L up to 24).
    s = np.array([2**24 - 1, 2**20, 12345678], np.uint32)
    a = np.asarray(codes.onemad_decode(s))
    b = np.array([ref.onemad_ref(int(x)) for x in s])
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_moments_near_standard_gaussian():
    states = np.arange(2**16, dtype=np.uint32)
    for fn in (codes.onemad_decode, codes.threeinst_decode):
        vals = np.asarray(fn(states))
        assert abs(vals.mean()) < 0.02
        assert abs(vals.std() - 1.0) < 0.02


def test_neighbor_decorrelation():
    # Figure 3: overlapping windows must decode to near-uncorrelated values.
    states = np.arange(2**16, dtype=np.uint32)
    for fn in (codes.onemad_decode, codes.threeinst_decode):
        a = np.asarray(fn(states))
        b = np.asarray(fn(states >> np.uint32(2)))
        corr = abs(np.corrcoef(a, b)[0, 1])
        assert corr < 0.05, corr


@pytest.mark.skipif(not (ARTIFACTS / "golden_codes.json").exists(), reason="run make artifacts")
def test_golden_file_pins_both_sides():
    golden = json.loads((ARTIFACTS / "golden_codes.json").read_text())
    states = np.array(golden["states"], np.uint32)
    np.testing.assert_allclose(
        np.asarray(codes.onemad_decode(states)), np.array(golden["1mad"]), atol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(codes.threeinst_decode(states)), np.array(golden["3inst"]), atol=1e-6
    )
    # And the numpy oracle agrees.
    for i in [0, 1, 17, 1023]:
        assert ref.onemad_ref(i) == pytest.approx(golden["1mad"][i], abs=1e-6)
        assert ref.threeinst_ref(i) == pytest.approx(golden["3inst"][i], abs=1e-6)


@pytest.mark.skipif(not (ARTIFACTS / "hyb_lut_q9.json").exists(), reason="run make artifacts")
def test_hyb_lut_artifact_shape():
    j = json.loads((ARTIFACTS / "hyb_lut_q9.json").read_text())
    lut = np.array(j["lut"], np.float32).reshape(1 << j["q"], j["v"])
    assert lut.shape == (512, 2)
    # Folded half-space training: last component non-negative.
    assert (lut[:, -1] >= 0).all()
    # Covers the Gaussian bulk.
    assert lut[:, 0].min() < -2.0 and lut[:, 0].max() > 2.0
