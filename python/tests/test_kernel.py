"""The core L1 correctness signal: the Pallas fused decode-matvec kernel vs the
pure-numpy oracle, swept across shapes, bitrates, and codes (hypothesis)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import decode, ref


def run_case(rows, cols, l, k, v, code, seed, lut=None, q=None):
    rng = np.random.default_rng(seed)
    tiles = ref.random_packed_tiles(rng, rows // 16, cols // 16, l, k, v, 16, 16)
    x = rng.standard_normal(cols).astype(np.float32)
    scale = np.float32(rng.uniform(0.1, 2.0))
    fn, _ = decode.make_decode_matvec(rows, cols, l, k, v, code, lut=lut, q=q)
    y = np.asarray(fn(tiles.reshape(rows // 16, -1), x, scale))
    y_ref = ref.matvec_ref(tiles, l, k, v, 16, 16, code, x, scale, lut=lut, q=q)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)


@settings(max_examples=12, deadline=None)
@given(
    tiles_r=st.integers(1, 3),
    tiles_c=st.integers(1, 3),
    k=st.integers(1, 4),
    l=st.sampled_from([12, 14, 16]),
    code=st.sampled_from(["1mad", "3inst"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_vs_ref_scalar_codes(tiles_r, tiles_c, k, l, code, seed):
    if k >= l:
        return
    run_case(tiles_r * 16, tiles_c * 16, l, k, 1, code, seed)


@settings(max_examples=8, deadline=None)
@given(
    tiles_r=st.integers(1, 2),
    tiles_c=st.integers(1, 2),
    k=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_vs_ref_hyb_v2(tiles_r, tiles_c, k, seed):
    rng = np.random.default_rng(seed ^ 0x5EED)
    q = 9
    lut = rng.standard_normal((1 << q, 2)).astype(np.float32)
    run_case(tiles_r * 16, tiles_c * 16, 16, k, 2, "hyb", seed, lut=lut, q=q)


def test_kernel_paper_configuration():
    # The paper's headline config: L=16, k=2, V=1, 16x16 tiles, 3INST.
    run_case(128, 128, 16, 2, 1, "3inst", 7)


def test_scale_is_linear():
    rng = np.random.default_rng(3)
    rows = cols = 32
    tiles = ref.random_packed_tiles(rng, 2, 2, 16, 2, 1, 16, 16)
    x = rng.standard_normal(cols).astype(np.float32)
    fn, _ = decode.make_decode_matvec(rows, cols, 16, 2, 1, "3inst")
    packed = tiles.reshape(2, -1)
    y1 = np.asarray(fn(packed, x, np.float32(1.0)))
    y3 = np.asarray(fn(packed, x, np.float32(3.0)))
    np.testing.assert_allclose(3.0 * y1, y3, rtol=1e-5)


def test_no_materialized_weight_tensor_in_hlo():
    """§Perf/L2 claim: the decode fuses into the GEMV — the lowered module must
    not contain a full rows×cols f32 weight intermediate."""
    import jax
    from compile import model as model_mod

    rows = cols = 128
    fn, _ = model_mod.quantized_matvec_fn(rows, cols, 16, 2, 1, "3inst")
    args = model_mod.example_args_matvec(rows, cols, 16, 2, 1)
    hlo = jax.jit(fn).lower(*args).compiler_ir("hlo").as_hlo_text()
    assert f"f32[{rows},{cols}]" not in hlo, "full weight tensor materialized!"


def test_window_extraction_against_ref():
    rng = np.random.default_rng(11)
    raw = rng.integers(0, 1 << 32, size=16, dtype=np.uint64).astype(np.uint32)
    padded = np.concatenate([raw, np.zeros(2, np.uint32)])
    import jax.numpy as jnp

    w_idx, sh = decode._window_tables(64, 2, 16)
    states = np.asarray(
        decode._extract_states(jnp.asarray(padded), jnp.asarray(w_idx), jnp.asarray(sh), 16)
    )
    for t in range(64):
        assert states[t] == ref.decode_window(padded, t * 2, 16), t
