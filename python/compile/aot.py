"""AOT lowering driver: jit → StableHLO → XLA HLO **text** artifacts.

HLO text (not `.serialize()`) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Also emits the cross-language contracts:
  * golden_codes.json — decode values for states 0..1023 per compute code
    (pinned by both pytest and `cargo test`),
  * hyb_lut_q9.json / hyb_lut_q6.json — the shared HYB LUTs (numpy k-means on
    an empirical Gaussian, seeded),
  * aot_manifest.json — index of every artifact with shapes/geometry.

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import codes


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: without it the shift/index
    # tables inside the kernel are elided as `constant({...})`, which XLA
    # 0.5.1's text parser silently re-materializes as ZEROS.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "elided constants survived — artifact would be corrupt"
    return text


def train_hyb_lut(q, v, seed, iters=30):
    """Seeded numpy k-means on (g, |g|) — the shared HYB LUT contract."""
    rng = np.random.default_rng(seed)
    k = 1 << q
    n = max(k * 64, 1 << 14)
    pts = rng.standard_normal((n, v)).astype(np.float32)
    pts[:, -1] = np.abs(pts[:, -1])
    # k-means++ light: random distinct init is fine at this n/k ratio.
    centroids = pts[rng.choice(n, size=k, replace=False)].copy()
    for _ in range(iters):
        d2 = ((pts[:, None, :] - centroids[None]) ** 2).sum(-1)  # (n, k)
        assign = d2.argmin(1)
        for c in range(k):
            m = assign == c
            if m.any():
                centroids[c] = pts[m].mean(0)
    return centroids


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    manifest = {"artifacts": [], "golden": "golden_codes.json"}

    # --- golden code vectors ---
    states = jnp.arange(1024, dtype=jnp.uint32)
    golden = {
        "states": list(range(1024)),
        "1mad": np.asarray(codes.onemad_decode(states)).astype(float).tolist(),
        "3inst": np.asarray(codes.threeinst_decode(states)).astype(float).tolist(),
    }
    (out / "golden_codes.json").write_text(json.dumps(golden))
    print("[aot] wrote golden_codes.json")

    # --- shared HYB LUTs ---
    for q, v in [(9, 2), (6, 1)]:
        lut = train_hyb_lut(q, v, seed=0xB0B + q)
        (out / f"hyb_lut_q{q}.json").write_text(
            json.dumps({"q": q, "v": v, "lut": lut.reshape(-1).astype(float).tolist()})
        )
        print(f"[aot] wrote hyb_lut_q{q}.json")

    # --- HLO artifacts: fused decode-matvec graphs ---
    jobs = [
        # (name, rows, cols, l, k, v, code)
        ("decode_matvec_3inst_128x128_k2", 128, 128, 16, 2, 1, "3inst"),
        ("decode_matvec_3inst_512x128_k2", 512, 128, 16, 2, 1, "3inst"),
        ("decode_matvec_3inst_128x512_k2", 128, 512, 16, 2, 1, "3inst"),
        ("decode_matvec_1mad_128x128_k2", 128, 128, 16, 2, 1, "1mad"),
        ("decode_matvec_3inst_128x128_k4", 128, 128, 16, 4, 1, "3inst"),
    ]
    for name, rows, cols, l, k, v, code in jobs:
        fn, meta = model_mod.quantized_matvec_fn(rows, cols, l, k, v, code)
        ex_args = model_mod.example_args_matvec(rows, cols, l, k, v)
        lowered = jax.jit(fn).lower(*ex_args)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        (out / path).write_text(text)
        manifest["artifacts"].append(
            dict(
                name=name,
                path=path,
                kind="decode_matvec",
                rows=rows,
                cols=cols,
                l=l,
                k=k,
                v=v,
                code=code,
                tx=16,
                ty=16,
                padded_len=meta["padded_len"],
            )
        )
        print(f"[aot] lowered {name} ({len(text)} chars)")

    # --- quantized MLP block (composition demo) ---
    d, dff, l, k = 128, 512, 16, 2
    mlp_fn, _ = model_mod.quantized_mlp_fn(d, dff, l, k, "3inst")
    pg = model_mod.example_args_matvec(dff, d, l, k, 1)[0]
    pd = model_mod.example_args_matvec(d, dff, l, k, 1)[0]
    xs = jax.ShapeDtypeStruct((d,), jnp.float32)
    ss = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(mlp_fn).lower(pg, pg, pd, xs, ss, ss, ss)
    (out / "quantized_mlp_3inst_128_k2.hlo.txt").write_text(to_hlo_text(lowered))
    manifest["artifacts"].append(
        dict(
            name="quantized_mlp_3inst_128_k2",
            path="quantized_mlp_3inst_128_k2.hlo.txt",
            kind="quantized_mlp",
            d_model=d,
            d_ff=dff,
            l=l,
            k=k,
            code="3inst",
        )
    )
    print("[aot] lowered quantized_mlp_3inst_128_k2")

    # --- dense baseline matvec ---
    dense = model_mod.f32_matvec_fn()
    lowered = jax.jit(dense).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128,), jnp.float32),
    )
    (out / "matvec_f32_128x128.hlo.txt").write_text(to_hlo_text(lowered))
    manifest["artifacts"].append(
        dict(
            name="matvec_f32_128x128",
            path="matvec_f32_128x128.hlo.txt",
            kind="dense_matvec",
            rows=128,
            cols=128,
        )
    )
    print("[aot] lowered matvec_f32_128x128")

    (out / "aot_manifest.json").write_text(json.dumps(manifest))
    print(f"[aot] manifest with {len(manifest['artifacts'])} artifacts")


if __name__ == "__main__":
    main()
