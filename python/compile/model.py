"""Layer-2 JAX compute graphs: the quantized linear primitives (wrapping the L1
Pallas kernel) and a composed quantized transformer-MLP block, all AOT-lowered
by aot.py to HLO text for the Rust runtime.

Python builds these graphs exactly once at `make artifacts`; the Rust
coordinator executes the compiled artifacts via PJRT on the request path.
"""

import jax
import jax.numpy as jnp

from .kernels import decode


def quantized_matvec_fn(rows, cols, l, k, v, code, lut=None, q=None):
    """The fused decode+GEMV graph: (packed, x, scale) -> y."""
    fn, meta = decode.make_decode_matvec(rows, cols, l, k, v, code, lut=lut, q=q)
    return fn, meta


def quantized_mlp_fn(d_model, d_ff, l, k, code):
    """A SwiGLU MLP with all three projections quantized — demonstrates that L1
    kernels compose into larger L2 graphs under one jit/HLO module:

        y = down( silu(gate(x)) * up(x) )

    Signature: (packed_gate, packed_up, packed_down, x,
                scale_gate, scale_up, scale_down) -> y
    """
    gate_fn, gmeta = decode.make_decode_matvec(d_ff, d_model, l, k, 1, code)
    up_fn, _ = decode.make_decode_matvec(d_ff, d_model, l, k, 1, code)
    down_fn, dmeta = decode.make_decode_matvec(d_model, d_ff, l, k, 1, code)

    def fn(pg, pu, pd, x, sg, su, sd):
        g = gate_fn(pg, x, sg)
        u = up_fn(pu, x, su)
        h = jax.nn.silu(g) * u
        return down_fn(pd, h, sd)

    return fn, dict(gate=gmeta, down=dmeta)


def f32_matvec_fn():
    """Dense baseline graph for the throughput comparison artifacts."""

    def fn(w, x):
        return w @ x

    return fn


def example_args_matvec(rows, cols, l, k, v, tx=16, ty=16):
    """ShapeDtypeStructs for lowering the quantized matvec."""
    t = tx * ty
    steps = t // v
    kv = k * v
    total_bits = steps * kv
    padded_len = (total_bits + (l - kv)) // 32 + 2
    tiles_r, tiles_c = rows // tx, cols // ty
    return (
        jax.ShapeDtypeStruct((tiles_r, tiles_c * padded_len), jnp.uint32),
        jax.ShapeDtypeStruct((cols,), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
