"""Offline corpus loading — mirrors rust/src/model/tokenizer.rs exactly
(same extensions, same skip rules, same deterministic traversal, same
train/holdout split) so Python training and Rust evaluation agree on the data.
"""

import os
from pathlib import Path

EXTS = {".rs", ".py", ".md", ".toml", ".txt"}
SKIP_DIRS = {"target", ".git", "artifacts"}


def load_corpus(roots, max_bytes):
    out = bytearray()
    stack = [Path(r) for r in roots]
    while stack:
        d = stack.pop()
        try:
            entries = sorted(p for p in d.iterdir())
        except OSError:
            continue
        for p in entries:
            if len(out) >= max_bytes:
                return bytes(out[:max_bytes])
            if p.is_dir():
                if p.name not in SKIP_DIRS:
                    stack.append(p)
            elif p.suffix in EXTS:
                try:
                    out += p.read_bytes()
                    out += b"\n"
                except OSError:
                    pass
    return bytes(out[:max_bytes])


def split_corpus(corpus, holdout_frac=0.1):
    cut = int(len(corpus) * (1.0 - holdout_frac))
    return corpus[:cut], corpus[cut:]


def default_roots():
    here = Path(__file__).resolve().parent.parent.parent  # repo root
    roots = [here]
    if os.path.isdir("/opt/xla-example/src"):
        roots.append(Path("/opt/xla-example/src"))
    return roots
