"""Layer-2 build-time trainer: a Llama-style byte-level GPT in pure JAX
(hand-rolled Adam — no optax offline), architecture-identical to
rust/src/model/transformer.rs (RMSNorm, adjacent-pair RoPE, SwiGLU, untied head).

Trains the `micro` and `nano` presets on the repository's own source corpus and
exports weights in the shared manifest+blob format (model/weights.rs). Runs
once at `make artifacts`; Python never touches the request path.

Usage: python -m compile.train --out ../artifacts [--budget-secs 480]
"""

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus as corpus_mod

CONFIGS = {
    "micro": dict(vocab=256, d_model=64, n_layers=2, n_heads=4, d_ff=256, max_seq=256),
    "nano": dict(vocab=256, d_model=128, n_layers=2, n_heads=4, d_ff=512, max_seq=256),
    "small": dict(vocab=256, d_model=256, n_layers=6, n_heads=8, d_ff=1024, max_seq=256),
}
ROPE_THETA = 10_000.0
RMS_EPS = 1e-5


def tensor_names(cfg):
    names = ["tok_emb"]
    for i in range(cfg["n_layers"]):
        for t in ["attn_norm", "q", "k", "v", "o", "mlp_norm", "gate", "up", "down"]:
            names.append(f"l{i}.{t}")
    names += ["out_norm", "head"]
    return names


def tensor_shape(cfg, name):
    d, f, v = cfg["d_model"], cfg["d_ff"], cfg["vocab"]
    if name in ("tok_emb", "head"):
        return (v, d)
    if name == "out_norm":
        return (d,)
    part = name.split(".")[1]
    return {
        "attn_norm": (d,),
        "mlp_norm": (d,),
        "q": (d, d),
        "k": (d, d),
        "v": (d, d),
        "o": (d, d),
        "gate": (f, d),
        "up": (f, d),
        "down": (d, f),
    }[part]


def init_params(cfg, key):
    params = {}
    for name in tensor_names(cfg):
        shape = tensor_shape(cfg, name)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            key, sub = jax.random.split(key)
            std = 1.0 / np.sqrt(shape[-1])
            params[name] = jax.random.normal(sub, shape, jnp.float32) * std
    return params


def rmsnorm(x, gain):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + RMS_EPS) * gain


def rope(x, positions):
    """Adjacent-pair RoPE, matching transformer.rs::rope_rotate.
    x: (..., T, H, Dh); positions: (T,)"""
    dh = x.shape[-1]
    idx = np.arange(0, dh, 2)
    freq = ROPE_THETA ** (-(idx.astype(np.float32)) / dh)  # (dh/2,)
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]  # (T, dh/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[None, :, None, :] if x.ndim == 4 else sin
    cos = cos[None, :, None, :] if x.ndim == 4 else cos
    a = x[..., 0::2]
    b = x[..., 1::2]
    ra = a * cos - b * sin
    rb = a * sin + b * cos
    out = jnp.stack([ra, rb], axis=-1).reshape(x.shape)
    return out


def forward(params, tokens, cfg):
    """tokens: (B, T) int32 -> logits (B, T, vocab)."""
    b, t = tokens.shape
    d = cfg["d_model"]
    h = cfg["n_heads"]
    dh = d // h
    x = params["tok_emb"][tokens]  # (B,T,D)
    positions = jnp.arange(t)
    mask = jnp.tril(jnp.ones((t, t), bool))
    for i in range(cfg["n_layers"]):
        xn = rmsnorm(x, params[f"l{i}.attn_norm"])
        q = (xn @ params[f"l{i}.q"].T).reshape(b, t, h, dh)
        k = (xn @ params[f"l{i}.k"].T).reshape(b, t, h, dh)
        v = (xn @ params[f"l{i}.v"].T).reshape(b, t, h, dh)
        q = rope(q, positions)
        k = rope(k, positions)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(dh)
        scores = jnp.where(mask[None, None], scores, -1e30)
        attn = jax.nn.softmax(scores, axis=-1)
        mix = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(b, t, d)
        x = x + mix @ params[f"l{i}.o"].T
        xn = rmsnorm(x, params[f"l{i}.mlp_norm"])
        gate = xn @ params[f"l{i}.gate"].T
        up = xn @ params[f"l{i}.up"].T
        act = jax.nn.silu(gate) * up
        x = x + act @ params[f"l{i}.down"].T
    x = rmsnorm(x, params["out_norm"])
    return x @ params["head"].T


def loss_fn(params, tokens, cfg):
    logits = forward(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


@functools.partial(jax.jit, static_argnames=("cfg_key",))
def train_step(params, opt_m, opt_v, tokens, step, lr_base, cfg_key):
    cfg = CONFIGS[cfg_key]
    loss, grads = jax.value_and_grad(loss_fn)(params, tokens, cfg)
    b1, b2, eps = 0.9, 0.95, 1e-8
    warmup = 20.0
    lr = lr_base * jnp.minimum(1.0, (step + 1) / warmup)
    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt_m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt_v, grads)
    tcorr1 = 1 - b1 ** (step + 1)
    tcorr2 = 1 - b2 ** (step + 1)
    new_params = jax.tree.map(
        lambda p, m, v: p - lr * (m / tcorr1) / (jnp.sqrt(v / tcorr2) + eps),
        params,
        new_m,
        new_v,
    )
    return new_params, new_m, new_v, loss


def batches(data, batch, seq, rng):
    n = len(data) - seq - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([data[i : i + seq + 1] for i in idx]).astype(np.int32)


def export(params, cfg, name, out_dir, meta):
    names = tensor_names(cfg)
    blob = bytearray()
    tensors = []
    offset = 0
    for tname in names:
        arr = np.asarray(params[tname], np.float32)
        tensors.append(
            dict(name=tname, shape=list(arr.shape), offset=offset)
        )
        blob += arr.tobytes()
        offset += arr.size
    manifest = dict(
        config=dict(
            name=name,
            vocab=cfg["vocab"],
            d_model=cfg["d_model"],
            n_layers=cfg["n_layers"],
            n_heads=cfg["n_heads"],
            d_ff=cfg["d_ff"],
            max_seq=cfg["max_seq"],
            rope_theta=ROPE_THETA,
            rms_eps=RMS_EPS,
        ),
        weights_file=f"model_{name}.bin",
        tensors=tensors,
        meta=meta,
    )
    (out_dir / f"model_{name}.json").write_text(json.dumps(manifest))
    (out_dir / f"model_{name}.bin").write_bytes(bytes(blob))
    print(f"[train] exported {name}: {offset} floats -> model_{name}.bin")


def train_model(name, data_train, out_dir, budget_secs, batch=8, lr=3e-3, max_steps=2000):
    cfg = CONFIGS[name]
    key = jax.random.PRNGKey(hash(name) & 0xFFFF)
    params = init_params(cfg, key)
    opt_m = jax.tree.map(jnp.zeros_like, params)
    opt_v = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(1234)
    gen = batches(np.frombuffer(data_train, dtype=np.uint8), batch, cfg["max_seq"], rng)
    losses = []
    start = time.time()
    step = 0
    while step < max_steps and time.time() - start < budget_secs:
        tokens = next(gen)
        params, opt_m, opt_v, loss = train_step(
            params, opt_m, opt_v, tokens, step, lr, name
        )
        if step % 10 == 0 or step == max_steps - 1:
            losses.append([step, float(loss)])
            print(f"[train/{name}] step {step} loss {float(loss):.4f} "
                  f"({time.time()-start:.0f}s)", flush=True)
        step += 1
    meta = dict(
        steps=step,
        final_loss=losses[-1][1] if losses else None,
        loss_curve=losses,
        seconds=round(time.time() - start, 1),
        corpus_bytes=len(data_train),
    )
    export(params, cfg, name, out_dir, meta)
    return meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--budget-secs", type=float, default=420.0)
    ap.add_argument("--models", default="micro,nano")
    args = ap.parse_args()
    from pathlib import Path

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    raw = corpus_mod.load_corpus(corpus_mod.default_roots(), 4 << 20)
    train_data, holdout = corpus_mod.split_corpus(raw, 0.1)
    (out_dir / "corpus_holdout.bin").write_bytes(holdout)
    print(f"[train] corpus {len(raw)} bytes ({len(holdout)} held out)")

    models = args.models.split(",")
    # Split the budget: micro converges fast, nano gets the bulk.
    shares = {"micro": 0.25, "nano": 0.75, "small": 1.0}
    total_share = sum(shares.get(m, 1.0) for m in models)
    for m in models:
        budget = args.budget_secs * shares.get(m, 1.0) / total_share
        train_model(m, train_data, out_dir, budget)


if __name__ == "__main__":
    main()
