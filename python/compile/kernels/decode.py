"""Layer-1 Pallas kernel: fused trellis-decode + matvec (paper §3.1, §4.3).

One grid instance per 16-row output tile. Each instance walks the row's packed
tiles, extracts every L-bit window with static shift tables (the bitstream is
little-endian, so a window is `(lo >> sh) | (hi << (32-sh))` — the "bitshift
decode"), maps states to weights with the compute code, and accumulates the
tile-local GEMV. The decoded weights never leave registers/VMEM: no `rows×cols`
f32 tensor is materialized (asserted by tests on the lowered HLO).

TPU note (DESIGN.md §Hardware-Adaptation): `interpret=True` is mandatory here —
the CPU PJRT plugin cannot execute Mosaic custom calls. BlockSpecs express the
same HBM→VMEM schedule the CUDA kernels express with threadblocks; per-tile
VMEM = tile_words·4 B (packed) + 64 B (x tile) + 64 B (acc) ≪ VMEM.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import codes


def _window_tables(steps, kv, l):
    """Static per-step word-index and shift tables for window extraction."""
    bit = np.arange(steps, dtype=np.int64) * kv
    w_idx = (bit >> 5).astype(np.int32)
    sh = (bit & 31).astype(np.uint32)
    del l
    return w_idx, sh


def _extract_states(words, w_idx, sh, l):
    """Vectorized little-endian window extraction (uint32 arithmetic only)."""
    lo = words[w_idx]
    hi = words[w_idx + 1]
    # (hi << (32-sh)) without an out-of-range shift when sh == 0:
    # (hi << (31-sh)) << 1 drops to 0 exactly when sh == 0.
    high = (hi << (jnp.uint32(31) - sh)) << jnp.uint32(1)
    return ((lo >> sh) | high) & jnp.uint32((1 << l) - 1)


def _decode_states(name, states, lut, q):
    if name == "1mad":
        return codes.onemad_decode(states)[:, None]
    if name == "3inst":
        return codes.threeinst_decode(states)[:, None]
    if name == "hyb":
        return codes.hyb_decode(states, lut, q)
    raise ValueError(name)


def make_decode_matvec(rows, cols, l, k, v, code, tx=16, ty=16, lut=None, q=None):
    """Build the fused decode-matvec as a jax function.

    Signature of the returned fn:
        fn(packed: uint32[tiles_r, tiles_c * padded_len], x: f32[cols],
           scale: f32[]) -> f32[rows]
    """
    assert rows % tx == 0 and cols % ty == 0
    t = tx * ty
    assert t % v == 0
    steps = t // v
    kv = k * v
    total_bits = steps * kv
    padded_len = (total_bits + (l - kv)) // 32 + 2
    tiles_r, tiles_c = rows // tx, cols // ty
    w_idx_np, sh_np = _window_tables(steps, kv, l)
    has_lut = lut is not None
    lut_np = None if lut is None else np.asarray(lut, np.float32)

    # Pallas forbids captured array constants: the static shift tables (and the
    # HYB LUT) enter as explicit kernel inputs, broadcast to every grid step.
    def kernel(packed_ref, x_ref, w_idx_ref, sh_ref, *rest):
        lut_ref = rest[0] if has_lut else None
        o_ref = rest[-1]
        words_row = packed_ref[0, :]
        w_idx = w_idx_ref[...]
        sh = sh_ref[...]
        lut_arr = lut_ref[...] if has_lut else None
        acc = jnp.zeros((tx,), jnp.float32)
        for bj in range(tiles_c):
            words = words_row[bj * padded_len : (bj + 1) * padded_len]
            states = _extract_states(words, w_idx, sh, l)
            vals = _decode_states(code, states, lut_arr, q)  # (steps, v)
            w_tile = vals.reshape(tx, ty)
            acc = acc + w_tile @ x_ref[bj * ty : (bj + 1) * ty]
        o_ref[...] = acc

    in_specs = [
        pl.BlockSpec((1, tiles_c * padded_len), lambda i: (i, 0)),
        pl.BlockSpec((cols,), lambda i: (0,)),
        pl.BlockSpec((steps,), lambda i: (0,)),
        pl.BlockSpec((steps,), lambda i: (0,)),
    ]
    if has_lut:
        in_specs.append(pl.BlockSpec(lut_np.shape, lambda i: (0,) * lut_np.ndim))

    call = pl.pallas_call(
        kernel,
        grid=(tiles_r,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tx,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )

    w_idx_c = jnp.asarray(w_idx_np)
    sh_c = jnp.asarray(sh_np)

    if has_lut:
        lut_c = jnp.asarray(lut_np)

        def fn(packed, x, scale):
            return call(packed, x, w_idx_c, sh_c, lut_c) * scale

    else:

        def fn(packed, x, scale):
            return call(packed, x, w_idx_c, sh_c) * scale

    return fn, dict(padded_len=padded_len, tiles_r=tiles_r, tiles_c=tiles_c)


@functools.lru_cache(maxsize=None)
def cached_decode_matvec(rows, cols, l, k, v, code, tx=16, ty=16):
    """LUT-free codes only (hashable args) — used by tests."""
    return make_decode_matvec(rows, cols, l, k, v, code, tx, ty)
