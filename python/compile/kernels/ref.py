"""Pure-numpy correctness oracle for the trellis decode + matvec.

Independent implementation (numpy int arithmetic, no JAX) of:
  * the little-endian packed bitstream / window extraction (DESIGN.md §7),
  * the compute codes (1MAD / 3INST / HYB),
  * the tiled decode-matvec the Pallas kernel computes.

pytest compares kernels/decode.py (and the jnp decode in codes.py) against this
module; the Rust test-suite pins the same golden vectors from aot.py.
"""

import numpy as np


# ---------- packed stream helpers ----------

def pack_bits(bits):
    """Pack a 0/1 array (little-endian bit order) into uint32 words."""
    bits = np.asarray(bits, dtype=np.uint64)
    n_words = (len(bits) + 31) // 32
    words = np.zeros(n_words, dtype=np.uint64)
    for p, b in enumerate(bits):
        words[p // 32] |= np.uint64(int(b) & 1) << np.uint64(p % 32)
    return words.astype(np.uint32)


def pad_for_decode(words, total_bits, l, kv):
    """Duplicate the head L-kV bits after the stream end + 1 spare word
    (mirror of rust trellis::packing::pad_for_decode)."""
    words = np.asarray(words, dtype=np.uint32)
    pad_bits = l - kv
    padded_bits = total_bits + pad_bits
    out = np.zeros(padded_bits // 32 + 2, dtype=np.uint32)
    out[: len(words)] = words
    for i in range(pad_bits):
        b = (int(words[i // 32]) >> (i % 32)) & 1
        p = total_bits + i
        out[p // 32] |= np.uint32(b << (p % 32))
    return out


def decode_window(padded, bit_offset, l):
    """State at bit_offset: one unaligned 64-bit load, shift, mask."""
    w = bit_offset >> 5
    sh = bit_offset & 31
    lo = int(padded[w])
    hi = int(padded[w + 1]) if w + 1 < len(padded) else 0
    pair = lo | (hi << 32)
    return (pair >> sh) & ((1 << l) - 1)


# ---------- codes (independent numpy implementations) ----------

M32 = 1 << 32


def onemad_ref(state):
    x = (34038481 * int(state) + 76625530) % M32
    s = (x & 0xFF) + ((x >> 8) & 0xFF) + ((x >> 16) & 0xFF) + (x >> 24)
    return np.float32(
        (np.float32(s) - np.float32(510.0)) * (np.float32(1.0) / np.float32(147.8005413))
    )


def _f16(bits):
    return np.float32(np.array([bits], dtype=np.uint16).view(np.float16)[0])


def threeinst_ref(state):
    x = (89226354 * int(state) + 64248484) % M32
    m1 = _f16(((x & 0xFFFF) & 0x8FFF) ^ 0x3B60)
    m2 = _f16((((x >> 16) & 0xFFFF) & 0x8FFF) ^ 0x3B60)
    return np.float32((m1 + m2) * (np.float32(1.0) / np.float32(1.2443900210)))


def hyb_ref(state, lut, q):
    x = (int(state) * int(state) + int(state)) % M32
    idx = (x >> (15 - q)) & ((1 << q) - 1)
    v = np.array(lut[idx], dtype=np.float32).copy()
    if x & (1 << 15):
        v[-1] = -v[-1]
    return v


def decode_ref(name, state, lut=None, q=None):
    if name == "1mad":
        return np.array([onemad_ref(state)], dtype=np.float32)
    if name == "3inst":
        return np.array([threeinst_ref(state)], dtype=np.float32)
    if name == "hyb":
        return hyb_ref(state, lut, q)
    raise ValueError(name)


# ---------- tiled decode + matvec oracle ----------

def decode_tile_ref(padded_words, l, k, v, tx, ty, name, lut=None, q=None):
    """Decode one tx*ty tile (row-major) from its padded word stream."""
    t = tx * ty
    steps = t // v
    out = np.zeros(t, dtype=np.float32)
    for step in range(steps):
        state = decode_window(padded_words, step * k * v, l)
        vals = decode_ref(name, state, lut, q)
        out[step * v : (step + 1) * v] = vals
    return out.reshape(tx, ty)


def matvec_ref(packed_tiles, l, k, v, tx, ty, name, x, scale, lut=None, q=None):
    """y = scale * decode(W) @ x over a (tiles_r, tiles_c, tile_words) layout."""
    tiles_r, tiles_c, _ = packed_tiles.shape
    y = np.zeros(tiles_r * tx, dtype=np.float32)
    for bi in range(tiles_r):
        for bj in range(tiles_c):
            w = decode_tile_ref(packed_tiles[bi, bj], l, k, v, tx, ty, name, lut, q)
            y[bi * tx : (bi + 1) * tx] += w @ x[bj * ty : (bj + 1) * ty]
    return y * np.float32(scale)


def random_packed_tiles(rng, tiles_r, tiles_c, l, k, v, tx, ty):
    """Random (valid) tail-biting streams: ANY cyclic bitstring is a valid walk,
    so random bits + pad_for_decode give a well-formed tile."""
    t = tx * ty
    steps = t // v
    kv = k * v
    total_bits = steps * kv
    tile_words_packed = (total_bits + 31) // 32
    padded_len = (total_bits + (l - kv)) // 32 + 2
    tiles = np.zeros((tiles_r, tiles_c, padded_len), dtype=np.uint32)
    for bi in range(tiles_r):
        for bj in range(tiles_c):
            raw = rng.integers(0, M32, size=tile_words_packed, dtype=np.uint64).astype(
                np.uint32
            )
            extra = tile_words_packed * 32 - total_bits
            if extra:
                raw[-1] &= np.uint32((1 << (32 - extra)) - 1)
            tiles[bi, bj] = pad_for_decode(raw, total_bits, l, kv)
    return tiles
