"""Bit-exact JAX mirrors of the Rust compute codes (rust/src/codes/*).

These are the L-bit-state -> pseudorandom-Gaussian decoders of paper §3.1,
written with jnp.uint32 wrap-around arithmetic and f16 bitcasts so that the
Pallas kernel (decode.py), the jnp reference (ref.py), and the Rust decoder all
agree bit-for-bit. Frozen constants are documented in DESIGN.md §7; golden
vectors are emitted by aot.py and checked on both sides.
"""

import jax.numpy as jnp
from jax import lax

# --- 1MAD (Alg. 1) ---
ONEMAD_A = 34038481
ONEMAD_B = 76625530
ONEMAD_MEAN = 510.0
ONEMAD_STD = 147.8005413

# --- 3INST (Alg. 2) ---
THREEINST_A = 89226354
THREEINST_B = 64248484
THREEINST_MASK = 0x8FFF
THREEINST_MAGIC = 0x3B60  # f16 bits of 0.922
THREEINST_STD = 1.2443900210


def onemad_decode(states):
    """Decode uint32 state words to approx-N(0,1) float32 (1MAD)."""
    states = states.astype(jnp.uint32)
    x = states * jnp.uint32(ONEMAD_A) + jnp.uint32(ONEMAD_B)
    s = (
        (x & jnp.uint32(0xFF))
        + ((x >> jnp.uint32(8)) & jnp.uint32(0xFF))
        + ((x >> jnp.uint32(16)) & jnp.uint32(0xFF))
        + (x >> jnp.uint32(24))
    )
    return (s.astype(jnp.float32) - ONEMAD_MEAN) * (1.0 / ONEMAD_STD)


def _f16_bits_to_f32(bits16):
    """Reinterpret uint16 as IEEE binary16, widen to f32."""
    h = lax.bitcast_convert_type(bits16.astype(jnp.uint16), jnp.float16)
    return h.astype(jnp.float32)


def threeinst_decode(states):
    """Decode uint32 state words to approx-N(0,1) float32 (3INST)."""
    states = states.astype(jnp.uint32)
    x = states * jnp.uint32(THREEINST_A) + jnp.uint32(THREEINST_B)
    lo = (x & jnp.uint32(0xFFFF)).astype(jnp.uint16)
    hi = (x >> jnp.uint32(16)).astype(jnp.uint16)
    mask = jnp.uint16(THREEINST_MASK)
    magic = jnp.uint16(THREEINST_MAGIC)
    m1 = _f16_bits_to_f32((lo & mask) ^ magic)
    m2 = _f16_bits_to_f32((hi & mask) ^ magic)
    return (m1 + m2) * (1.0 / THREEINST_STD)


def hyb_hash(states):
    """Klimov-Shamir T-function x <- x^2 + x (mod 2^32)."""
    x = states.astype(jnp.uint32)
    return x * x + x


def hyb_decode(states, lut, q):
    """Decode via hashed lookup (Alg. 3). `lut` is (2^q, V) float32.

    Returns (N, V) float32 — bit 15 of the hash flips the sign of the last
    component.
    """
    x = hyb_hash(states)
    idx = (x >> jnp.uint32(15 - q)) & jnp.uint32((1 << q) - 1)
    v = jnp.asarray(lut, jnp.float32)[idx]  # (N, V)
    flip = ((x >> jnp.uint32(15)) & jnp.uint32(1)).astype(jnp.float32)
    sign = 1.0 - 2.0 * flip
    return v.at[:, -1].multiply(sign)


def decode_by_name(name, states, lut=None, q=None):
    if name == "1mad":
        return onemad_decode(states)
    if name == "3inst":
        return threeinst_decode(states)
    if name == "hyb":
        assert lut is not None and q is not None
        return hyb_decode(states, lut, q)
    raise ValueError(f"unknown code '{name}'")
