//! Vendored minimal [loom](https://docs.rs/loom)-compatible model checker.
//!
//! The qtip build environment has no crates.io access, so the real loom crate
//! cannot be a dependency. This crate re-implements the small slice of loom's
//! API that `qtip::util::sync` re-exports — `model`, `thread::spawn`/`join`,
//! `sync::{Arc, Mutex, MutexGuard, Condvar}` and `sync::atomic::*` — backed by
//! a systematic scheduler that *exhaustively enumerates thread interleavings*
//! (up to a preemption bound) instead of sampling whatever schedule the OS
//! happens to produce.
//!
//! ## How it explores
//!
//! Inside [`model`], threads are real OS threads but only one runs at a time:
//! a token (the active thread id) is passed under a scheduler mutex. Every
//! *visible* operation — atomic load/store/rmw, mutex lock, condvar
//! wait/notify, spawn, join, thread exit — is a decision point where the
//! scheduler picks which runnable thread continues. The sequence of picks is
//! recorded as a decision trace; after each run the trace is advanced
//! depth-first (last decision with an unexplored alternative is bumped, the
//! suffix is discarded) and the closure is re-run, replaying the prefix
//! deterministically. The search terminates when every decision has been
//! exhausted.
//!
//! Like CHESS and loom's `LOOM_MAX_PREEMPTIONS`, the search bounds the number
//! of *preemptions* (switching away from a runnable thread) per schedule —
//! `LOOM_MAX_PREEMPTIONS`, default 2 — which keeps the space tractable while
//! still catching the vast majority of ordering bugs. Forced switches (the
//! active thread blocks) are free.
//!
//! ## Honest limitations vs real loom
//!
//! * Atomics are modeled *sequentially consistent*. The checker permutes
//!   statement interleavings, not C11 weak-memory reorderings, so it can miss
//!   bugs that only a relaxed-memory execution exposes (those are TSan's and
//!   code review's job; see EXPERIMENTS.md "Soundness tooling").
//! * Condvar spurious wakeups are not injected; `notify_one` wakes the
//!   longest-waiting thread. The pool only uses `notify_all`.
//! * No `UnsafeCell` access checking — the shimmed code's `unsafe` blocks are
//!   covered by Miri instead.
//!
//! Deadlocks (no runnable thread) and livelocks (schedules exceeding a step
//! cap) abort the model with a panic, as does a closure that returns while
//! spawned threads are still live (a missing `join`).

#![forbid(unsafe_code)]

use std::cell::RefCell;
use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex};

const DEFAULT_MAX_PREEMPTIONS: usize = 2;
const DEFAULT_MAX_ITERATIONS: usize = 500_000;
const MAX_STEPS_PER_SCHEDULE: usize = 100_000;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Run state of one modeled thread.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Run {
    Runnable,
    /// Waiting to acquire the mutex at this address; runnable once it is free.
    BlockedMutex(usize),
    /// Parked on the condvar at this address; a notify moves it to
    /// `BlockedMutex` on the mutex it released when it began waiting.
    BlockedCv(usize),
    /// Waiting for the thread with this id to finish.
    BlockedJoin(usize),
    Finished,
}

/// One scheduling decision: the runnable candidates (canonical order:
/// current thread first, then ascending tid) and which index was taken.
struct Decision {
    options: Vec<usize>,
    pick: usize,
}

struct SchedState {
    /// Tid holding the run token. `usize::MAX` after the last thread exits.
    active: usize,
    threads: Vec<Run>,
    /// Mutex address -> holder tid (None = free).
    mutexes: HashMap<usize, Option<usize>>,
    /// Condvar address -> FIFO of (waiter tid, mutex address it released).
    cv_waiters: HashMap<usize, Vec<(usize, usize)>>,
    trace: Vec<Decision>,
    /// Next index in `trace` to replay; past the end means we are extending.
    cursor: usize,
    preemptions: usize,
    steps: usize,
    /// Set on deadlock/divergence/panic so parked threads wake and unwind
    /// instead of hanging the test binary.
    aborted: Option<String>,
}

struct Scheduler {
    state: StdMutex<SchedState>,
    cv: StdCondvar,
    max_preemptions: usize,
}

thread_local! {
    static CTX: RefCell<Option<(StdArc<Scheduler>, usize)>> = const { RefCell::new(None) };
}

fn ctx() -> Option<(StdArc<Scheduler>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

fn set_ctx(s: &StdArc<Scheduler>, tid: usize) {
    CTX.with(|c| *c.borrow_mut() = Some((StdArc::clone(s), tid)));
}

fn clear_ctx() {
    CTX.with(|c| *c.borrow_mut() = None);
}

fn runnable(st: &SchedState, tid: usize) -> bool {
    match st.threads[tid] {
        Run::Runnable => true,
        Run::BlockedMutex(m) => st.mutexes.get(&m).map_or(true, |h| h.is_none()),
        _ => false,
    }
}

fn deadlock_msg(st: &SchedState) -> String {
    let mut s = String::from("deadlock: no runnable thread; states:");
    for (t, r) in st.threads.iter().enumerate() {
        s.push_str(&format!(" t{t}={r:?}"));
    }
    s
}

type Guard<'a> = std::sync::MutexGuard<'a, SchedState>;

impl Scheduler {
    fn new(trace: Vec<Decision>, max_preemptions: usize) -> Self {
        Scheduler {
            state: StdMutex::new(SchedState {
                active: 0,
                threads: vec![Run::Runnable],
                mutexes: HashMap::new(),
                cv_waiters: HashMap::new(),
                trace,
                cursor: 0,
                preemptions: 0,
                steps: 0,
                aborted: None,
            }),
            cv: StdCondvar::new(),
            max_preemptions,
        }
    }

    /// Lock the scheduler state, recovering from poisoning (a panicking model
    /// thread is an expected failure mode; the state itself stays coherent
    /// because every mutation is a small atomic-at-this-level update).
    fn lock_state(&self) -> Guard<'_> {
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn wait_state<'a>(&'a self, g: Guard<'a>) -> Guard<'a> {
        self.cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn check_abort(&self, st: &Guard<'_>) {
        if let Some(msg) = &st.aborted {
            let msg = msg.clone();
            panic!("loom model aborted: {msg}");
        }
    }

    fn abort(&self, mut st: Guard<'_>, msg: String) -> ! {
        st.aborted = Some(msg.clone());
        self.cv.notify_all();
        drop(st);
        panic!("loom model aborted: {msg}");
    }

    /// Make one scheduling decision on behalf of `me` (the token holder),
    /// replaying the trace if inside the recorded prefix and extending it
    /// otherwise. If another thread is chosen, hands the token over and — when
    /// `wait_token` — blocks until `me` is scheduled again. `wait_token` is
    /// false only for a finishing thread, which hands off and exits.
    fn decide(&self, mut st: Guard<'_>, me: usize, wait_token: bool) -> Guard<'_> {
        self.check_abort(&st);
        st.steps += 1;
        if st.steps > MAX_STEPS_PER_SCHEDULE {
            self.abort(
                st,
                format!(
                    "schedule exceeded {MAX_STEPS_PER_SCHEDULE} steps; \
                     livelock (unbounded spin) in the modeled code?"
                ),
            );
        }
        let chosen = if st.cursor < st.trace.len() {
            // Replay: re-take the recorded pick; re-derive the preemption
            // count so the extension phase budgets against the right value.
            let d = &st.trace[st.cursor];
            let (c, first) = (d.options[d.pick], d.options[0]);
            if !runnable(&st, c) {
                let msg = format!(
                    "replay divergence at step {}: thread {c} is not \
                     runnable (non-deterministic model closure?)",
                    st.cursor
                );
                self.abort(st, msg);
            }
            if first == me && c != me {
                st.preemptions += 1;
            }
            c
        } else {
            // Extend: enumerate runnable candidates. Switching away from a
            // runnable `me` is a preemption and only offered under budget.
            let me_runnable = runnable(&st, me);
            let mut options = Vec::new();
            if me_runnable {
                options.push(me);
            }
            if !me_runnable || st.preemptions < self.max_preemptions {
                for t in 0..st.threads.len() {
                    if t != me && runnable(&st, t) {
                        options.push(t);
                    }
                }
            }
            if options.is_empty() {
                let done = st
                    .threads
                    .iter()
                    .enumerate()
                    .all(|(t, r)| t == me || *r == Run::Finished);
                if done && !wait_token {
                    // `me` was the last live thread and just finished.
                    st.active = usize::MAX;
                    self.cv.notify_all();
                    return st;
                }
                let msg = deadlock_msg(&st);
                self.abort(st, msg);
            }
            let c = options[0];
            st.trace.push(Decision { options, pick: 0 });
            c
        };
        st.cursor += 1;
        st.active = chosen;
        if chosen != me {
            self.cv.notify_all();
            if wait_token {
                loop {
                    st = self.wait_state(st);
                    self.check_abort(&st);
                    if st.active == me {
                        break;
                    }
                }
            }
        }
        st
    }

    /// Decision point for a non-blocking visible op (atomic access, the
    /// instant before a lock attempt, spawn, notify).
    fn switch(&self, me: usize) {
        let st = self.lock_state();
        drop(self.decide(st, me, true));
    }

    /// Block until a new thread is granted the token for the first time.
    fn wait_for_token(&self, me: usize) {
        let mut st = self.lock_state();
        loop {
            self.check_abort(&st);
            if st.active == me {
                return;
            }
            st = self.wait_state(st);
        }
    }

    fn register_thread(&self) -> usize {
        let mut st = self.lock_state();
        st.threads.push(Run::Runnable);
        st.threads.len() - 1
    }

    fn model_lock(&self, addr: usize, me: usize) {
        self.switch(me);
        let mut st = self.lock_state();
        loop {
            let holder = st.mutexes.get(&addr).copied().flatten();
            match holder {
                None => {
                    st.mutexes.insert(addr, Some(me));
                    st.threads[me] = Run::Runnable;
                    return;
                }
                Some(h) if h == me => {
                    let msg = format!("thread {me} re-locked a mutex it already holds");
                    self.abort(st, msg);
                }
                Some(_) => {
                    st.threads[me] = Run::BlockedMutex(addr);
                    // We are only rescheduled once the mutex is free; the
                    // loop re-checks and claims it.
                    st = self.decide(st, me, true);
                }
            }
        }
    }

    fn model_unlock(&self, addr: usize, me: usize) {
        let mut st = self.lock_state();
        let holder = st.mutexes.get(&addr).copied().flatten();
        if holder == Some(me) {
            st.mutexes.insert(addr, None);
        } else {
            let msg = format!("thread {me} released a mutex it does not hold");
            self.abort(st, msg);
        }
        // No decision point here: blocked waiters become runnable candidates
        // at the very next decision, which every subsequent visible op (or
        // thread exit) provides.
    }

    fn model_cv_wait(&self, cv: usize, mutex_addr: usize, me: usize) {
        self.switch(me);
        let mut st = self.lock_state();
        let holder = st.mutexes.get(&mutex_addr).copied().flatten();
        if holder != Some(me) {
            let msg = format!("thread {me} waited on a condvar without holding the mutex");
            self.abort(st, msg);
        }
        // Atomically (the token is not released until `decide`) drop the
        // mutex and register as a waiter, matching std condvar semantics.
        st.mutexes.insert(mutex_addr, None);
        st.cv_waiters.entry(cv).or_default().push((me, mutex_addr));
        st.threads[me] = Run::BlockedCv(cv);
        st = self.decide(st, me, true);
        // A notify moved us to BlockedMutex(mutex_addr) and a later decision
        // scheduled us, which requires the mutex to be free — but another
        // woken waiter may race us to it, so loop like a lock.
        loop {
            let holder = st.mutexes.get(&mutex_addr).copied().flatten();
            if holder.is_none() {
                st.mutexes.insert(mutex_addr, Some(me));
                st.threads[me] = Run::Runnable;
                return;
            }
            st.threads[me] = Run::BlockedMutex(mutex_addr);
            st = self.decide(st, me, true);
        }
    }

    fn model_notify(&self, cv: usize, me: usize, all: bool) {
        self.switch(me);
        let mut st = self.lock_state();
        let woken: Vec<(usize, usize)> = match st.cv_waiters.get_mut(&cv) {
            Some(w) if !w.is_empty() => {
                if all {
                    std::mem::take(w)
                } else {
                    vec![w.remove(0)]
                }
            }
            _ => Vec::new(),
        };
        for (t, m) in woken {
            st.threads[t] = Run::BlockedMutex(m);
        }
    }

    fn model_join(&self, target: usize, me: usize) {
        self.switch(me);
        let mut st = self.lock_state();
        if st.threads[target] != Run::Finished {
            st.threads[me] = Run::BlockedJoin(target);
            st = self.decide(st, me, true);
            debug_assert_eq!(st.threads[target], Run::Finished);
        }
        drop(st);
    }

    fn finish_thread(&self, me: usize, panicked: bool) {
        let mut st = self.lock_state();
        st.threads[me] = Run::Finished;
        for t in 0..st.threads.len() {
            if st.threads[t] == Run::BlockedJoin(me) {
                st.threads[t] = Run::Runnable;
            }
        }
        if panicked {
            // Don't try to schedule further: flag the whole model so every
            // parked thread wakes up and unwinds.
            st.aborted
                .get_or_insert_with(|| format!("model thread {me} panicked"));
            st.active = usize::MAX;
            self.cv.notify_all();
            return;
        }
        drop(self.decide(st, me, false));
    }
}

/// Depth-first advance: bump the deepest decision with an unexplored
/// alternative, discarding everything after it. Returns false when the whole
/// space has been explored.
fn advance(trace: &mut Vec<Decision>) -> bool {
    while let Some(d) = trace.last_mut() {
        d.pick += 1;
        if d.pick < d.options.len() {
            return true;
        }
        trace.pop();
    }
    false
}

/// Exhaustively model-check `f` under every thread interleaving (up to the
/// `LOOM_MAX_PREEMPTIONS` bound, default 2). Panics — failing the enclosing
/// test — on the first schedule where `f` panics, deadlocks, livelocks, or
/// returns with unjoined threads.
pub fn model<F>(f: F)
where
    F: Fn() + Send + Sync + 'static,
{
    let max_preemptions = env_usize("LOOM_MAX_PREEMPTIONS", DEFAULT_MAX_PREEMPTIONS);
    let max_iterations = env_usize("LOOM_MAX_ITERATIONS", DEFAULT_MAX_ITERATIONS);
    let mut trace: Vec<Decision> = Vec::new();
    let mut iterations = 0usize;
    loop {
        iterations += 1;
        assert!(
            iterations <= max_iterations,
            "loom: {max_iterations} schedules explored without exhausting the \
             space; raise LOOM_MAX_ITERATIONS or shrink the model"
        );
        let sched = StdArc::new(Scheduler::new(std::mem::take(&mut trace), max_preemptions));
        set_ctx(&sched, 0);
        let out = catch_unwind(AssertUnwindSafe(&f));
        clear_ctx();
        let mut st = sched.lock_state();
        if let Err(payload) = out {
            st.aborted
                .get_or_insert_with(|| "model closure panicked".to_string());
            sched.cv.notify_all();
            drop(st);
            resume_unwind(payload);
        }
        if st.threads.iter().skip(1).any(|r| *r != Run::Finished) {
            let msg = format!(
                "model closure returned with live threads (missing join?): {}",
                deadlock_msg(&st)
            );
            st.aborted = Some(msg.clone());
            sched.cv.notify_all();
            drop(st);
            panic!("{msg}");
        }
        trace = std::mem::take(&mut st.trace);
        drop(st);
        if !advance(&mut trace) {
            return;
        }
    }
}

pub mod thread {
    use super::{clear_ctx, ctx, set_ctx};
    use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};

    pub struct JoinHandle<T> {
        inner: Option<std::thread::JoinHandle<T>>,
        tid: Option<usize>,
    }

    impl<T> JoinHandle<T> {
        pub fn join(mut self) -> std::thread::Result<T> {
            if let Some(tid) = self.tid {
                if let Some((s, me)) = ctx() {
                    s.model_join(tid, me);
                }
            }
            self.inner.take().expect("join called twice").join()
        }
    }

    /// Spawn a thread. Inside [`super::model`] the thread joins the modeled
    /// schedule (its first step is waiting to be scheduled); outside it this
    /// is a plain `std::thread::spawn`.
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        match ctx() {
            None => JoinHandle { inner: Some(std::thread::spawn(f)), tid: None },
            Some((sched, me)) => {
                let tid = sched.register_thread();
                let s2 = std::sync::Arc::clone(&sched);
                let inner = std::thread::Builder::new()
                    .name(format!("loom-model-{tid}"))
                    .spawn(move || {
                        set_ctx(&s2, tid);
                        s2.wait_for_token(tid);
                        let out = catch_unwind(AssertUnwindSafe(f));
                        s2.finish_thread(tid, out.is_err());
                        clear_ctx();
                        match out {
                            Ok(v) => v,
                            Err(p) => resume_unwind(p),
                        }
                    })
                    .expect("spawn loom model thread");
                // The spawn itself is a visible op: child-runs-first schedules
                // must be explorable.
                sched.switch(me);
                JoinHandle { inner: Some(inner), tid: Some(tid) }
            }
        }
    }
}

pub mod sync {
    pub use std::sync::Arc;

    use super::ctx;
    use std::sync::{LockResult, PoisonError};

    pub struct Mutex<T> {
        inner: std::sync::Mutex<T>,
    }

    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
        inner: Option<std::sync::MutexGuard<'a, T>>,
        modeled: Option<(std::sync::Arc<super::Scheduler>, usize)>,
    }

    impl<T> Mutex<T> {
        pub fn new(t: T) -> Self {
            Mutex { inner: std::sync::Mutex::new(t) }
        }

        fn addr(&self) -> usize {
            self as *const Mutex<T> as usize
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            let modeled = ctx();
            if let Some((s, me)) = &modeled {
                // The model grants exclusive ownership before we touch the
                // real mutex, so the inner lock below never contends.
                s.model_lock(self.addr(), *me);
            }
            match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g), modeled }),
                Err(p) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                    modeled,
                })),
            }
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("guard present until drop")
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("guard present until drop")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            // Release the real lock first, then the model's ownership record;
            // no other model thread can run in between (we hold the token).
            self.inner.take();
            if let Some((s, me)) = self.modeled.take() {
                s.model_unlock(self.lock.addr(), me);
            }
        }
    }

    #[derive(Default)]
    pub struct Condvar {
        raw: std::sync::Condvar,
    }

    impl Condvar {
        pub fn new() -> Self {
            Condvar { raw: std::sync::Condvar::new() }
        }

        fn addr(&self) -> usize {
            self as *const Condvar as usize
        }

        pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            let lock = guard.lock;
            match guard.modeled.take() {
                Some((s, me)) => {
                    // Drop the real guard before the model releases the
                    // mutex; the token serializes us against other threads.
                    guard.inner.take();
                    drop(guard);
                    s.model_cv_wait(self.addr(), lock.addr(), me);
                    // Woken and re-granted the mutex by the model; the real
                    // lock is uncontended.
                    match lock.inner.lock() {
                        Ok(g) => Ok(MutexGuard {
                            lock,
                            inner: Some(g),
                            modeled: Some((s, me)),
                        }),
                        Err(p) => Err(PoisonError::new(MutexGuard {
                            lock,
                            inner: Some(p.into_inner()),
                            modeled: Some((s, me)),
                        })),
                    }
                }
                None => {
                    let inner = guard.inner.take().expect("guard present until drop");
                    drop(guard);
                    match self.raw.wait(inner) {
                        Ok(g) => Ok(MutexGuard { lock, inner: Some(g), modeled: None }),
                        Err(p) => Err(PoisonError::new(MutexGuard {
                            lock,
                            inner: Some(p.into_inner()),
                            modeled: None,
                        })),
                    }
                }
            }
        }

        pub fn notify_all(&self) {
            match ctx() {
                Some((s, me)) => s.model_notify(self.addr(), me, true),
                None => self.raw.notify_all(),
            }
        }

        pub fn notify_one(&self) {
            match ctx() {
                Some((s, me)) => s.model_notify(self.addr(), me, false),
                None => self.raw.notify_one(),
            }
        }
    }

    pub mod atomic {
        pub use std::sync::atomic::Ordering;

        use super::super::ctx;

        fn decision_point() {
            if let Some((s, me)) = ctx() {
                s.switch(me);
            }
        }

        macro_rules! modeled_atomic {
            ($name:ident, $std:ty, $val:ty) => {
                /// Modeled atomic: every access is a scheduler decision point.
                /// Memory-order arguments are accepted for API compatibility
                /// but the model executes sequentially consistent (see crate
                /// docs for why that is an under-approximation).
                #[derive(Debug, Default)]
                pub struct $name {
                    v: $std,
                }

                impl $name {
                    pub fn new(v: $val) -> Self {
                        Self { v: <$std>::new(v) }
                    }

                    pub fn load(&self, _order: Ordering) -> $val {
                        decision_point();
                        self.v.load(Ordering::SeqCst)
                    }

                    pub fn store(&self, val: $val, _order: Ordering) {
                        decision_point();
                        self.v.store(val, Ordering::SeqCst)
                    }

                    pub fn swap(&self, val: $val, _order: Ordering) -> $val {
                        decision_point();
                        self.v.swap(val, Ordering::SeqCst)
                    }

                    pub fn compare_exchange(
                        &self,
                        current: $val,
                        new: $val,
                        _success: Ordering,
                        _failure: Ordering,
                    ) -> Result<$val, $val> {
                        decision_point();
                        self.v.compare_exchange(current, new, Ordering::SeqCst, Ordering::SeqCst)
                    }

                    pub fn into_inner(self) -> $val {
                        self.v.into_inner()
                    }
                }
            };
        }

        macro_rules! modeled_atomic_int {
            ($name:ident, $val:ty) => {
                impl $name {
                    pub fn fetch_add(&self, val: $val, _order: Ordering) -> $val {
                        decision_point();
                        self.v.fetch_add(val, Ordering::SeqCst)
                    }

                    pub fn fetch_sub(&self, val: $val, _order: Ordering) -> $val {
                        decision_point();
                        self.v.fetch_sub(val, Ordering::SeqCst)
                    }
                }
            };
        }

        modeled_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
        modeled_atomic!(AtomicU8, std::sync::atomic::AtomicU8, u8);
        modeled_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
        modeled_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
        modeled_atomic_int!(AtomicU8, u8);
        modeled_atomic_int!(AtomicU64, u64);
        modeled_atomic_int!(AtomicUsize, usize);
    }
}

#[cfg(test)]
mod tests {
    use super::sync::atomic::{AtomicUsize, Ordering};
    use super::sync::{Arc, Condvar, Mutex};

    /// The checker must find the lost-update interleaving of a naive
    /// read-modify-write split across two threads.
    #[test]
    fn finds_lost_update() {
        let raced = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let raced2 = std::sync::Arc::clone(&raced);
        super::model(move || {
            let v = Arc::new(AtomicUsize::new(0));
            let v2 = Arc::clone(&v);
            let h = super::thread::spawn(move || {
                let x = v2.load(Ordering::SeqCst);
                v2.store(x + 1, Ordering::SeqCst);
            });
            let x = v.load(Ordering::SeqCst);
            v.store(x + 1, Ordering::SeqCst);
            h.join().unwrap();
            if v.load(Ordering::SeqCst) == 1 {
                raced2.store(true, std::sync::atomic::Ordering::SeqCst);
            }
        });
        assert!(
            raced.load(std::sync::atomic::Ordering::SeqCst),
            "exploration never produced the lost-update schedule"
        );
    }

    /// Mutexed increments must never lose an update, under every schedule.
    #[test]
    fn mutex_excludes() {
        super::model(|| {
            let v = Arc::new(Mutex::new(0usize));
            let v2 = Arc::clone(&v);
            let h = super::thread::spawn(move || {
                *v2.lock().unwrap() += 1;
            });
            *v.lock().unwrap() += 1;
            h.join().unwrap();
            assert_eq!(*v.lock().unwrap(), 2);
        });
    }

    /// Classic flag + condvar handshake: the waiter must always observe the
    /// flag, in particular when it parks before the signaler runs.
    #[test]
    fn condvar_handshake_never_hangs() {
        super::model(|| {
            let pair = Arc::new((Mutex::new(false), Condvar::new()));
            let pair2 = Arc::clone(&pair);
            let h = super::thread::spawn(move || {
                let mut done = pair2.0.lock().unwrap();
                *done = true;
                pair2.1.notify_all();
            });
            let mut done = pair.0.lock().unwrap();
            while !*done {
                done = pair.1.wait(done).unwrap();
            }
            drop(done);
            h.join().unwrap();
        });
    }

    /// A deadlock (waiting with nobody left to notify) must be detected and
    /// reported, not hang the test binary.
    #[test]
    fn deadlock_is_detected() {
        let r = std::panic::catch_unwind(|| {
            super::model(|| {
                let pair = Arc::new((Mutex::new(false), Condvar::new()));
                let g = pair.0.lock().unwrap();
                let _ = pair.1.wait(g).unwrap();
            });
        });
        let msg = match r {
            Ok(()) => panic!("deadlocked model returned successfully"),
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_else(|| "non-string panic".to_string()),
        };
        assert!(msg.contains("deadlock"), "unexpected panic: {msg}");
    }
}
