//! Evaluation harness: held-out perplexity (the W2/C4 substitute) and the
//! zeroshot-proxy task suite (the LM-Eval substitute) — see DESIGN.md §4.

pub mod perplexity;
pub mod zeroshot;

pub use perplexity::{perplexity, perplexity_pool, PerplexityReport};
pub use zeroshot::{zeroshot_suite, zeroshot_suite_pool, ZeroshotReport};
