//! Zeroshot-proxy task suite — the LM-Eval substitute (DESIGN.md §4).
//!
//! Three deterministic tasks, scored like LM-Eval multiple choice / accuracy:
//!
//! * **next-byte** — top-1 next-token accuracy on held-out corpus windows
//!   (the closest proxy to the broad-coverage zeroshot suites).
//! * **copy** — induction: a random string is repeated; accuracy of continuing
//!   the second occurrence. Tests the attention circuitry quantization most
//!   easily damages.
//! * **bracket** — multiple-choice: after a synthetic nested-bracket prefix, the
//!   model must rank the *matching* closer above the two mismatched ones.

use crate::model::transformer::Transformer;
use crate::util::rng::Rng;
use crate::util::threadpool::ExecPool;

#[derive(Clone, Debug)]
pub struct ZeroshotReport {
    pub next_byte_acc: f64,
    pub copy_acc: f64,
    pub bracket_acc: f64,
}

impl ZeroshotReport {
    pub fn mean(&self) -> f64 {
        (self.next_byte_acc + self.copy_acc + self.bracket_acc) / 3.0
    }
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

/// Top-1 next-byte accuracy over `n_windows` held-out windows.
pub fn next_byte_accuracy(model: &Transformer, data: &[u8], n_windows: usize) -> f64 {
    next_byte_accuracy_pool(model, data, n_windows, &ExecPool::sequential())
}

/// [`next_byte_accuracy`] with the window forwards striped across `pool`.
pub fn next_byte_accuracy_pool(
    model: &Transformer,
    data: &[u8],
    n_windows: usize,
    pool: &ExecPool,
) -> f64 {
    let seq = model.cfg.max_seq.min(64);
    let mut correct = 0usize;
    let mut total = 0usize;
    let mut off = 0usize;
    for _ in 0..n_windows {
        if off + seq + 1 > data.len() {
            break;
        }
        let tokens: Vec<u16> = data[off..off + seq + 1].iter().map(|&b| b as u16).collect();
        let logits = model.forward_batch_with(&tokens[..seq], pool);
        // Score the second half only (give the model context).
        for t in seq / 2..seq {
            if argmax(logits.row(t)) == tokens[t + 1] as usize {
                correct += 1;
            }
            total += 1;
        }
        off += seq;
    }
    if total == 0 {
        return 0.0;
    }
    correct as f64 / total as f64
}

/// Induction-head copy task: "<s> X <s> X[..j]" → predict X[j].
pub fn copy_accuracy(model: &Transformer, n_cases: usize, seed: u64) -> f64 {
    copy_accuracy_pool(model, n_cases, seed, &ExecPool::sequential())
}

/// [`copy_accuracy`] with the case forwards striped across `pool`.
pub fn copy_accuracy_pool(
    model: &Transformer,
    n_cases: usize,
    seed: u64,
    pool: &ExecPool,
) -> f64 {
    let mut rng = Rng::new(seed);
    let mut correct = 0usize;
    let alphabet: Vec<u8> = (b'a'..=b'z').collect();
    for _ in 0..n_cases {
        let len = 8 + rng.below(8);
        let s: Vec<u8> = (0..len).map(|_| alphabet[rng.below(26)]).collect();
        let j = 2 + rng.below(len - 2);
        let mut prompt: Vec<u16> = Vec::new();
        prompt.push(b'|' as u16);
        prompt.extend(s.iter().map(|&b| b as u16));
        prompt.push(b'|' as u16);
        prompt.extend(s[..j].iter().map(|&b| b as u16));
        let logits = model.forward_batch_with(&prompt, pool);
        let pred = argmax(logits.row(prompt.len() - 1));
        if pred == s[j] as usize {
            correct += 1;
        }
    }
    correct as f64 / n_cases as f64
}

/// Multiple-choice bracket matching: rank the correct closer above distractors.
pub fn bracket_accuracy(model: &Transformer, n_cases: usize, seed: u64) -> f64 {
    bracket_accuracy_pool(model, n_cases, seed, &ExecPool::sequential())
}

/// [`bracket_accuracy`] with the case forwards striped across `pool`.
pub fn bracket_accuracy_pool(
    model: &Transformer,
    n_cases: usize,
    seed: u64,
    pool: &ExecPool,
) -> f64 {
    let mut rng = Rng::new(seed);
    let pairs = [(b'(', b')'), (b'[', b']'), (b'{', b'}')];
    let mut correct = 0usize;
    for _ in 0..n_cases {
        // Build a nested prefix and track the open stack.
        let depth = 2 + rng.below(4);
        let mut prompt: Vec<u16> = Vec::new();
        let mut stack = Vec::new();
        for _ in 0..depth {
            let p = pairs[rng.below(3)];
            prompt.push(p.0 as u16);
            stack.push(p.1);
            // Occasionally add filler content.
            if rng.below(2) == 0 {
                prompt.push(b'x' as u16);
            }
        }
        // Close one level so the pattern "open...close" is visible, then ask.
        let expected = *stack.last().unwrap();
        let logits = model.forward_batch_with(&prompt, pool);
        let row = logits.row(prompt.len() - 1);
        let scores: Vec<f32> = pairs.iter().map(|p| row[p.1 as usize]).collect();
        let choice = pairs[argmax(&scores)].1;
        if choice == expected {
            correct += 1;
        }
    }
    correct as f64 / n_cases as f64
}

/// Run the whole suite.
pub fn zeroshot_suite(
    model: &Transformer,
    holdout: &[u8],
    n_cases: usize,
    seed: u64,
) -> ZeroshotReport {
    zeroshot_suite_pool(model, holdout, n_cases, seed, &ExecPool::sequential())
}

/// [`zeroshot_suite`] with every task's forwards striped across `pool` —
/// results are bit-identical at any worker count.
pub fn zeroshot_suite_pool(
    model: &Transformer,
    holdout: &[u8],
    n_cases: usize,
    seed: u64,
    pool: &ExecPool,
) -> ZeroshotReport {
    ZeroshotReport {
        next_byte_acc: next_byte_accuracy_pool(model, holdout, n_cases, pool),
        copy_acc: copy_accuracy_pool(model, n_cases, seed, pool),
        bracket_acc: bracket_accuracy_pool(model, n_cases, seed ^ 0xB0, pool),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Transformer, WeightStore};

    fn tiny() -> Transformer {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 64;
        Transformer::from_store(&WeightStore::random(&cfg, 21))
    }

    #[test]
    fn suite_runs_and_bounds() {
        let model = tiny();
        let holdout: Vec<u8> = (0..4096).map(|i| (i * 31 % 251) as u8).collect();
        let rep = zeroshot_suite(&model, &holdout, 8, 1);
        for acc in [rep.next_byte_acc, rep.copy_acc, rep.bracket_acc] {
            assert!((0.0..=1.0).contains(&acc));
        }
        assert!((0.0..=1.0).contains(&rep.mean()));
    }

    #[test]
    fn random_model_bracket_near_chance() {
        // 3-way multiple choice: untrained model ≈ 1/3.
        let model = tiny();
        let acc = bracket_accuracy(&model, 60, 7);
        assert!(acc < 0.75, "untrained should not ace bracket matching: {acc}");
    }

    #[test]
    fn deterministic_given_seed() {
        let model = tiny();
        let a = copy_accuracy(&model, 10, 3);
        let b = copy_accuracy(&model, 10, 3);
        assert_eq!(a, b);
    }
}
