//! Held-out perplexity, computed the OPTQ way (paper §A.3.4): the corpus is split
//! into non-overlapping max_seq windows; loss is averaged over every next-token
//! prediction.

use crate::model::transformer::Transformer;
use crate::util::matrix::Matrix;
use crate::util::threadpool::ExecPool;

#[derive(Clone, Copy, Debug)]
pub struct PerplexityReport {
    pub nll: f64,
    pub ppl: f64,
    pub tokens: usize,
    pub seconds: f64,
}

/// Log-softmax cross-entropy of row `r` of `logits` against `target`.
fn nll_row(logits: &Matrix, r: usize, target: u16) -> f64 {
    let row = logits.row(r);
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let lse = max
        + row
            .iter()
            .map(|&v| ((v as f64) - max).exp())
            .sum::<f64>()
            .ln();
    lse - row[target as usize] as f64
}

/// Evaluate perplexity of `model` on `data` (byte tokens), using at most
/// `max_tokens` tokens in non-overlapping `max_seq` windows.
pub fn perplexity(model: &Transformer, data: &[u8], max_tokens: usize) -> PerplexityReport {
    perplexity_pool(model, data, max_tokens, &ExecPool::sequential())
}

/// [`perplexity`] with the per-window forward GEMMs striped across `pool`
/// (bit-identical at any worker count).
pub fn perplexity_pool(
    model: &Transformer,
    data: &[u8],
    max_tokens: usize,
    pool: &ExecPool,
) -> PerplexityReport {
    let timer = crate::util::Timer::start();
    let seq = model.cfg.max_seq;
    let mut nll = 0.0f64;
    let mut count = 0usize;
    let mut off = 0usize;
    while off + seq + 1 <= data.len() && count < max_tokens {
        let tokens: Vec<u16> = data[off..off + seq + 1].iter().map(|&b| b as u16).collect();
        let logits = model.forward_batch_with(&tokens[..seq], pool);
        for t in 0..seq {
            nll += nll_row(&logits, t, tokens[t + 1]);
            count += 1;
            if count >= max_tokens {
                break;
            }
        }
        off += seq;
    }
    assert!(count > 0, "not enough data for even one window");
    let mean = nll / count as f64;
    PerplexityReport { nll: mean, ppl: mean.exp(), tokens: count, seconds: timer.secs() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Transformer, WeightStore};

    fn tiny() -> Transformer {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 16;
        Transformer::from_store(&WeightStore::random(&cfg, 9))
    }

    #[test]
    fn random_model_near_uniform() {
        // An untrained model should score close to -ln(1/256) per byte.
        let model = tiny();
        let data: Vec<u8> = (0..2000).map(|i| (i * 37 % 251) as u8).collect();
        let rep = perplexity(&model, &data, 256);
        assert!(rep.tokens == 256);
        assert!((rep.nll - (256f64).ln()).abs() < 1.0, "nll {}", rep.nll);
        assert!(rep.ppl > 50.0 && rep.ppl < 1000.0);
    }

    #[test]
    fn deterministic() {
        let model = tiny();
        let data: Vec<u8> = (0..1000).map(|i| (i % 256) as u8).collect();
        let a = perplexity(&model, &data, 128);
        let b = perplexity(&model, &data, 128);
        assert_eq!(a.nll, b.nll);
    }

    #[test]
    #[should_panic(expected = "not enough data")]
    fn too_little_data_panics() {
        let model = tiny();
        perplexity(&model, &[1, 2, 3], 100);
    }

    #[test]
    fn nll_row_matches_manual() {
        let logits = Matrix::from_vec(1, 4, vec![0.0, 1.0, 2.0, 3.0]);
        let z: f64 = (0..4).map(|i| (i as f64 - 3.0).exp()).sum::<f64>().ln() + 3.0;
        let expect = z - 1.0;
        assert!((nll_row(&logits, 0, 1) - expect).abs() < 1e-9);
    }
}
