//! Benchmark harness (criterion substitute): wall-clock measurement with warmup
//! and repetitions, plus paper-style table rendering shared by every
//! `rust/benches/*` target and `EXPERIMENTS.md`.

use crate::util::Timer;

/// Time `f` with warmup; returns (mean_secs, std_secs) over `reps` runs.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
        / samples.len() as f64;
    (mean, var.sqrt())
}

/// Run until at least `min_secs` elapsed, returning per-iteration seconds.
pub fn time_throughput<F: FnMut()>(min_secs: f64, mut f: F) -> f64 {
    // Warmup.
    f();
    let t = Timer::start();
    let mut iters = 0usize;
    while t.secs() < min_secs {
        f();
        iters += 1;
    }
    t.secs() / iters.max(1) as f64
}

/// A paper-style results table that renders as aligned text + markdown.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned markdown table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{:-<width$}|", "", width = width + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out
    }

    /// Print to stdout and append to `bench_results/<file>.md` for EXPERIMENTS.md.
    pub fn emit(&self, file: &str) {
        let text = self.render();
        println!("{text}");
        let dir = std::path::Path::new("bench_results");
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(dir.join(file), &text);
    }
}

/// Format helpers.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Shared bench environment: consistent seeds + sample-count overrides via env.
pub fn samples(default: usize) -> usize {
    std::env::var("QTIP_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Test", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("### Test"));
        assert!(r.contains("| a  | bb |") || r.contains("| a | bb |"));
        assert!(r.contains("| 1"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_checks_arity() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn time_fn_returns_positive() {
        let (mean, _) = time_fn(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(mean >= 0.0);
    }

    #[test]
    fn samples_env_default() {
        assert_eq!(samples(7), 7);
    }
}
