//! Benchmark harness (criterion substitute): wall-clock measurement with warmup
//! and repetitions, paper-style table rendering shared by every
//! `rust/benches/*` target and `EXPERIMENTS.md`, and the machine-readable
//! perf-trajectory writer ([`BenchJson`] → `BENCH_<name>.json` at the repo
//! root) so successive PRs can be compared mechanically.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::Timer;

/// Time `f` with warmup; returns (mean_secs, std_secs) over `reps` runs.
pub fn time_fn<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> (f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
        / samples.len() as f64;
    (mean, var.sqrt())
}

/// Run until at least `min_secs` elapsed, returning per-iteration seconds.
pub fn time_throughput<F: FnMut()>(min_secs: f64, mut f: F) -> f64 {
    // Warmup.
    f();
    let t = Timer::start();
    let mut iters = 0usize;
    while t.secs() < min_secs {
        f();
        iters += 1;
    }
    t.secs() / iters.max(1) as f64
}

/// A paper-style results table that renders as aligned text + markdown.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned markdown table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = format!("\n### {}\n\n", self.title);
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{:-<width$}|", "", width = width + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out
    }

    /// Print to stdout and append to `bench_results/<file>.md` for EXPERIMENTS.md.
    pub fn emit(&self, file: &str) {
        let text = self.render();
        println!("{text}");
        let dir = std::path::Path::new("bench_results");
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(dir.join(file), &text);
    }
}

/// Format helpers.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn f4(x: f64) -> String {
    format!("{x:.4}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Shared bench environment: consistent seeds + sample-count overrides via env.
pub fn samples(default: usize) -> usize {
    std::env::var("QTIP_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Perf-trajectory schema version (`BENCH_*.json`); bump on layout changes.
pub const BENCH_JSON_SCHEMA_VERSION: usize = 1;

/// Whether machine-readable bench emission is on: `--json` anywhere in argv
/// (benches are `harness = false` binaries, so flags pass straight through
/// `cargo bench --bench X -- --json`) or `QTIP_BENCH_JSON=1`.
pub fn json_enabled() -> bool {
    std::env::args().any(|a| a == "--json")
        || std::env::var("QTIP_BENCH_JSON").map(|v| v == "1").unwrap_or(false)
}

/// Short git revision stamped into the perf trajectory (best-effort:
/// "unknown" when git or the repo is unavailable).
pub fn git_rev() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short=12", "HEAD"])
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn repo_root() -> std::path::PathBuf {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).to_path_buf()
}

/// The perf trajectory: one machine-readable record per bench run, written as
/// `BENCH_<name>.json` at the repo root when [`json_enabled`]. Schema (v1):
///
/// ```json
/// {"bench": "microbench", "schema_version": 1, "git_rev": "abc123",
///  "config": {"samples": 1, "kernel": "lanes", "threads": 2},
///  "rows": [{"params": {"code": "3inst", "kernel": "scalar"},
///            "metric": "ns_per_weight", "value": 1.9}, ...]}
/// ```
///
/// `params` values are strings (mechanical diffing beats clever typing);
/// `value` is the single scalar measurement named by `metric`.
pub struct BenchJson {
    bench: String,
    config: BTreeMap<String, Json>,
    rows: Vec<Json>,
}

impl BenchJson {
    pub fn new(bench: &str) -> BenchJson {
        let mut config = BTreeMap::new();
        config.insert("samples".to_string(), Json::Num(samples(1) as f64));
        config.insert(
            "kernel".to_string(),
            Json::Str(crate::quant::kernel::selected_resolved().name().to_string()),
        );
        config.insert(
            "threads".to_string(),
            Json::Num(crate::util::threadpool::default_workers() as f64),
        );
        BenchJson { bench: bench.to_string(), config, rows: Vec::new() }
    }

    /// Record one measurement row.
    pub fn row(&mut self, params: &[(&str, String)], metric: &str, value: f64) {
        let p: BTreeMap<String, Json> =
            params.iter().map(|(k, v)| (k.to_string(), Json::Str(v.clone()))).collect();
        self.rows.push(Json::obj(vec![
            ("params", Json::Obj(p)),
            ("metric", Json::Str(metric.to_string())),
            ("value", Json::Num(value)),
        ]));
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("bench", Json::Str(self.bench.clone())),
            ("schema_version", Json::Num(BENCH_JSON_SCHEMA_VERSION as f64)),
            ("git_rev", Json::Str(git_rev())),
            ("config", Json::Obj(self.config.clone())),
            ("rows", Json::Arr(self.rows.clone())),
        ])
    }

    /// Write `BENCH_<name>.json` at the repo root when JSON emission is
    /// enabled ([`json_enabled`]); silently a no-op otherwise so benches can
    /// call it unconditionally.
    pub fn emit(&self) {
        if !json_enabled() {
            return;
        }
        let path = repo_root().join(format!("BENCH_{}.json", self.bench));
        match std::fs::write(&path, self.to_json().to_string()) {
            Ok(()) => println!("[bench-json] wrote {path:?} ({} rows)", self.rows.len()),
            Err(e) => eprintln!("[bench-json] failed to write {path:?}: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Test", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("### Test"));
        assert!(r.contains("| a  | bb |") || r.contains("| a | bb |"));
        assert!(r.contains("| 1"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_checks_arity() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn time_fn_returns_positive() {
        let (mean, _) = time_fn(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(mean >= 0.0);
    }

    #[test]
    fn samples_env_default() {
        assert_eq!(samples(7), 7);
    }

    #[test]
    fn bench_json_schema_roundtrips() {
        // The CI schema checker (scripts/check_bench_json.py) and this test
        // pin the same contract: top-level bench/schema_version/git_rev/
        // config/rows, rows of {params, metric, value}.
        let mut bj = BenchJson::new("unit");
        let params = [("code", "3inst".to_string()), ("kernel", "lanes".to_string())];
        bj.row(&params, "tok_per_sec", 42.5);
        bj.row(&[("d", "1024".to_string())], "ns_per_weight", 1.25);
        let text = bj.to_json().to_string();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.req_str("bench"), "unit");
        assert_eq!(j.req_usize("schema_version"), BENCH_JSON_SCHEMA_VERSION);
        assert!(!j.req_str("git_rev").is_empty());
        assert!(j.get("config").and_then(|c| c.get("samples")).is_some());
        let rows = j.get("rows").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(rows.len(), 2);
        let code = rows[0].get("params").and_then(|p| p.get("code"));
        assert_eq!(code.and_then(|c| c.as_str()), Some("3inst"));
        assert_eq!(rows[0].req_str("metric"), "tok_per_sec");
        assert_eq!(rows[0].req_f64("value"), 42.5);
    }
}
