//! `qtip` — the coordinator CLI.
//!
//! Subcommands:
//!   info                         environment + artifact status
//!   quantize --model nano --k 2  quantize a model, report per-layer metrics
//!   eval     --model nano --k 2  perplexity + zeroshot before/after quantization
//!   serve    --model nano        quantize then serve demo requests (batched);
//!                                add --tcp 127.0.0.1:7171 for the network front-end
//!   generate --prompt "..."      one-shot generation from a quantized model

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};
use qtip::cli::Args;
use qtip::coordinator::{quantize_model_qtip, GenRequest, ServerConfig, ServerHandle};
use qtip::eval::{perplexity, zeroshot_suite};
use qtip::hessian::collect_hessians;
use qtip::model::{load_corpus, split_corpus, ModelConfig, Transformer, WeightStore};
use qtip::quant::QtipConfig;
use qtip::util::threadpool::default_workers;

fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("QTIP_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn load_model(name: &str) -> Result<Transformer> {
    let dir = artifacts_dir();
    match WeightStore::load(&dir, name) {
        Ok(ws) => {
            eprintln!("[qtip] loaded trained '{name}' from {dir:?}");
            Ok(Transformer::from_store(&ws))
        }
        Err(e) => {
            eprintln!("[qtip] no trained weights for '{name}' ({e}); using random init");
            let cfg = ModelConfig::by_name(name);
            Ok(Transformer::from_store(&WeightStore::random(&cfg, 0x5EED)))
        }
    }
}

fn calibration_sequences(model: &Transformer, n: usize) -> Vec<Vec<u16>> {
    let dir = artifacts_dir();
    let holdout = dir.join("corpus_holdout.bin");
    let corpus = if holdout.exists() {
        std::fs::read(&holdout).unwrap()
    } else {
        load_corpus(&[Path::new(env!("CARGO_MANIFEST_DIR"))], 1 << 20)
    };
    let (train, _) = split_corpus(&corpus, 0.5);
    let seq = model.cfg.max_seq.min(128);
    train
        .chunks(seq)
        .take(n)
        .map(|c| c.iter().map(|&b| b as u16).collect())
        .collect()
}

fn qtip_cfg_from_args(args: &Args) -> QtipConfig {
    QtipConfig {
        l: args.get_u32("l", 12),
        k: args.get_u32("k", 2),
        v: args.get_u32("v", 1),
        tx: args.get_usize("tx", 16),
        ty: args.get_usize("ty", 16),
        code: args.get_or("code", "3inst").to_string(),
        seed: args.get_u64("seed", 0x5171_50),
    }
}

fn cmd_info() -> Result<()> {
    println!("qtip — Quantization with Trellises and Incoherence Processing");
    println!("artifacts dir: {:?}", artifacts_dir());
    for name in ["micro", "nano", "small"] {
        let ok = artifacts_dir().join(format!("model_{name}.json")).exists();
        println!(
            "  model_{name}: {}",
            if ok { "trained weights present" } else { "absent (random init fallback)" }
        );
    }
    match qtip::runtime::Registry::open(&artifacts_dir()) {
        Ok(reg) => {
            println!("  AOT artifacts: {}", reg.artifacts.len());
            for a in &reg.artifacts {
                println!("    - {} ({})", a.name, a.kind);
            }
            let rt = qtip::runtime::PjrtRuntime::cpu()?;
            println!("  PJRT platform: {}", rt.platform());
        }
        Err(e) => println!("  AOT artifacts: unavailable ({e})"),
    }
    println!("  workers: {}", default_workers());
    Ok(())
}

fn quantize_inner(args: &Args) -> Result<(Transformer, qtip::coordinator::QuantizeReport)> {
    let model_name = args.get_or("model", "nano");
    let mut model = load_model(model_name)?;
    let n_calib = args.get_usize("calib-seqs", 24);
    eprintln!("[qtip] calibrating Hessians on {n_calib} sequences...");
    let seqs = calibration_sequences(&model, n_calib);
    let hessians = collect_hessians(&model, &seqs);
    let cfg = qtip_cfg_from_args(args);
    eprintln!(
        "[qtip] quantizing with code={} L={} k={} V={} T={}x{}",
        cfg.code, cfg.l, cfg.k, cfg.v, cfg.tx, cfg.ty
    );
    let report = quantize_model_qtip(&mut model, &hessians, &cfg, default_workers(), |layer| {
        eprintln!(
            "  {}: {}x{} proxy {:.5} mse {:.5} ({:.1}s)",
            layer.name,
            layer.rows,
            layer.cols,
            layer.metrics.relative_proxy,
            layer.metrics.mse,
            layer.metrics.seconds
        );
    });
    Ok((model, report))
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let (_, report) = quantize_inner(args)?;
    println!(
        "quantized {} layers in {:.1}s: {} -> {} bytes ({:.2}x), mean rel. proxy {:.5}",
        report.layers.len(),
        report.seconds,
        report.bytes_before,
        report.bytes_after,
        report.compression_ratio(),
        report.mean_relative_proxy()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model_name = args.get_or("model", "nano");
    let max_tokens = args.get_usize("tokens", 2048);
    let holdout = std::fs::read(artifacts_dir().join("corpus_holdout.bin"))
        .context("corpus_holdout.bin (run `make artifacts`)")?;

    let dense = load_model(model_name)?;
    let rep = perplexity(&dense, &holdout, max_tokens);
    let zs = zeroshot_suite(&dense, &holdout, 24, 7);
    println!(
        "fp32      : ppl {:.3} (nll {:.4}, {} tok) | next-byte {:.3} copy {:.3} bracket {:.3}",
        rep.ppl, rep.nll, rep.tokens, zs.next_byte_acc, zs.copy_acc, zs.bracket_acc
    );

    let (mut qmodel, report) = quantize_inner(args)?;
    qmodel.ensure_caches();
    let qrep = perplexity(&qmodel, &holdout, max_tokens);
    let qzs = zeroshot_suite(&qmodel, &holdout, 24, 7);
    println!(
        "qtip-{}bit : ppl {:.3} (nll {:.4}) | next-byte {:.3} copy {:.3} bracket {:.3} | {:.2}x smaller",
        args.get_u32("k", 2),
        qrep.ppl,
        qrep.nll,
        qzs.next_byte_acc,
        qzs.copy_acc,
        qzs.bracket_acc,
        report.compression_ratio(),
    );
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let mut model = if args.has_flag("fp32") {
        load_model(args.get_or("model", "nano"))?
    } else {
        quantize_inner(args)?.0
    };
    model.ensure_caches();
    let server = ServerHandle::spawn(Arc::new(model), ServerConfig::default());
    let req = GenRequest {
        id: 0,
        prompt: args.get_or("prompt", "fn main() {").to_string(),
        max_new_tokens: args.get_usize("max-new", 128),
        temperature: args.get_f32("temp", 0.7),
        top_k: args.get_usize("top-k", 40),
        seed: args.get_u64("seed", 1),
    };
    let resp = server.submit(req).recv()?;
    if let Some(err) = resp.error {
        anyhow::bail!("request rejected: {err}");
    }
    println!("--- generation ({:.1} tok/s) ---", resp.decode_tok_per_sec);
    println!("{}", resp.text);
    server.shutdown();
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (mut model, report) = quantize_inner(args)?;
    model.ensure_caches();
    // Network mode: expose the batcher over newline-JSON TCP and block.
    if let Some(addr) = args.get("tcp") {
        println!(
            "serving quantized model ({:.2}x compression) over TCP...",
            report.compression_ratio()
        );
        let server = std::sync::Arc::new(ServerHandle::spawn(
            Arc::new(model),
            ServerConfig {
                max_batch: args.get_usize("max-batch", 4),
                kv_budget_bytes: args.get_usize("kv-budget-mb", 256) << 20,
            },
        ));
        let fe = qtip::coordinator::TcpFrontend::spawn(server, addr)?;
        println!("listening on {} (Ctrl-C to stop)", fe.addr);
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    let n = args.get_usize("requests", 6);
    println!(
        "serving quantized model ({:.2}x compression); submitting {n} demo requests",
        report.compression_ratio(),
    );
    let server = ServerHandle::spawn(
        Arc::new(model),
        ServerConfig {
            max_batch: args.get_usize("max-batch", 4),
            kv_budget_bytes: args.get_usize("kv-budget-mb", 256) << 20,
        },
    );
    let prompts = ["fn main", "pub struct", "import ", "## ", "let mut ", "def "];
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            server.submit(GenRequest {
                id: i as u64,
                prompt: prompts[i % prompts.len()].to_string(),
                max_new_tokens: args.get_usize("max-new", 48),
                temperature: 0.7,
                top_k: 40,
                seed: i as u64,
            })
        })
        .collect();
    for rx in rxs {
        let r = rx.recv()?;
        if let Some(err) = r.error {
            println!("[req {}] rejected: {err}", r.id);
            continue;
        }
        println!(
            "[req {}] ttft {:.1} ms, {:.1} tok/s: {:?}",
            r.id,
            r.ttft * 1e3,
            r.decode_tok_per_sec,
            r.text.chars().take(40).collect::<String>()
        );
    }
    let stats = server.shutdown();
    println!(
        "served {} requests, {} tokens, aggregate {:.1} tok/s (peak batch {})",
        stats.completed,
        stats.total_generated_tokens,
        stats.throughput_tok_per_sec(),
        stats.peak_batch
    );
    Ok(())
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "info".to_string() } else { argv.remove(0) };
    let args = Args::parse(argv);
    match cmd.as_str() {
        "info" => cmd_info(),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        other => {
            eprintln!(
                "unknown command '{other}'\nusage: qtip <info|quantize|eval|generate|serve> [--model nano] [--k 2] [--l 12] [--code 3inst] ..."
            );
            std::process::exit(2);
        }
    }
}
