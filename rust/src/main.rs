//! `qtip` — the coordinator CLI.
//!
//! Subcommands:
//!   info                         environment + artifact status, including
//!                                saved quantized artifacts and the registered
//!                                quantization methods
//!   quantize --model nano --k 2  quantize a model, report per-layer metrics;
//!                                --save <name> persists the packed trellis
//!                                artifact for cold-start serving
//!   eval     --model nano --k 2  perplexity + zeroshot before/after
//!                                quantization — measured only on the eval
//!                                half of the corpus, disjoint from the
//!                                calibration half; --artifact <name> reuses
//!                                a saved quantized artifact
//!   serve    --model nano        quantize then serve demo requests (batched);
//!                                --artifact <name> cold-starts from a saved
//!                                artifact (skips calibration/quantization);
//!                                repeat --artifact to serve several models
//!                                behind one batcher, routed on the request's
//!                                "model" field (lane names = artifact names);
//!                                --tcp 127.0.0.1:7171 for the newline-JSON
//!                                front-end, --http 127.0.0.1:8080 for the
//!                                HTTP/SSE front-end — both may run at once
//!                                (Ctrl-C drains, then prints stats)
//!   generate --prompt "..."      one-shot generation from a quantized model
//!                                (--artifact <name> supported)
//!
//! `serve` and `generate` refuse to run on random-init weights unless
//! --allow-random is passed; `quantize`/`eval` keep the silent fallback so CI
//! can exercise the pipeline without trained artifacts.
//!
//! All quantizing/serving subcommands take `--kernel auto|scalar|lanes` to pin
//! the decode-matvec kernel family (precedence `--kernel` > `QTIP_KERNEL` >
//! auto); `info` prints the resolved selection. Scalar and lane kernels are
//! bit-identical — the flag trades speed, never output.
//!
//! `serve` also takes the overload knobs `--max-queue N` (bound each lane's
//! admission queue; overflow is shed immediately with a `queue_full`
//! rejection instead of waiting, 0 = unbounded) and `--default-deadline MS`
//! (deadline applied to requests that do not carry their own `deadline_ms`;
//! expired requests fail with `deadline_exceeded` and free their KV blocks
//! the same round, 0 = none).
//!
//! `serve` additionally takes `--kv-layout auto|contig|paged` (auto → paged:
//! the block-arena continuous batcher; contig keeps the sequence-granular
//! reference scheduler), `--kv-block N` for the arena geometry (precedence
//! `--kv-block` > `QTIP_KV_BLOCK` > the artifact manifest's recorded
//! geometry > 32), and `--no-prefix-share` to disable the paged scheduler's
//! copy-on-write prefix sharing (on by default). Every combination emits
//! bit-identical tokens — the flags trade admission capacity and prefill
//! work, never output.
//!
//! Prompt ingestion on the paged layout runs in GEMM chunks: `--prefill-chunk
//! N` bounds the positions decoded per weight pass (precedence
//! `--prefill-chunk` > `QTIP_PREFILL_CHUNK` > the artifact manifest > 32; the
//! contig layout always ingests token-at-a-time), and `--round-budget N` caps
//! the tokens a lane decodes per round — active decode sequences get their
//! token first, the remainder is split across prefilling sequences in
//! admission order (0 = unlimited). Chunked and token-at-a-time prefill are
//! bit-identical.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};
use qtip::cli::Args;
use qtip::coordinator::{
    quantize_model_qtip, GenRequest, QuantizeReport, ServerConfig, ServerHandle, ServerStats,
};
use qtip::eval::{perplexity_pool, zeroshot_suite_pool};
use qtip::hessian::collect_hessians;
use qtip::model::{
    calibration_split, eval_split, load_corpus, resolve_kv_block, resolve_prefill_chunk,
    resolve_round_budget, KvLayout, ModelConfig, Transformer, WeightStore,
};
use qtip::quant::{kernel, KernelKind, QtipConfig};
use qtip::util::threadpool::{resolve_workers, ExecPool};
use qtip::util::Timer;

/// Build the process-wide execution pool from `--threads N` (0 = auto;
/// precedence: --threads > QTIP_THREADS env > available parallelism).
fn make_pool(args: &Args) -> ExecPool {
    ExecPool::new(args.get_usize("threads", 0))
}

fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("QTIP_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn load_model(name: &str, allow_random: bool) -> Result<Transformer> {
    let dir = artifacts_dir();
    match WeightStore::load(&dir, name) {
        Ok(ws) => {
            eprintln!("[qtip] loaded trained '{name}' from {dir:?}");
            Ok(Transformer::from_store(&ws))
        }
        Err(e) if allow_random => {
            eprintln!("[qtip] no trained weights for '{name}' ({e}); using random init");
            let cfg = ModelConfig::by_name(name);
            Ok(Transformer::from_store(&WeightStore::random(&cfg, 0x5EED)))
        }
        Err(e) => anyhow::bail!(
            "no trained weights for '{name}' in {dir:?} ({e}); refusing to serve random-init \
             garbage. Run `make artifacts` to train them, pass --artifact <name> to serve a \
             saved quantized artifact, or pass --allow-random to override"
        ),
    }
}

fn calibration_sequences(model: &Transformer, n: usize) -> Vec<Vec<u16>> {
    let dir = artifacts_dir();
    let holdout = dir.join("corpus_holdout.bin");
    let corpus = if holdout.exists() {
        std::fs::read(&holdout).unwrap()
    } else {
        load_corpus(&[Path::new(env!("CARGO_MANIFEST_DIR"))], 1 << 20)
    };
    // First half only: `cmd_eval` measures perplexity on the disjoint second
    // half (`eval_split`), so calibration must never touch those bytes.
    let train = calibration_split(&corpus);
    let seq = model.cfg.max_seq.min(128);
    train
        .chunks(seq)
        .take(n)
        .map(|c| c.iter().map(|&b| b as u16).collect())
        .collect()
}

fn qtip_cfg_from_args(args: &Args) -> QtipConfig {
    QtipConfig {
        l: args.get_u32("l", 12),
        k: args.get_u32("k", 2),
        v: args.get_u32("v", 1),
        tx: args.get_usize("tx", 16),
        ty: args.get_usize("ty", 16),
        code: args.get_or("code", "3inst").to_string(),
        seed: args.get_u64("seed", 0x5171_50),
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("qtip — Quantization with Trellises and Incoherence Processing");
    println!("artifacts dir: {:?}", artifacts_dir());
    for name in ["micro", "nano", "small"] {
        let ok = artifacts_dir().join(format!("model_{name}.json")).exists();
        println!(
            "  model_{name}: {}",
            if ok { "trained weights present" } else { "absent (random init fallback)" }
        );
    }
    println!("  quant methods (registry):");
    for m in qtip::quant::registry::all() {
        let info = m.info();
        let table = if info.default_table_bytes == 0 {
            "computed (no LUT)".to_string()
        } else {
            format!("{} LUT bytes", info.default_table_bytes)
        };
        println!(
            "    - {}: {} | V {:?} | {}-{} bits/weight | {}",
            info.name, info.summary, info.v_options, info.bits_min, info.bits_max, table
        );
    }
    let quants = qtip::io::list_quantized_artifacts(&artifacts_dir());
    if quants.is_empty() {
        println!("  quantized artifacts: none (save one with `qtip quantize --save <name>`)");
    } else {
        println!("  quantized artifacts: {}", quants.len());
        for q in &quants {
            println!(
                "    - {}: model {} | method {} | {} | {} layers quantized | {} blob bytes",
                q.name, q.config.name, q.method, q.quant_desc, q.quantized_layers, q.blob_bytes
            );
        }
    }
    match qtip::runtime::Registry::open(&artifacts_dir()) {
        Ok(reg) => {
            println!("  AOT artifacts: {}", reg.artifacts.len());
            for a in &reg.artifacts {
                println!("    - {} ({})", a.name, a.kind);
            }
            let rt = qtip::runtime::PjrtRuntime::cpu()?;
            println!("  PJRT platform: {}", rt.platform());
        }
        Err(e) => println!("  AOT artifacts: unavailable ({e})"),
    }
    let width = resolve_workers(args.get_usize("threads", 0));
    println!(
        "  workers: {width} resolved ({} worker threads + the submitting thread when a \
         pool is built; override with --threads N or QTIP_THREADS, 0 = auto)",
        width - 1
    );
    let kern = kernel::selected();
    println!(
        "  decode kernel: {} (resolves to '{}'; precedence --kernel > QTIP_KERNEL > auto; \
         scalar and lane kernels are bit-identical)",
        kern.name(),
        kern.resolve().name()
    );
    println!(
        "  intra-op: decode matvecs, GEMMs, per-layer quantize jobs, and artifact \
         loads all stripe across this pool"
    );
    // Propagate a bad --kv-layout spelling instead of silently reporting the
    // default — `info` is where users check their flags before a long serve.
    let layout = kv_layout_from_args(args)?;
    println!(
        "  kv layout: {} (resolves to '{}'; --kv-layout auto|contig|paged; both layouts \
         emit bit-identical tokens)",
        layout.name(),
        layout.resolve().name()
    );
    println!(
        "  kv block: {} positions (precedence --kv-block > QTIP_KV_BLOCK > artifact \
         manifest > 32); the serve arena leases blocks per sequence on demand",
        resolve_kv_block(args.get_usize("kv-block", 0), 0)
    );
    println!(
        "  prefill chunk: {} positions (precedence --prefill-chunk > QTIP_PREFILL_CHUNK > \
         artifact manifest > 32); paged-layout prompt ingestion decodes each weight tile \
         once per chunk, bit-identical to token-at-a-time",
        resolve_prefill_chunk(args.get_usize("prefill-chunk", 0), 0)
    );
    let budget = resolve_round_budget(args.get_usize("round-budget", 0));
    println!(
        "  round budget: {} (--round-budget > QTIP_ROUND_BUDGET; tokens per lane round, \
         decode steps first, remainder to prefill chunks; 0 = unlimited)",
        if budget == 0 { "unlimited".to_string() } else { budget.to_string() }
    );
    Ok(())
}

fn quantize_inner(args: &Args, allow_random: bool) -> Result<(Transformer, QuantizeReport)> {
    let model_name = args.get_or("model", "nano");
    let mut model = load_model(model_name, allow_random)?;
    let n_calib = args.get_usize("calib-seqs", 24);
    eprintln!("[qtip] calibrating Hessians on {n_calib} sequences...");
    let seqs = calibration_sequences(&model, n_calib);
    let hessians = collect_hessians(&model, &seqs);
    let cfg = qtip_cfg_from_args(args);
    let pool = make_pool(args);
    eprintln!(
        "[qtip] quantizing with code={} L={} k={} V={} T={}x{} on {} workers",
        cfg.code, cfg.l, cfg.k, cfg.v, cfg.tx, cfg.ty, pool.width()
    );
    let report = quantize_model_qtip(&mut model, &hessians, &cfg, &pool, |layer| {
        eprintln!(
            "  {}: {}x{} proxy {:.5} mse {:.5} ({:.1}s)",
            layer.name,
            layer.rows,
            layer.cols,
            layer.metrics.relative_proxy,
            layer.metrics.mse,
            layer.metrics.seconds
        );
    })?;
    Ok((model, report))
}

/// Acquire a quantized model: cold-start from a saved artifact when
/// `--artifact <name>` is given (no calibration, no quantization), otherwise
/// run the full quantization pipeline. The third element is the artifact
/// manifest's recorded `(kv_block, prefill_chunk)` geometry ((0, 0) when
/// quantizing fresh) — the lowest-precedence defaults for `serve`'s arena
/// shape and chunked prefill.
fn quantized_model(
    args: &Args,
    allow_random: bool,
) -> Result<(Transformer, QuantizeReport, (usize, usize))> {
    if let Some(name) = args.get("artifact") {
        let timer = Timer::start();
        let pool = make_pool(args);
        let (model, report, info) =
            qtip::io::load_quantized_model_pool(&artifacts_dir(), name, &pool)?;
        eprintln!(
            "[qtip] cold-started from quantized artifact '{name}' ({}; {} blob bytes) in \
             {:.3}s — calibration and quantization skipped",
            info.quant_desc,
            info.blob_bytes,
            timer.secs()
        );
        Ok((model, report, (info.kv_block, info.prefill_chunk)))
    } else {
        let (model, report) = quantize_inner(args, allow_random)?;
        Ok((model, report, (0, 0)))
    }
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let (model, report) = quantize_inner(args, true)?;
    println!(
        "quantized {} layers in {:.1}s: {} -> {} bytes ({:.2}x), mean rel. proxy {:.5}",
        report.layers.len(),
        report.seconds,
        report.bytes_before,
        report.bytes_after,
        report.compression_ratio(),
        report.mean_relative_proxy()
    );
    if let Some(save_name) = args.get("save") {
        // Record the resolved serving geometry (CLI flag > env > default) in
        // the manifest so cold-started serves default to it.
        let kv_block = resolve_kv_block(args.get_usize("kv-block", 0), 0);
        let prefill_chunk = resolve_prefill_chunk(args.get_usize("prefill-chunk", 0), 0);
        let info = qtip::io::save_quantized_model_with_geometry(
            &artifacts_dir(),
            save_name,
            &model,
            &report,
            kv_block,
            prefill_chunk,
        )?;
        println!(
            "saved quantized artifact '{save_name}' -> {:?} ({} blob bytes, {} layers); \
             cold-start it with `qtip serve --artifact {save_name}`",
            info.manifest_path, info.blob_bytes, info.quantized_layers
        );
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let max_tokens = args.get_usize("tokens", 2048);
    let corpus = std::fs::read(artifacts_dir().join("corpus_holdout.bin"))
        .context("corpus_holdout.bin (run `make artifacts`)")?;
    // Perplexity/zeroshot run only on the second half of the corpus; Hessian
    // calibration (inside quantize_inner) draws only from the first half, so
    // the two byte ranges are disjoint by construction.
    let eval_bytes = eval_split(&corpus);

    // Acquire the quantized model first: with --artifact, the fp32 baseline
    // must come from the model the artifact was quantized from, not whatever
    // --model defaults to — otherwise the comparison is cross-model garbage.
    let (mut qmodel, report, _) = quantized_model(args, true)?;
    let dense_name = qmodel.cfg.name.clone();
    if let Some(explicit) = args.get("model") {
        if args.get("artifact").is_some() && explicit != dense_name {
            eprintln!(
                "[qtip] note: --model {explicit} ignored for the fp32 baseline; the \
                 artifact was quantized from model '{dense_name}'"
            );
        }
    }
    let pool = make_pool(args);
    let dense = load_model(&dense_name, true)?;
    let rep = perplexity_pool(&dense, eval_bytes, max_tokens, &pool);
    let zs = zeroshot_suite_pool(&dense, eval_bytes, 24, 7, &pool);
    println!(
        "fp32      : ppl {:.3} (nll {:.4}, {} tok) | next-byte {:.3} copy {:.3} bracket {:.3}",
        rep.ppl, rep.nll, rep.tokens, zs.next_byte_acc, zs.copy_acc, zs.bracket_acc
    );

    qmodel.ensure_caches();
    let qrep = perplexity_pool(&qmodel, eval_bytes, max_tokens, &pool);
    let qzs = zeroshot_suite_pool(&qmodel, eval_bytes, 24, 7, &pool);
    // Label with the bitrate the model was actually quantized at: with
    // --artifact the CLI --k flag may not match the saved artifact's k.
    let bits = report
        .layers
        .first()
        .map(|l| l.metrics.bits_per_weight)
        .unwrap_or_else(|| args.get_u32("k", 2) as f64);
    println!(
        "qtip-{:.0}bit : ppl {:.3} (nll {:.4}) | next-byte {:.3} copy {:.3} bracket {:.3} | {:.2}x smaller",
        bits,
        qrep.ppl,
        qrep.nll,
        qzs.next_byte_acc,
        qzs.copy_acc,
        qzs.bracket_acc,
        report.compression_ratio(),
    );
    Ok(())
}

fn cmd_generate(args: &Args) -> Result<()> {
    let (mut model, (artifact_kv_block, artifact_prefill_chunk)) = if args.has_flag("fp32") {
        (load_model(args.get_or("model", "nano"), args.has_flag("allow-random"))?, (0, 0))
    } else {
        let (m, _, geo) = quantized_model(args, args.has_flag("allow-random"))?;
        (m, geo)
    };
    model.ensure_caches();
    let server_cfg = ServerConfig {
        threads: args.get_usize("threads", 0),
        kv_layout: kv_layout_from_args(args)?,
        kv_block: resolve_kv_block(args.get_usize("kv-block", 0), artifact_kv_block),
        prefill_chunk: resolve_prefill_chunk(
            args.get_usize("prefill-chunk", 0),
            artifact_prefill_chunk,
        ),
        ..Default::default()
    };
    let server = ServerHandle::spawn(Arc::new(model), server_cfg);
    let req = GenRequest {
        id: 0,
        prompt: args.get_or("prompt", "fn main() {").to_string(),
        max_new_tokens: args.get_usize("max-new", 128),
        temperature: args.get_f32("temp", 0.7),
        top_k: args.get_usize("top-k", 40),
        seed: args.get_u64("seed", 1),
        model: String::new(),
        deadline_ms: 0,
    };
    let resp = server.submit(req).recv()?;
    if let Some(err) = resp.error {
        anyhow::bail!("request rejected: {err}");
    }
    println!("--- generation ({:.1} tok/s) ---", resp.decode_tok_per_sec);
    println!("{}", resp.text);
    server.shutdown();
    Ok(())
}

fn print_server_stats(stats: &ServerStats) {
    println!(
        "served {} requests, {} tokens, aggregate {:.1} tok/s (peak batch {}, {} workers, \
         {} kernel)",
        stats.completed,
        stats.total_generated_tokens,
        stats.throughput_tok_per_sec(),
        stats.peak_batch,
        stats.workers,
        stats.kernel
    );
    println!(
        "  scheduling: {} kv layout, peak active {}, queue high-water {}, {} evictions, \
         {} rejected, {} cancelled",
        stats.kv_layout,
        stats.peak_active,
        stats.queue_high_water,
        stats.evictions,
        stats.rejected,
        stats.cancelled
    );
    if stats.kv_blocks_total > 0 {
        println!(
            "  kv arena: {} blocks x {} positions, high-water {} blocks ({} B peak)",
            stats.kv_blocks_total,
            stats.kv_block_positions,
            stats.kv_blocks_high_water,
            stats.peak_kv_bytes
        );
        println!(
            "  prefix sharing: {} hits, {} blocks aliased, {} cow copies, {} stalls \
             instead of evictions",
            stats.prefix_hits,
            stats.blocks_shared,
            stats.cow_copies,
            stats.stalls_instead_of_evictions
        );
    }
    if stats.prefill_chunks > 0 {
        println!(
            "  chunked prefill: {} chunks ({} tokens GEMM-ingested), {} budget deferrals",
            stats.prefill_chunks, stats.prefill_tokens_chunked, stats.budget_deferrals
        );
    }
    // Overload lines only when something actually happened — the nominal
    // summary stays as short as it always was.
    if stats.shed_queue_full + stats.shed_slow_clients + stats.expired_queued
        + stats.expired_running
        > 0
    {
        println!(
            "  overload: {} shed (queue full), {} slow clients dropped, {} deadlines \
             expired queued, {} expired mid-decode",
            stats.shed_queue_full,
            stats.shed_slow_clients,
            stats.expired_queued,
            stats.expired_running
        );
    }
    if stats.lane_panics + stats.watchdog_stalls > 0 {
        println!(
            "  faults: {} lane panic(s) isolated, {} watchdog stall alarm(s)",
            stats.lane_panics, stats.watchdog_stalls
        );
    }
}

/// `--kv-layout auto|contig|paged` (default auto → paged).
fn kv_layout_from_args(args: &Args) -> Result<KvLayout> {
    match args.get("kv-layout") {
        Some(spec) => KvLayout::parse(spec).map_err(anyhow::Error::msg),
        None => Ok(KvLayout::Auto),
    }
}

/// Models to serve, as `(lane name, model)` pairs. A single `--artifact` (or
/// none) keeps the historical single-model path with lane name "default";
/// repeated `--artifact` flags cold-start each saved artifact as its own lane
/// named after the artifact, all behind the shared batcher.
fn serve_models(
    args: &Args,
) -> Result<(Vec<(String, Arc<Transformer>)>, QuantizeReport, (usize, usize))> {
    let artifacts = args.get_all("artifact");
    if artifacts.len() <= 1 {
        let (mut model, report, geometry) = quantized_model(args, args.has_flag("allow-random"))?;
        model.ensure_caches();
        return Ok((vec![("default".to_string(), Arc::new(model))], report, geometry));
    }
    let pool = make_pool(args);
    let mut models = Vec::new();
    let mut first_report = None;
    let mut kv_block = 0usize;
    let mut prefill_chunk = 0usize;
    for name in &artifacts {
        let (mut model, report, info) =
            qtip::io::load_quantized_model_pool(&artifacts_dir(), name, &pool)?;
        model.ensure_caches();
        eprintln!(
            "[qtip] lane '{name}': model {} quantized with {} ({} blob bytes)",
            info.config.name, info.quant_desc, info.blob_bytes
        );
        // First artifact's recorded geometry is the lowest-precedence default
        // (the lanes share one --kv-block / --prefill-chunk setting).
        if kv_block == 0 {
            kv_block = info.kv_block;
        }
        if prefill_chunk == 0 {
            prefill_chunk = info.prefill_chunk;
        }
        first_report.get_or_insert(report);
        models.push((name.to_string(), Arc::new(model)));
    }
    Ok((models, first_report.expect("at least two artifacts"), (kv_block, prefill_chunk)))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let (models, report, (artifact_kv_block, artifact_prefill_chunk)) = serve_models(args)?;
    let n_models = models.len();
    let server_cfg = ServerConfig {
        max_batch: args.get_usize("max-batch", 4),
        kv_budget_bytes: args.get_usize("kv-budget-mb", 256) << 20,
        threads: args.get_usize("threads", 0),
        kv_layout: kv_layout_from_args(args)?,
        kv_block: resolve_kv_block(args.get_usize("kv-block", 0), artifact_kv_block),
        // Chunked prefill geometry and the per-round token budget (decode
        // steps first, remainder to prefill chunks; 0 = unlimited).
        prefill_chunk: resolve_prefill_chunk(
            args.get_usize("prefill-chunk", 0),
            artifact_prefill_chunk,
        ),
        round_budget: resolve_round_budget(args.get_usize("round-budget", 0)),
        // Prefix sharing is on by default (bit-identical outputs either way);
        // --no-prefix-share keeps an A/B escape hatch for benchmarking.
        prefix_share: !args.has_flag("no-prefix-share"),
        // Overload posture: queue bound (0 = unbounded) and the fallback
        // deadline for requests that do not set their own `deadline_ms`.
        max_queue: args.get_usize("max-queue", 0),
        default_deadline_ms: args.get_u64("default-deadline", 0),
        ..Default::default()
    };
    // Network mode: expose the batcher over newline-JSON TCP and/or HTTP+SSE
    // until Ctrl-C, then close the frontends, drain in-flight requests, and
    // report stats.
    let (tcp_addr, http_addr) = (args.get("tcp"), args.get("http"));
    if tcp_addr.is_some() || http_addr.is_some() {
        println!(
            "serving {n_models} quantized model(s) ({:.2}x compression) over the network...",
            report.compression_ratio()
        );
        let server = Arc::new(ServerHandle::spawn_multi(models, server_cfg));
        let tcp_fe = tcp_addr
            .map(|addr| qtip::coordinator::TcpFrontend::spawn(server.clone(), addr))
            .transpose()?;
        let http_fe = http_addr
            .map(|addr| qtip::coordinator::HttpFrontend::spawn(server.clone(), addr))
            .transpose()?;
        if let Some(fe) = &tcp_fe {
            println!("listening on tcp://{}", fe.addr);
        }
        if let Some(fe) = &http_fe {
            println!("listening on http://{} (POST /v1/generate, GET /v1/models)", fe.addr);
        }
        println!("models: {} (Ctrl-C to drain and stop)", server.models().join(", "));
        let shutdown = qtip::util::shutdown::install();
        while !shutdown.is_set() {
            std::thread::sleep(std::time::Duration::from_millis(100));
        }
        eprintln!("[qtip] shutdown requested; closing frontends and draining...");
        if let Some(fe) = tcp_fe {
            fe.shutdown();
        }
        if let Some(fe) = http_fe {
            fe.shutdown();
        }
        let server = Arc::try_unwrap(server)
            .map_err(|_| anyhow::anyhow!("frontend still holds server references after join"))?;
        print_server_stats(&server.shutdown());
        return Ok(());
    }
    let n = args.get_usize("requests", 6);
    println!(
        "serving quantized model ({:.2}x compression); submitting {n} demo requests",
        report.compression_ratio(),
    );
    let server = ServerHandle::spawn_multi(models, server_cfg);
    let lane_names: Vec<String> = server.models().to_vec();
    let prompts = ["fn main", "pub struct", "import ", "## ", "let mut ", "def "];
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            server.submit(GenRequest {
                id: i as u64,
                prompt: prompts[i % prompts.len()].to_string(),
                max_new_tokens: args.get_usize("max-new", 48),
                temperature: 0.7,
                top_k: 40,
                seed: i as u64,
                // Demo requests round-robin across the served lanes.
                model: lane_names[i % lane_names.len()].clone(),
                deadline_ms: 0,
            })
        })
        .collect();
    for rx in rxs {
        let r = rx.recv()?;
        if let Some(err) = r.error {
            println!("[req {}] rejected: {err}", r.id);
            continue;
        }
        println!(
            "[req {}] ttft {:.1} ms, {:.1} tok/s: {:?}",
            r.id,
            r.ttft * 1e3,
            r.decode_tok_per_sec,
            r.text.chars().take(40).collect::<String>()
        );
    }
    print_server_stats(&server.shutdown());
    Ok(())
}

fn main() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if argv.is_empty() { "info".to_string() } else { argv.remove(0) };
    let args = Args::parse(argv);
    // Decode-kernel selection applies to every subcommand that builds a
    // QuantizedMatrix (quantize/serve/generate/eval — and info reports it).
    // Precedence: --kernel > QTIP_KERNEL env > auto.
    if let Some(spec) = args.get("kernel") {
        let kind = KernelKind::parse(spec).map_err(anyhow::Error::msg)?;
        kernel::set_process_kernel(kind);
    }
    match cmd.as_str() {
        "info" => cmd_info(&args),
        "quantize" => cmd_quantize(&args),
        "eval" => cmd_eval(&args),
        "generate" => cmd_generate(&args),
        "serve" => cmd_serve(&args),
        other => {
            eprintln!(
                "unknown command '{other}'\nusage: qtip <info|quantize|eval|generate|serve> \
                 [--model nano] [--k 2] [--l 12] [--code 3inst] [--save NAME] \
                 [--artifact NAME]... [--threads N] [--kernel auto|scalar|lanes] \
                 [--kv-layout auto|contig|paged] [--kv-block N] \
                 [--prefill-chunk N] [--round-budget N] \
                 [--max-queue N] [--default-deadline MS] \
                 [--tcp ADDR] [--http ADDR] [--allow-random] ..."
            );
            std::process::exit(2);
        }
    }
}
