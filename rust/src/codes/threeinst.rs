//! Algorithm 2 "3INST": a lookup-free computed Gaussian code.
//!
//! An LCG expands the state to a 32-bit word X. Each 16-bit half of X is masked to
//! its sign bit, bottom-two exponent bits, and mantissa, then XOR-ed into the
//! corresponding fields of the magic FP16 constant m = 0.922 (bits 0x3B60). Each
//! half is therefore an FP16 with random sign, random mantissa, and exponent in
//! {2^-3 .. 2^0} · [1,2) — approximately a mirrored exponential. The sum of the two
//! halves is close to Gaussian. On GPU: MAD, lop3 (mask+XOR with the packed
//! duplicated magic), HADD2 — 3 instructions for two weights.

use anyhow::{ensure, Result};

use super::Code;
use crate::quant::method::{
    CodeSpec, KernelCall, MethodBuild, MethodInfo, QuantMethod, TableSink, TableSource,
};
use crate::quant::{QtipConfig, LANES};
use crate::trellis::Trellis;
use crate::util::json::Json;

/// LCG multiplier from the paper (§3.1.1).
pub const A: u32 = 89226354;
/// LCG increment from the paper (§3.1.1).
pub const B: u32 = 64248484;
/// Mask: sign (bit 15), bottom two exponent bits (bits 11, 10), mantissa (9..0).
pub const MASK: u16 = 0x8FFF;
/// f16 bits of the magic constant 0.922.
pub const MAGIC: u16 = 0x3B60;
/// Std of (m1 + m2) over the full 2^16 u16 grid; frozen cross-language constant
/// (see DESIGN.md §7). Computed once from the exact f16 semantics.
pub const STD: f32 = 1.2443900210;

/// Branch-free binary16→f32 for the masked-XOR outputs: `(w & MASK) ^ MAGIC`
/// always has exponent field in 01100..=01111 (never subnormal/inf/nan), so the
/// general converter's special cases are dead — this is the §Perf hot-path
/// specialization (asserted equivalent to `f16_to_f32` in tests).
#[inline(always)]
fn f16_normal_to_f32(bits: u16) -> f32 {
    let sign = (bits as u32 & 0x8000) << 16;
    let exp_man = (bits as u32 & 0x7FFF) << 13;
    // Rebias exponent: +(127-15) << 23.
    f32::from_bits(sign | (exp_man + (112u32 << 23)))
}

/// Decode one state word to an approximately N(0,1) scalar.
#[inline(always)]
pub fn decode_scalar(state: u32) -> f32 {
    let x = A.wrapping_mul(state).wrapping_add(B);
    let m1 = f16_normal_to_f32(((x & 0xFFFF) as u16 & MASK) ^ MAGIC);
    let m2 = f16_normal_to_f32(((x >> 16) as u16 & MASK) ^ MAGIC);
    (m1 + m2) * (1.0 / STD)
}

/// Lane-array decode: elementwise [`decode_scalar`] over `N` states in a
/// fixed-width array, the shape the lane-blocked matvec kernels feed (`N` =
/// `quant::LANES`). Plain safe Rust over fixed arrays so LLVM auto-vectorizes
/// the LCG, mask/XOR, and f16 rebias across lanes; each lane runs the exact
/// scalar op sequence, so outputs are bit-identical to `decode_scalar`.
#[inline(always)]
pub fn decode_lanes<const N: usize>(states: [u32; N]) -> [f32; N] {
    let mut out = [0.0f32; N];
    for (o, s) in out.iter_mut().zip(states) {
        *o = decode_scalar(s);
    }
    out
}

/// The 3INST code (V=1).
#[derive(Clone, Copy, Debug)]
pub struct ThreeInstCode {
    l: u32,
}

impl ThreeInstCode {
    pub fn new(l: u32) -> Self {
        assert!(l <= 32);
        ThreeInstCode { l }
    }
}

impl Code for ThreeInstCode {
    fn l(&self) -> u32 {
        self.l
    }

    fn v(&self) -> u32 {
        1
    }

    fn name(&self) -> &'static str {
        "3inst"
    }

    #[inline]
    fn decode(&self, state: u32, out: &mut [f32]) {
        out[0] = decode_scalar(state);
    }
}

/// Registry entry for the 3INST computed code (V=1, no decode table).
pub struct ThreeInstMethod;

impl QuantMethod for ThreeInstMethod {
    fn name(&self) -> &'static str {
        "3inst"
    }

    fn info(&self) -> MethodInfo {
        MethodInfo {
            name: "3inst",
            summary: "computed Gaussian code: LCG + masked-XOR f16 halves (MAD/lop3/HADD2)",
            v_options: &[1],
            bits_min: 1,
            bits_max: 8,
            default_table_bytes: 0,
        }
    }

    fn build(&'static self, cfg: &QtipConfig) -> Result<MethodBuild> {
        ensure!(cfg.v == 1, "3inst is a V=1 code (got V={})", cfg.v);
        Ok(MethodBuild {
            code: Box::new(ThreeInstCode::new(cfg.l)),
            spec: CodeSpec::new(self, 1, Vec::new(), Vec::new()),
        })
    }

    fn decode_state(&self, _spec: &CodeSpec, state: u32, out: &mut [f32]) {
        out[0] = decode_scalar(state);
    }

    fn spec_to_json(&self, _spec: &CodeSpec, _sink: &mut dyn TableSink) -> Json {
        Json::obj(vec![("method", Json::Str("3inst".into()))])
    }

    fn spec_from_json(
        &'static self,
        _j: &Json,
        _src: &dyn TableSource,
        _trellis: &Trellis,
    ) -> Result<CodeSpec> {
        Ok(CodeSpec::new(self, 1, Vec::new(), Vec::new()))
    }

    fn run_kernel(&self, _spec: &CodeSpec, call: KernelCall<'_>) {
        call.run_v1(decode_scalar, decode_lanes::<LANES>);
    }

    fn synthetic_entry(&'static self, l: u32, k: u32, seed: u64) -> (Trellis, CodeSpec) {
        let _ = seed;
        (Trellis::new(l, k, 1), CodeSpec::new(self, 1, Vec::new(), Vec::new()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::f16::{f16_to_f32, f32_to_f16};
    use crate::util::stats;

    #[test]
    fn magic_constant_is_0922() {
        assert_eq!(f32_to_f16(0.922), MAGIC);
    }

    #[test]
    fn fast_f16_path_matches_general_converter() {
        // The hot-path specialization must agree with the exact converter on
        // every value the masked-XOR construction can produce.
        for w in 0u32..=0xFFFF {
            let bits = ((w as u16) & MASK) ^ MAGIC;
            assert_eq!(
                f16_normal_to_f32(bits),
                f16_to_f32(bits),
                "bits {bits:#06x}"
            );
        }
    }

    #[test]
    fn lane_decode_matches_scalar() {
        for base in [0u32, 1, 12345, 0xFFF8, u32::MAX - 7] {
            let states: [u32; 8] = std::array::from_fn(|j| base.wrapping_add(j as u32));
            let lanes = decode_lanes(states);
            for (j, &s) in states.iter().enumerate() {
                assert_eq!(lanes[j].to_bits(), decode_scalar(s).to_bits(), "lane {j}");
            }
        }
    }

    #[test]
    fn mask_covers_expected_fields() {
        // sign | exp[1:0] | mantissa
        assert_eq!(MASK, 0x8000 | (0b00011 << 10) | 0x3FF);
    }

    #[test]
    fn golden_vectors() {
        // state 0: X = B = 64248484 = 0x03D45EA4
        let x: u32 = 64248484;
        assert_eq!(A.wrapping_mul(0).wrapping_add(B), x);
        let lo = (x & 0xFFFF) as u16; // 0x5EA4
        let hi = (x >> 16) as u16; // 0x03D4
        let m1 = f16_to_f32((lo & MASK) ^ MAGIC);
        let m2 = f16_to_f32((hi & MASK) ^ MAGIC);
        let expect = (m1 + m2) / STD;
        assert!((decode_scalar(0) - expect).abs() < 1e-7);
        // Sanity: masked-XOR keeps exponent within [magic_exp-3, magic_exp].
        // magic exp field = 01110; flipping bottom two bits spans 01100..01111.
        for w in [lo, hi] {
            let e = (((w & MASK) ^ MAGIC) >> 10) & 0x1F;
            assert!((0b01100..=0b01111).contains(&e));
        }
    }

    #[test]
    fn marginal_moments() {
        let code = ThreeInstCode::new(16);
        let values = code.materialize();
        assert!(stats::mean(&values).abs() < 0.01);
        assert!((stats::std_dev(&values) - 1.0).abs() < 0.01);
        // Sum of two mirrored exponentials: mildly leptokurtic vs the Gaussian.
        let kurt = stats::kurtosis(&values);
        assert!((2.5..4.0).contains(&kurt), "kurtosis {kurt}");
    }

    #[test]
    fn neighbor_decorrelation() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        for s in 0..(1u32 << 16) {
            a.push(decode_scalar(s));
            b.push(decode_scalar(s >> 2));
        }
        let corr = stats::pearson(&a, &b).abs();
        assert!(corr < 0.05, "3INST neighbor correlation {corr}");
    }

    #[test]
    fn values_bounded_by_construction() {
        // Each half has |value| < 2 (exponent <= 0 field 01111 -> [1,2)); sum < 4.
        let code = ThreeInstCode::new(16);
        for v in code.materialize() {
            assert!(v.abs() < 4.0 / STD + 1e-6);
        }
    }
}
