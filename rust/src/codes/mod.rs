//! Trellis node-value codes (paper §3.1.1–3.1.2).
//!
//! A code maps an L-bit trellis state to a value in R^V. QTIP's contribution is a
//! family of *computed* codes that turn the state into a pseudorandom approximate
//! Gaussian in a handful of ALU instructions, so no `2^L × V` codebook has to live
//! in cache at decode time:
//!
//! * [`onemad::OneMadCode`] — Alg. 1 "1MAD": LCG + horizontal byte add (≈3 ops).
//! * [`threeinst::ThreeInstCode`] — Alg. 2 "3INST": LCG + mask/XOR into two FP16
//!   halves + add (3 ops).
//! * [`hybrid::HybridCode`] — Alg. 3 "HYB": integer hash + lookup in a tiny
//!   (cache-resident, fine-tunable) LUT + sign flip (amortized 2 ops).
//! * [`lut::PureLutCode`] — pure-lookup i.i.d. Gaussian codebook (the RPTC-style
//!   quality ceiling; Tables 1, 10, 11, 15).
//! * [`correlated::CorrelatedCode`] — deliberately miscorrelated code (Figure 3
//!   far-left): Gaussian marginal, linear in the state, so neighboring windows
//!   produce strongly correlated values. Quality foil for the computed codes.
//!
//! All integer semantics are u32-exact and mirrored by
//! `python/compile/kernels/codes.py`; golden-vector tests pin both sides.

pub mod correlated;
pub mod hybrid;
pub mod kmeans;
pub mod lut;
pub mod onemad;
pub mod threeinst;
pub mod vptq;

pub use correlated::CorrelatedCode;
pub use hybrid::{HybMethod, HybridCode};
pub use lut::{LutMethod, PureLutCode};
pub use onemad::{OneMadCode, OneMadMethod};
pub use threeinst::{ThreeInstCode, ThreeInstMethod};
pub use vptq::{VptqCode, VptqMethod};

/// A trellis node-value code: decodes an L-bit state word into V weights.
pub trait Code: Send + Sync {
    /// State width in bits.
    fn l(&self) -> u32;
    /// Values produced per state.
    fn v(&self) -> u32;
    /// Short identifier ("1mad", "3inst", "hyb", "lut", "corr").
    fn name(&self) -> &'static str;
    /// Decode one state into `out` (length == V).
    fn decode(&self, state: u32, out: &mut [f32]);

    /// Materialize the full `2^L × V` codebook (for Viterbi quantization — the
    /// *encode* side is allowed to hold the table; only decode must be compute-only).
    fn materialize(&self) -> Vec<f32> {
        let states = 1usize << self.l();
        let v = self.v() as usize;
        let mut values = vec![0.0f32; states * v];
        for s in 0..states {
            let (chunk, _) = values[s * v..].split_at_mut(v);
            self.decode(s as u32, chunk);
        }
        values
    }
}

/// Instantiate a code by name with the given trellis geometry.
/// `hyb` trains its LUT deterministically from `seed` (Q=9 for V=2, Q=6 for V=1,
/// matching the paper's GPU and ARM configurations).
pub fn build_code(name: &str, l: u32, v: u32, seed: u64) -> Box<dyn Code> {
    match name {
        "1mad" => {
            assert_eq!(v, 1, "1MAD is a 1D code");
            Box::new(OneMadCode::new(l))
        }
        "3inst" => {
            assert_eq!(v, 1, "3INST is a 1D code");
            Box::new(ThreeInstCode::new(l))
        }
        "hyb" => {
            let q = if v == 2 { 9 } else { 6 };
            Box::new(HybridCode::train(l, v, q, seed))
        }
        "lut" => Box::new(PureLutCode::new(l, v, seed)),
        "corr" => {
            assert_eq!(v, 1, "correlated demo code is 1D");
            Box::new(CorrelatedCode::new(l))
        }
        other => panic!("unknown code '{other}' (expected 1mad|3inst|hyb|lut|corr)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    /// The computed codes must produce approximately standard-Gaussian marginals —
    /// that is the property that lets RHT-processed weights be trellis-coded well.
    /// (HYB is excluded by design: its k-means LUT spaces entries ~density^(1/3), so
    /// the uniform-over-states marginal is deliberately heavier-tailed; what matters
    /// for HYB is *coverage*, checked in `hybrid::tests`.)
    #[test]
    fn all_codes_near_standard_gaussian() {
        for name in ["1mad", "3inst", "lut", "corr"] {
            let v = 1;
            let code = build_code(name, 14, v, 7);
            let values = code.materialize();
            let m = stats::mean(&values);
            let sd = stats::std_dev(&values);
            assert!(m.abs() < 0.05, "{name}: mean {m}");
            assert!((sd - 1.0).abs() < 0.12, "{name}: std {sd}");
        }
        // HYB: symmetric (sign flip) and covering.
        let code = build_code("hyb", 14, 1, 7);
        let values = code.materialize();
        assert!(stats::mean(&values).abs() < 0.06, "hyb mean");
        let min = values.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = values.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(min < -2.5 && max > 2.5, "hyb must cover the Gaussian tails");
    }

    #[test]
    fn materialize_matches_decode() {
        let code = build_code("3inst", 12, 1, 0);
        let values = code.materialize();
        let mut out = [0.0f32];
        for s in [0u32, 1, 77, 4095] {
            code.decode(s, &mut out);
            assert_eq!(values[s as usize], out[0]);
        }
    }

    #[test]
    fn hyb_v2_geometry() {
        let code = build_code("hyb", 16, 2, 3);
        assert_eq!(code.v(), 2);
        assert_eq!(code.materialize().len(), 65536 * 2);
    }

    #[test]
    #[should_panic(expected = "unknown code")]
    fn unknown_code_panics() {
        build_code("nope", 16, 1, 0);
    }
}
