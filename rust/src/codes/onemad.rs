//! Algorithm 1 "1MAD": a lookup-free computed Gaussian code.
//!
//! A linear congruential generator expands the L-bit state into a pseudorandom
//! 32-bit word; the horizontal sum of its four bytes is approximately Gaussian by
//! the CLT (n=4 uniforms), and a final multiply-add centers and scales it. On an
//! NVIDIA GPU this is MAD + AND, `vabsdiff4` (byte sum), and MAD — hence "1MAD" per
//! weight amortized; here the identical u32 arithmetic runs on CPU and inside the
//! Pallas kernel (`python/compile/kernels/codes.py`).

use anyhow::{ensure, Result};

use super::Code;
use crate::quant::method::{
    CodeSpec, KernelCall, MethodBuild, MethodInfo, QuantMethod, TableSink, TableSource,
};
use crate::quant::{QtipConfig, LANES};
use crate::trellis::Trellis;
use crate::util::json::Json;

/// LCG multiplier from the paper (§3.1.1).
pub const A: u32 = 34038481;
/// LCG increment from the paper (§3.1.1).
pub const B: u32 = 76625530;
/// Mean of the four-byte sum: 4 * 255/2.
pub const MEAN: f32 = 510.0;
/// Std of the four-byte sum: sqrt(4 * (256^2 - 1) / 12). Frozen cross-language.
pub const STD: f32 = 147.8005413;

/// Decode one state word to an approximately N(0,1) scalar.
#[inline(always)]
pub fn decode_scalar(state: u32) -> f32 {
    let x = A.wrapping_mul(state).wrapping_add(B);
    // Sum of the four bytes (the GPU form is one vabsdiff4 against 0).
    let s = (x & 0xFF) + ((x >> 8) & 0xFF) + ((x >> 16) & 0xFF) + (x >> 24);
    (s as f32 - MEAN) * (1.0 / STD)
}

/// Lane-array decode: elementwise [`decode_scalar`] over `N` states in a
/// fixed-width array, the shape the lane-blocked matvec kernels feed (`N` =
/// `quant::LANES`). Plain safe Rust over fixed arrays so LLVM auto-vectorizes
/// the LCG and byte-sum across lanes; each lane runs the exact scalar op
/// sequence, so outputs are bit-identical to `decode_scalar` per lane.
#[inline(always)]
pub fn decode_lanes<const N: usize>(states: [u32; N]) -> [f32; N] {
    let mut out = [0.0f32; N];
    for (o, s) in out.iter_mut().zip(states) {
        *o = decode_scalar(s);
    }
    out
}

/// The 1MAD code (V=1).
#[derive(Clone, Copy, Debug)]
pub struct OneMadCode {
    l: u32,
}

impl OneMadCode {
    pub fn new(l: u32) -> Self {
        assert!(l <= 32);
        OneMadCode { l }
    }
}

impl Code for OneMadCode {
    fn l(&self) -> u32 {
        self.l
    }

    fn v(&self) -> u32 {
        1
    }

    fn name(&self) -> &'static str {
        "1mad"
    }

    #[inline]
    fn decode(&self, state: u32, out: &mut [f32]) {
        out[0] = decode_scalar(state);
    }
}

/// Registry entry for the 1MAD computed code (V=1, no decode table).
pub struct OneMadMethod;

impl QuantMethod for OneMadMethod {
    fn name(&self) -> &'static str {
        "1mad"
    }

    fn info(&self) -> MethodInfo {
        MethodInfo {
            name: "1mad",
            summary: "computed Gaussian code: LCG + byte-sum (MAD/AND/vabsdiff4/MAD)",
            v_options: &[1],
            bits_min: 1,
            bits_max: 8,
            default_table_bytes: 0,
        }
    }

    fn build(&'static self, cfg: &QtipConfig) -> Result<MethodBuild> {
        ensure!(cfg.v == 1, "1mad is a V=1 code (got V={})", cfg.v);
        Ok(MethodBuild {
            code: Box::new(OneMadCode::new(cfg.l)),
            spec: CodeSpec::new(self, 1, Vec::new(), Vec::new()),
        })
    }

    fn decode_state(&self, _spec: &CodeSpec, state: u32, out: &mut [f32]) {
        out[0] = decode_scalar(state);
    }

    fn spec_to_json(&self, _spec: &CodeSpec, _sink: &mut dyn TableSink) -> Json {
        Json::obj(vec![("method", Json::Str("1mad".into()))])
    }

    fn spec_from_json(
        &'static self,
        _j: &Json,
        _src: &dyn TableSource,
        _trellis: &Trellis,
    ) -> Result<CodeSpec> {
        Ok(CodeSpec::new(self, 1, Vec::new(), Vec::new()))
    }

    fn run_kernel(&self, _spec: &CodeSpec, call: KernelCall<'_>) {
        call.run_v1(decode_scalar, decode_lanes::<LANES>);
    }

    fn synthetic_entry(&'static self, l: u32, k: u32, seed: u64) -> (Trellis, CodeSpec) {
        let _ = seed;
        (Trellis::new(l, k, 1), CodeSpec::new(self, 1, Vec::new(), Vec::new()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn golden_vectors() {
        // Frozen cross-language golden values (mirrored in python/tests).
        // state 0: X = B = 76625530 = 0x0491367A -> bytes 0x7A+0x36+0x91+0x04 = 325
        let expect0 = (325.0f64 - 510.0) / 147.8005413;
        assert!((decode_scalar(0) as f64 - expect0).abs() < 1e-6);
        // state 1: X = A + B = 110664011 = 0x0698994B -> 0x06+0x98+0x99+0x4B = 386
        let x: u32 = 110664011;
        let s = (x & 0xFF) + ((x >> 8) & 0xFF) + ((x >> 16) & 0xFF) + (x >> 24);
        assert_eq!(s, 386);
        let expect1 = (s as f32 - 510.0) / 147.8005413;
        assert!((decode_scalar(1) - expect1).abs() < 1e-6);
    }

    #[test]
    fn lane_decode_matches_scalar() {
        // The lane evaluator must be bit-identical to the scalar decode in
        // every lane — the lane-blocked matvec kernels' identity rests on it.
        for base in [0u32, 1, 917, 0xFFF0, u32::MAX - 7] {
            let states: [u32; 8] = std::array::from_fn(|j| base.wrapping_add(j as u32));
            let lanes = decode_lanes(states);
            for (j, &s) in states.iter().enumerate() {
                assert_eq!(lanes[j].to_bits(), decode_scalar(s).to_bits(), "lane {j}");
            }
        }
    }

    #[test]
    fn wrapping_is_mod_2_32() {
        // Large states must wrap, not panic/saturate.
        let v = decode_scalar(u32::MAX);
        assert!(v.is_finite());
    }

    #[test]
    fn marginal_moments() {
        let code = OneMadCode::new(16);
        let values = code.materialize();
        let m = stats::mean(&values);
        let sd = stats::std_dev(&values);
        let kurt = stats::kurtosis(&values);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((sd - 1.0).abs() < 0.02, "std {sd}");
        // CLT with n=4: kurtosis slightly platykurtic (~2.7), far from uniform (1.8).
        assert!((kurt - 2.7).abs() < 0.3, "kurtosis {kurt}");
    }

    #[test]
    fn output_is_bounded() {
        // Byte-sum construction bounds outputs to ±510/147.8 ≈ ±3.45 sigma.
        let code = OneMadCode::new(16);
        for v in code.materialize() {
            assert!(v.abs() <= 3.46);
        }
    }

    #[test]
    fn neighbor_decorrelation() {
        // Figure 3 (left-center): consecutive trellis windows of a k=2 stream —
        // state pairs (s, next) sharing L-2 bits — must be nearly uncorrelated.
        let l = 16u32;
        let mut a = Vec::new();
        let mut b = Vec::new();
        for s in 0..(1u32 << l) {
            // Next-window states for newbits=0: next = s >> 2 (top bits zero).
            let next = s >> 2;
            a.push(decode_scalar(s));
            b.push(decode_scalar(next));
        }
        let corr = stats::pearson(&a, &b).abs();
        assert!(corr < 0.05, "1MAD neighbor correlation {corr}");
    }
}
