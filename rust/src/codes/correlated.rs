//! A deliberately *bad* trellis code: Gaussian marginal but linear in the state
//! word, so overlapping windows (which share most of their bits) decode to nearly
//! identical values. This is the Figure 3 far-left panel — the failure mode that
//! motivates the pseudorandom computed codes.

use super::Code;

/// Inverse standard normal CDF (Acklam's rational approximation, |eps| < 1.2e-8).
pub fn probit(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "probit domain");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Gaussian-marginal code that is monotone in the state integer.
#[derive(Clone, Copy, Debug)]
pub struct CorrelatedCode {
    l: u32,
}

impl CorrelatedCode {
    pub fn new(l: u32) -> Self {
        assert!(l <= 24);
        CorrelatedCode { l }
    }
}

impl Code for CorrelatedCode {
    fn l(&self) -> u32 {
        self.l
    }

    fn v(&self) -> u32 {
        1
    }

    fn name(&self) -> &'static str {
        "corr"
    }

    #[inline]
    fn decode(&self, state: u32, out: &mut [f32]) {
        let n = (1u64 << self.l) as f64;
        let u = (state as f64 + 0.5) / n;
        out[0] = probit(u) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn probit_known_values() {
        assert!(probit(0.5).abs() < 1e-8);
        assert!((probit(0.975) - 1.959964).abs() < 1e-4);
        assert!((probit(0.025) + 1.959964).abs() < 1e-4);
        assert!((probit(0.841344746) - 1.0).abs() < 1e-4);
    }

    #[test]
    fn probit_symmetry() {
        for p in [0.01, 0.1, 0.3, 0.45] {
            assert!((probit(p) + probit(1.0 - p)).abs() < 1e-7);
        }
    }

    #[test]
    fn marginal_is_gaussian() {
        let code = CorrelatedCode::new(14);
        let values = code.materialize();
        assert!(stats::mean(&values).abs() < 1e-3);
        assert!((stats::std_dev(&values) - 1.0).abs() < 0.01);
    }

    #[test]
    fn neighbors_strongly_correlated() {
        // The windows of a bitshift walk share L-kV bits. In the little-endian
        // orientation state_{t+1} = (state_t >> kV) | new<<(L-kV): the *low* bits of
        // the current state are the *high* bits of... of the previous window's
        // shifted copy; a monotone-in-integer code correlates those windows whose
        // shared bits sit at the top of the integer. Check the pairing used by
        // Figure 3: (s, s >> kV | d << (L-kV)) averaged over d.
        let code = CorrelatedCode::new(16);
        let values = code.materialize();
        // Pair each state with a successor sharing its top bits: since the code is
        // monotone in the integer, states (s, s ^ lowbit) are near-identical, and
        // successors that keep the high bits (d reproducing them) stay correlated.
        // The aggregate neighbor correlation must be far from zero (vs <0.05 for
        // the computed codes).
        let mut a = Vec::new();
        let mut b = Vec::new();
        for s in 0..(1u32 << 16) {
            a.push(values[s as usize]);
            // Successor choosing new bits equal to the old top bits (worst case
            // plausible walk under a smooth source).
            let succ = (s >> 2) | (s & 0xC000);
            b.push(values[succ as usize]);
        }
        let corr = stats::pearson(&a, &b);
        assert!(corr > 0.5, "expected strong correlation, got {corr}");
    }
}
