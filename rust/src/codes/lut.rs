//! Pure-lookup random Gaussian code.
//!
//! Each state's value is an i.i.d. N(0,1) draw keyed deterministically by
//! (seed, state). This is the quality ceiling among bitshift-trellis codes: the
//! paper's Table 1 "RPTC" column and the LUT rows of Tables 10/11/15 use exactly
//! this construction. It is *not* decode-friendly at L ≳ 12 — the materialized
//! table would blow out L1 (the point of §3.1's computed codes) — but quantization
//! quality comparisons need it.

use anyhow::{ensure, Result};

use super::Code;
use crate::quant::method::{
    CodeSpec, KernelCall, MethodBuild, MethodInfo, QuantMethod, TableSink, TableSource,
};
use crate::quant::{QtipConfig, LANES};
use crate::trellis::Trellis;
use crate::util::json::Json;
use crate::util::rng::mix64;

/// Deterministic standard normal from a 64-bit key (Box–Muller on two hashes).
#[inline]
fn key_gauss(key: u64) -> f32 {
    let a = mix64(key);
    let b = mix64(key ^ 0xD6E8_FEB8_6659_FD93);
    // 53-bit uniforms.
    let u1 = 1.0 - (a >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    let u2 = (b >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    let r = (-2.0 * u1.ln()).sqrt();
    (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

/// Pure-lookup i.i.d. Gaussian codebook.
#[derive(Clone, Debug)]
pub struct PureLutCode {
    l: u32,
    v: u32,
    seed: u64,
    /// Materialized at construction: the encode path needs it anyway, and tests
    /// read it directly.
    pub table: Vec<f32>,
}

impl PureLutCode {
    pub fn new(l: u32, v: u32, seed: u64) -> Self {
        assert!(l <= 24);
        let states = 1usize << l;
        let mut table = Vec::with_capacity(states * v as usize);
        for s in 0..states {
            for j in 0..v {
                let j_mix = (j as u64).wrapping_mul(0xB5AD_4ECE_DA1C_E2A9);
                table.push(key_gauss((seed << 1) ^ ((s as u64) << 3) ^ j_mix));
            }
        }
        PureLutCode { l, v, seed, table }
    }

    /// Storage footprint of the codebook in bytes (FP16), for Table 10's size column.
    pub fn codebook_bytes(&self) -> usize {
        self.table.len() * 2
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

impl Code for PureLutCode {
    fn l(&self) -> u32 {
        self.l
    }

    fn v(&self) -> u32 {
        self.v
    }

    fn name(&self) -> &'static str {
        "lut"
    }

    #[inline]
    fn decode(&self, state: u32, out: &mut [f32]) {
        let v = self.v as usize;
        let base = state as usize * v;
        out[..v].copy_from_slice(&self.table[base..base + v]);
    }

    fn materialize(&self) -> Vec<f32> {
        self.table.clone()
    }
}

/// Registry entry for the pure-LUT code (2^L × V materialized table).
pub struct LutMethod;

impl QuantMethod for LutMethod {
    fn name(&self) -> &'static str {
        "lut"
    }

    fn info(&self) -> MethodInfo {
        MethodInfo {
            name: "lut",
            summary: "pure-lookup i.i.d. Gaussian codebook (quality ceiling, 2^L x V table)",
            v_options: &[1, 2],
            bits_min: 1,
            bits_max: 8,
            // L=12, V=1 fp16 table: the largest geometry that stays L1-resident.
            default_table_bytes: (1usize << 12) * 2,
        }
    }

    fn build(&'static self, cfg: &QtipConfig) -> Result<MethodBuild> {
        ensure!(cfg.l <= 24, "lut requires L <= 24 (got L={})", cfg.l);
        let code = PureLutCode::new(cfg.l, cfg.v, cfg.seed);
        let spec = CodeSpec::new(self, cfg.v, Vec::new(), code.table.clone());
        Ok(MethodBuild { code: Box::new(code), spec })
    }

    fn decode_state(&self, spec: &CodeSpec, state: u32, out: &mut [f32]) {
        let vv = spec.v() as usize;
        let base = state as usize * vv;
        out[..vv].copy_from_slice(&spec.table()[base..base + vv]);
    }

    fn spec_to_json(&self, spec: &CodeSpec, sink: &mut dyn TableSink) -> Json {
        let table_off = sink.put_f32s(spec.table());
        Json::obj(vec![
            ("method", Json::Str("lut".into())),
            ("v", Json::Num(spec.v() as f64)),
            ("table_off", Json::Num(table_off as f64)),
            ("table_len", Json::Num(spec.table().len() as f64)),
        ])
    }

    fn spec_from_json(
        &'static self,
        j: &Json,
        src: &dyn TableSource,
        trellis: &Trellis,
    ) -> Result<CodeSpec> {
        let v = j.req_usize("v") as u32;
        ensure!((1..=2).contains(&v), "lut code spec out of range (v={v})");
        let table_len = j.req_usize("table_len");
        ensure!(
            table_len == (1usize << trellis.l) * v as usize,
            "lut table length {table_len} does not match L={}, v={v}",
            trellis.l
        );
        let table = src.f32s(j.req_usize("table_off"), table_len)?;
        Ok(CodeSpec::new(self, v, Vec::new(), table))
    }

    fn run_kernel(&self, spec: &CodeSpec, call: KernelCall<'_>) {
        let table = spec.table();
        if spec.v() == 1 {
            call.run_v1(
                move |s| table[s as usize],
                move |s: [u32; LANES]| {
                    let mut out = [0.0f32; LANES];
                    for (o, &st) in out.iter_mut().zip(s.iter()) {
                        *o = table[st as usize];
                    }
                    out
                },
            )
        } else {
            call.run_v2(
                move |s| (table[s as usize * 2], table[s as usize * 2 + 1]),
                move |s: [u32; LANES]| {
                    let mut a = [0.0f32; LANES];
                    let mut b = [0.0f32; LANES];
                    for ((av, bv), &st) in a.iter_mut().zip(b.iter_mut()).zip(s.iter()) {
                        *av = table[st as usize * 2];
                        *bv = table[st as usize * 2 + 1];
                    }
                    (a, b)
                },
            )
        }
    }

    fn synthetic_entry(&'static self, l: u32, k: u32, seed: u64) -> (Trellis, CodeSpec) {
        let code = PureLutCode::new(l, 1, seed);
        (Trellis::new(l, k, 1), CodeSpec::new(self, 1, Vec::new(), code.table))
    }

    fn bench_l(&self) -> u32 {
        // Cap the bench trellis so the materialized table stays L1-resident,
        // matching the regime the paper benches LUT codes in.
        12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = PureLutCode::new(10, 1, 42);
        let b = PureLutCode::new(10, 1, 42);
        let c = PureLutCode::new(10, 1, 43);
        assert_eq!(a.table, b.table);
        assert_ne!(a.table, c.table);
    }

    #[test]
    fn marginals_standard_gaussian() {
        let code = PureLutCode::new(16, 1, 1);
        assert!(stats::mean(&code.table).abs() < 0.02);
        assert!((stats::std_dev(&code.table) - 1.0).abs() < 0.02);
        assert!((stats::kurtosis(&code.table) - 3.0).abs() < 0.15);
    }

    #[test]
    fn neighbor_states_uncorrelated() {
        // The defining property the computed codes must emulate (Figure 3 far-right).
        let code = PureLutCode::new(16, 1, 2);
        let a: Vec<f32> = (0..65536u32).map(|s| code.table[s as usize]).collect();
        let b: Vec<f32> = (0..65536u32).map(|s| code.table[(s >> 2) as usize]).collect();
        assert!(stats::pearson(&a, &b).abs() < 0.02);
    }

    #[test]
    fn v2_layout() {
        let code = PureLutCode::new(8, 2, 5);
        assert_eq!(code.table.len(), 512);
        let mut out = [0.0f32; 2];
        code.decode(37, &mut out);
        assert_eq!(out[0], code.table[74]);
        assert_eq!(out[1], code.table[75]);
    }

    #[test]
    fn codebook_bytes_table10() {
        // Table 10's CB size column: L=16, V=1 FP16 LUT = 128 KiB... the paper
        // counts Kb (kilobits): 2^16 states * 16 bits = 1.05 Mb. We report bytes.
        let code = PureLutCode::new(16, 1, 0);
        assert_eq!(code.codebook_bytes(), 131072);
    }
}
