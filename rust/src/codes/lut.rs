//! Pure-lookup random Gaussian code.
//!
//! Each state's value is an i.i.d. N(0,1) draw keyed deterministically by
//! (seed, state). This is the quality ceiling among bitshift-trellis codes: the
//! paper's Table 1 "RPTC" column and the LUT rows of Tables 10/11/15 use exactly
//! this construction. It is *not* decode-friendly at L ≳ 12 — the materialized
//! table would blow out L1 (the point of §3.1's computed codes) — but quantization
//! quality comparisons need it.

use super::Code;
use crate::util::rng::mix64;

/// Deterministic standard normal from a 64-bit key (Box–Muller on two hashes).
#[inline]
fn key_gauss(key: u64) -> f32 {
    let a = mix64(key);
    let b = mix64(key ^ 0xD6E8_FEB8_6659_FD93);
    // 53-bit uniforms.
    let u1 = 1.0 - (a >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    let u2 = (b >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
    let r = (-2.0 * u1.ln()).sqrt();
    (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

/// Pure-lookup i.i.d. Gaussian codebook.
#[derive(Clone, Debug)]
pub struct PureLutCode {
    l: u32,
    v: u32,
    seed: u64,
    /// Materialized at construction: the encode path needs it anyway, and tests
    /// read it directly.
    pub table: Vec<f32>,
}

impl PureLutCode {
    pub fn new(l: u32, v: u32, seed: u64) -> Self {
        assert!(l <= 24);
        let states = 1usize << l;
        let mut table = Vec::with_capacity(states * v as usize);
        for s in 0..states {
            for j in 0..v {
                table.push(key_gauss(
                    (seed << 1) ^ ((s as u64) << 3) ^ (j as u64).wrapping_mul(0xB5AD_4ECE_DA1C_E2A9),
                ));
            }
        }
        PureLutCode { l, v, seed, table }
    }

    /// Storage footprint of the codebook in bytes (FP16), for Table 10's size column.
    pub fn codebook_bytes(&self) -> usize {
        self.table.len() * 2
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }
}

impl Code for PureLutCode {
    fn l(&self) -> u32 {
        self.l
    }

    fn v(&self) -> u32 {
        self.v
    }

    fn name(&self) -> &'static str {
        "lut"
    }

    #[inline]
    fn decode(&self, state: u32, out: &mut [f32]) {
        let v = self.v as usize;
        let base = state as usize * v;
        out[..v].copy_from_slice(&self.table[base..base + v]);
    }

    fn materialize(&self) -> Vec<f32> {
        self.table.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = PureLutCode::new(10, 1, 42);
        let b = PureLutCode::new(10, 1, 42);
        let c = PureLutCode::new(10, 1, 43);
        assert_eq!(a.table, b.table);
        assert_ne!(a.table, c.table);
    }

    #[test]
    fn marginals_standard_gaussian() {
        let code = PureLutCode::new(16, 1, 1);
        assert!(stats::mean(&code.table).abs() < 0.02);
        assert!((stats::std_dev(&code.table) - 1.0).abs() < 0.02);
        assert!((stats::kurtosis(&code.table) - 3.0).abs() < 0.15);
    }

    #[test]
    fn neighbor_states_uncorrelated() {
        // The defining property the computed codes must emulate (Figure 3 far-right).
        let code = PureLutCode::new(16, 1, 2);
        let a: Vec<f32> = (0..65536u32).map(|s| code.table[s as usize]).collect();
        let b: Vec<f32> = (0..65536u32).map(|s| code.table[(s >> 2) as usize]).collect();
        assert!(stats::pearson(&a, &b).abs() < 0.02);
    }

    #[test]
    fn v2_layout() {
        let code = PureLutCode::new(8, 2, 5);
        assert_eq!(code.table.len(), 512);
        let mut out = [0.0f32; 2];
        code.decode(37, &mut out);
        assert_eq!(out[0], code.table[74]);
        assert_eq!(out[1], code.table[75]);
    }

    #[test]
    fn codebook_bytes_table10() {
        // Table 10's CB size column: L=16, V=1 FP16 LUT = 128 KiB... the paper
        // counts Kb (kilobits): 2^16 states * 16 bits = 1.05 Mb. We report bytes.
        let code = PureLutCode::new(16, 1, 0);
        assert_eq!(code.codebook_bytes(), 131072);
    }
}
