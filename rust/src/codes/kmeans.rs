//! k-means (k-means++ init + Lloyd iterations) for codebook training.
//!
//! Used to initialize the HYB code's LUT on an empirical 2D Gaussian (paper §3.1.2),
//! to build the Lloyd–Max scalar baseline (k-means in 1D is exactly Lloyd–Max), and
//! to sanity-train small VQ codebooks for comparisons.

use crate::util::rng::Rng;

/// Result of a k-means run over `dim`-dimensional points.
pub struct KMeans {
    pub centroids: Vec<f32>, // k * dim
    pub dim: usize,
    pub inertia: f64,
}

/// Squared distance between a point and a centroid.
#[inline]
fn dist2(p: &[f32], c: &[f32]) -> f64 {
    let mut s = 0.0f64;
    for i in 0..p.len() {
        let d = (p[i] - c[i]) as f64;
        s += d * d;
    }
    s
}

/// Index of the nearest centroid (brute force).
pub fn nearest(point: &[f32], centroids: &[f32], dim: usize) -> usize {
    let k = centroids.len() / dim;
    let mut best = 0;
    let mut bd = f64::INFINITY;
    for c in 0..k {
        let d = dist2(point, &centroids[c * dim..(c + 1) * dim]);
        if d < bd {
            bd = d;
            best = c;
        }
    }
    best
}

/// Run k-means on `points` (n × dim, row-major) into `k` clusters.
pub fn kmeans(points: &[f32], dim: usize, k: usize, iters: usize, rng: &mut Rng) -> KMeans {
    assert!(dim > 0 && points.len() % dim == 0);
    let n = points.len() / dim;
    assert!(n >= k, "need at least k points");

    // k-means++ seeding.
    let mut centroids = vec![0.0f32; k * dim];
    let first = rng.below(n);
    centroids[..dim].copy_from_slice(&points[first * dim..(first + 1) * dim]);
    let mut d2 = vec![0.0f64; n];
    for i in 0..n {
        d2[i] = dist2(&points[i * dim..(i + 1) * dim], &centroids[..dim]);
    }
    for c in 1..k {
        let total: f64 = d2.iter().sum();
        let mut target = rng.uniform() * total;
        let mut pick = n - 1;
        for (i, &d) in d2.iter().enumerate() {
            target -= d;
            if target <= 0.0 {
                pick = i;
                break;
            }
        }
        let dst = c * dim;
        centroids.copy_within(0..0, 0); // no-op, keeps clippy quiet about styles
        centroids[dst..dst + dim].copy_from_slice(&points[pick * dim..(pick + 1) * dim]);
        for i in 0..n {
            let d = dist2(&points[i * dim..(i + 1) * dim], &centroids[dst..dst + dim]);
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    // Lloyd iterations.
    let mut assign = vec![0usize; n];
    let mut inertia = f64::INFINITY;
    for _ in 0..iters {
        // Assignment.
        let mut new_inertia = 0.0f64;
        for i in 0..n {
            let p = &points[i * dim..(i + 1) * dim];
            let c = nearest(p, &centroids, dim);
            assign[i] = c;
            new_inertia += dist2(p, &centroids[c * dim..(c + 1) * dim]);
        }
        // Update.
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0usize; k];
        for i in 0..n {
            let c = assign[i];
            counts[c] += 1;
            for j in 0..dim {
                sums[c * dim + j] += points[i * dim + j] as f64;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed an empty cluster at a random point.
                let pick = rng.below(n);
                centroids[c * dim..(c + 1) * dim]
                    .copy_from_slice(&points[pick * dim..(pick + 1) * dim]);
            } else {
                for j in 0..dim {
                    centroids[c * dim + j] = (sums[c * dim + j] / counts[c] as f64) as f32;
                }
            }
        }
        if (inertia - new_inertia).abs() < 1e-9 * inertia.max(1.0) {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }
    KMeans { centroids, dim, inertia }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_separated_clusters() {
        let mut rng = Rng::new(1);
        let mut pts = Vec::new();
        let centers = [(-10.0f32, -10.0), (10.0, 10.0), (-10.0, 10.0)];
        for _ in 0..300 {
            let c = centers[rng.below(3)];
            pts.push(c.0 + rng.gauss_f32() * 0.1);
            pts.push(c.1 + rng.gauss_f32() * 0.1);
        }
        let km = kmeans(&pts, 2, 3, 30, &mut rng);
        // Each true center must be close to some centroid.
        for c in centers {
            let mut best = f64::INFINITY;
            for i in 0..3 {
                let d = ((km.centroids[i * 2] - c.0) as f64).powi(2)
                    + ((km.centroids[i * 2 + 1] - c.1) as f64).powi(2);
                best = best.min(d);
            }
            assert!(best < 0.1, "center {c:?} not recovered: {best}");
        }
    }

    #[test]
    fn lloyd_max_1d_2bit_matches_theory() {
        // k-means on N(0,1) with k=4 is the 2-bit Lloyd–Max quantizer.
        // Optimal levels ±0.4528, ±1.510; MSE = 0.1175 (paper Table 1's 0.118).
        let mut rng = Rng::new(2);
        let pts = rng.gauss_vec(200_000);
        let km = kmeans(&pts, 1, 4, 60, &mut rng);
        let mut levels = km.centroids.clone();
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!((levels[0] + 1.510).abs() < 0.05, "{levels:?}");
        assert!((levels[1] + 0.4528).abs() < 0.03, "{levels:?}");
        assert!((levels[2] - 0.4528).abs() < 0.03, "{levels:?}");
        assert!((levels[3] - 1.510).abs() < 0.05, "{levels:?}");
        let mse = km.inertia / 200_000.0;
        assert!((mse - 0.1175).abs() < 0.005, "mse {mse}");
    }

    #[test]
    fn inertia_decreases_with_k() {
        let mut rng = Rng::new(3);
        let pts = rng.gauss_vec(5_000);
        let i2 = kmeans(&pts, 1, 2, 25, &mut rng).inertia;
        let i4 = kmeans(&pts, 1, 4, 25, &mut rng).inertia;
        let i8 = kmeans(&pts, 1, 8, 25, &mut rng).inertia;
        assert!(i2 > i4 && i4 > i8);
    }

    #[test]
    fn nearest_is_argmin() {
        let centroids = vec![0.0f32, 0.0, 5.0, 5.0, -3.0, 2.0];
        assert_eq!(nearest(&[4.9, 4.8], &centroids, 2), 1);
        assert_eq!(nearest(&[-2.0, 1.5], &centroids, 2), 2);
        assert_eq!(nearest(&[0.1, -0.2], &centroids, 2), 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(9);
        let pts1 = r1.gauss_vec(1000);
        let km1 = kmeans(&pts1, 1, 8, 10, &mut r1);
        let mut r2 = Rng::new(9);
        let pts2 = r2.gauss_vec(1000);
        let km2 = kmeans(&pts2, 1, 8, 10, &mut r2);
        assert_eq!(km1.centroids, km2.centroids);
    }
}
