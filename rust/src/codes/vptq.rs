//! VPTQ-style vector-codebook code: residual two-stage vector quantization.
//!
//! Following VPTQ (Liu et al., 2024; see PAPERS.md), each code point is a 2-D
//! vector reconstructed as `c1[i1] + c2[i2]` from a first-stage codebook and a
//! residual codebook, both trained with k-means (first stage on N(0, I₂)
//! samples, second stage on the residuals to the nearest first-stage
//! centroid). Unlike VPTQ's per-layer codebooks we key both indices off the
//! trellis state through a multiplicative hash, which turns the pair of
//! codebooks into a stateful trellis code the Viterbi encoder can search —
//! the registry's proof that a genuinely different decode scheme plugs in
//! without touching the quant/io/serve layers.
//!
//! Both codebooks are 2^Q1 = 2^Q2 = 64 entries × V=2, so the concatenated
//! decode table is 256 f32 (512 fp16 bytes on device) — far below the L1
//! budget Table 10 cares about, while the *effective* codebook is the 4096-
//! entry Minkowski sum.

use anyhow::{bail, ensure, Result};

use super::kmeans::{kmeans, nearest};
use super::Code;
use crate::quant::method::{
    CodeSpec, KernelCall, MethodBuild, MethodInfo, QuantMethod, TableSink, TableSource,
};
use crate::quant::{QtipConfig, LANES};
use crate::trellis::Trellis;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// log2 first-stage codebook entries.
pub const Q1: u32 = 6;
/// log2 residual codebook entries.
pub const Q2: u32 = 6;
/// Training sample count (first stage; residuals reuse the same points).
const TRAIN_POINTS: usize = 4096;
/// k-means Lloyd iterations per stage.
const TRAIN_ITERS: usize = 25;

/// State mixer: one multiplicative hash (Fibonacci multiplier) whose *high*
/// bits index the codebooks — high bits of a multiplicative hash have the
/// best avalanche, which is what decorrelates the trellis-adjacent states
/// sharing L−k low bits (the property Figure 3 demands of any code here).
#[inline(always)]
pub fn mix(state: u32) -> u32 {
    state.wrapping_mul(0x9E37_79B1)
}

/// Lane-array mixer: elementwise [`mix`] in the fixed-width shape the
/// lane-blocked kernels feed (`N` = `quant::LANES`); bit-identical per lane.
#[inline(always)]
pub fn mix_lanes<const N: usize>(states: [u32; N]) -> [u32; N] {
    let mut out = [0u32; N];
    for (o, s) in out.iter_mut().zip(states) {
        *o = mix(s);
    }
    out
}

/// First/second-stage codebook indices for a state.
#[inline(always)]
pub fn indices(x: u32) -> (usize, usize) {
    let i1 = (x >> (32 - Q1)) as usize;
    let i2 = ((x >> (32 - Q1 - Q2)) & ((1 << Q2) - 1)) as usize;
    (i1, i2)
}

/// Train the two codebooks and return them concatenated:
/// `table[..2^Q1·2]` = first stage, `table[2^Q1·2..]` = residual stage.
pub fn train_table(seed: u64) -> Vec<f32> {
    let k1 = 1usize << Q1;
    let k2 = 1usize << Q2;
    let mut rng = Rng::new(seed ^ 0x5650_5451); // "VPTQ" salt
    let mut pts = Vec::with_capacity(TRAIN_POINTS * 2);
    for _ in 0..TRAIN_POINTS * 2 {
        pts.push(rng.gauss_f32());
    }
    let km1 = kmeans(&pts, 2, k1, TRAIN_ITERS, &mut rng);
    // Residuals to the nearest first-stage centroid.
    let mut res = Vec::with_capacity(pts.len());
    for p in pts.chunks_exact(2) {
        let c = nearest(p, &km1.centroids, 2);
        res.push(p[0] - km1.centroids[c * 2]);
        res.push(p[1] - km1.centroids[c * 2 + 1]);
    }
    let km2 = kmeans(&res, 2, k2, TRAIN_ITERS, &mut rng);
    let mut table = km1.centroids;
    table.extend_from_slice(&km2.centroids);
    table
}

/// The VPTQ-style code (V=2): encode-side [`Code`] for the Viterbi search.
#[derive(Clone, Debug)]
pub struct VptqCode {
    l: u32,
    /// Concatenated `[first-stage | residual]` codebooks, `(2^Q1 + 2^Q2) × 2`.
    pub table: Vec<f32>,
}

impl VptqCode {
    pub fn new(l: u32, seed: u64) -> Self {
        assert!(l <= 24);
        VptqCode { l, table: train_table(seed) }
    }

    pub fn from_table(l: u32, table: Vec<f32>) -> Self {
        assert_eq!(table.len(), ((1usize << Q1) + (1usize << Q2)) * 2);
        VptqCode { l, table }
    }
}

impl Code for VptqCode {
    fn l(&self) -> u32 {
        self.l
    }

    fn v(&self) -> u32 {
        2
    }

    fn name(&self) -> &'static str {
        "vptq"
    }

    #[inline]
    fn decode(&self, state: u32, out: &mut [f32]) {
        let (i1, i2) = indices(mix(state));
        let c2 = &self.table[(1usize << Q1) * 2..];
        out[0] = self.table[i1 * 2] + c2[i2 * 2];
        out[1] = self.table[i1 * 2 + 1] + c2[i2 * 2 + 1];
    }
}

/// Registry entry for the VPTQ-style residual vector-codebook code.
pub struct VptqMethod;

impl QuantMethod for VptqMethod {
    fn name(&self) -> &'static str {
        "vptq"
    }

    fn info(&self) -> MethodInfo {
        MethodInfo {
            name: "vptq",
            summary: "residual two-stage vector codebooks (VPTQ-style), hash-indexed",
            v_options: &[2],
            bits_min: 1,
            bits_max: 4,
            default_table_bytes: ((1usize << Q1) + (1usize << Q2)) * 2 * 2,
        }
    }

    fn preferred_v(&self) -> u32 {
        2
    }

    fn build(&'static self, cfg: &QtipConfig) -> Result<MethodBuild> {
        ensure!(cfg.v == 2, "vptq is a V=2 code (got V={})", cfg.v);
        let code = VptqCode::new(cfg.l, cfg.seed);
        let spec = CodeSpec::new(self, 2, vec![Q1, Q2], code.table.clone());
        Ok(MethodBuild { code: Box::new(code), spec })
    }

    fn decode_state(&self, spec: &CodeSpec, state: u32, out: &mut [f32]) {
        let (i1, i2) = indices(mix(state));
        let table = spec.table();
        let c2 = &table[(1usize << Q1) * 2..];
        out[0] = table[i1 * 2] + c2[i2 * 2];
        out[1] = table[i1 * 2 + 1] + c2[i2 * 2 + 1];
    }

    fn spec_to_json(&self, spec: &CodeSpec, sink: &mut dyn TableSink) -> Json {
        let table_off = sink.put_f32s(spec.table());
        Json::obj(vec![
            ("method", Json::Str("vptq".into())),
            ("q1", Json::Num(Q1 as f64)),
            ("q2", Json::Num(Q2 as f64)),
            ("table_off", Json::Num(table_off as f64)),
            ("table_len", Json::Num(spec.table().len() as f64)),
        ])
    }

    fn spec_from_json(
        &'static self,
        j: &Json,
        src: &dyn TableSource,
        _trellis: &Trellis,
    ) -> Result<CodeSpec> {
        let q1 = j.req_usize("q1") as u32;
        let q2 = j.req_usize("q2") as u32;
        if q1 != Q1 || q2 != Q2 {
            bail!("vptq codebook geometry (q1={q1}, q2={q2}) unsupported by this build");
        }
        let table_len = j.req_usize("table_len");
        ensure!(
            table_len == ((1usize << Q1) + (1usize << Q2)) * 2,
            "vptq table length {table_len} does not match q1={Q1}, q2={Q2}"
        );
        let table = src.f32s(j.req_usize("table_off"), table_len)?;
        Ok(CodeSpec::new(self, 2, vec![Q1, Q2], table))
    }

    fn run_kernel(&self, spec: &CodeSpec, call: KernelCall<'_>) {
        let table = spec.table();
        let (c1, c2) = table.split_at((1usize << Q1) * 2);
        call.run_v2(
            move |s| {
                let (i1, i2) = indices(mix(s));
                (c1[i1 * 2] + c2[i2 * 2], c1[i1 * 2 + 1] + c2[i2 * 2 + 1])
            },
            move |s: [u32; LANES]| {
                let h = mix_lanes(s);
                let mut a = [0.0f32; LANES];
                let mut b = [0.0f32; LANES];
                for ((av, bv), &x) in a.iter_mut().zip(b.iter_mut()).zip(h.iter()) {
                    let (i1, i2) = indices(x);
                    *av = c1[i1 * 2] + c2[i2 * 2];
                    *bv = c1[i1 * 2 + 1] + c2[i2 * 2 + 1];
                }
                (a, b)
            },
        )
    }

    fn synthetic_entry(&'static self, l: u32, k: u32, seed: u64) -> (Trellis, CodeSpec) {
        (Trellis::new(l, k, 2), CodeSpec::new(self, 2, vec![Q1, Q2], train_table(seed)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn mix_golden_and_lanes_match() {
        // Fibonacci multiplicative hash, wrapping mod 2^32.
        assert_eq!(mix(0), 0);
        assert_eq!(mix(1), 0x9E37_79B1);
        assert_eq!(mix(2), 0x3C6E_F362);
        for base in [0u32, 7, 65521, u32::MAX - 3] {
            let states: [u32; 8] = std::array::from_fn(|j| base.wrapping_add(j as u32));
            let lanes = mix_lanes(states);
            for (j, &s) in states.iter().enumerate() {
                assert_eq!(lanes[j], mix(s), "lane {j}");
            }
        }
    }

    #[test]
    fn indices_use_high_bits() {
        let x = 0xFFFF_FFFFu32;
        let (i1, i2) = indices(x);
        assert_eq!(i1, 63);
        assert_eq!(i2, 63);
        let (i1, i2) = indices(0x0400_0000);
        assert_eq!(i1, 1);
        assert_eq!(i2, 0);
    }

    #[test]
    fn training_is_deterministic_and_seed_sensitive() {
        assert_eq!(train_table(9), train_table(9));
        assert_ne!(train_table(9), train_table(10));
        assert_eq!(train_table(9).len(), 256);
    }

    #[test]
    fn decode_is_residual_sum() {
        let code = VptqCode::new(12, 3);
        let mut out = [0.0f32; 2];
        for s in [0u32, 1, 777, 4095] {
            code.decode(s, &mut out);
            let (i1, i2) = indices(mix(s));
            let c2 = &code.table[128..];
            assert_eq!(out[0], code.table[i1 * 2] + c2[i2 * 2]);
            assert_eq!(out[1], code.table[i1 * 2 + 1] + c2[i2 * 2 + 1]);
        }
    }

    #[test]
    fn effective_codebook_covers_gaussian() {
        // The Minkowski sum of the two stages must re-center and cover the
        // bulk + tails of N(0, I_2), like the HYB LUT does.
        let code = VptqCode::new(12, 5);
        let values = code.materialize();
        let xs: Vec<f32> = values.iter().step_by(2).copied().collect();
        let ys: Vec<f32> = values.iter().skip(1).step_by(2).copied().collect();
        for comp in [&xs, &ys] {
            assert!(stats::mean(comp).abs() < 0.1);
            let min = comp.iter().cloned().fold(f32::INFINITY, f32::min);
            let max = comp.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(min < -2.0 && max > 2.0, "component must cover tails");
        }
    }

    #[test]
    fn residual_stage_refines_first_stage() {
        // Two-stage reconstruction must beat first-stage-only on fresh
        // Gaussian points — the property that makes the residual stage worth
        // its bits.
        let table = train_table(11);
        let (c1, c2) = table.split_at(128);
        let mut rng = Rng::new(424242);
        let mut mse1 = 0.0f64;
        let mut mse2 = 0.0f64;
        let n = 2000;
        for _ in 0..n {
            let p = [rng.gauss_f32(), rng.gauss_f32()];
            let i1 = nearest(&p, c1, 2);
            let r = [p[0] - c1[i1 * 2], p[1] - c1[i1 * 2 + 1]];
            mse1 += (r[0] * r[0] + r[1] * r[1]) as f64;
            let i2 = nearest(&r, c2, 2);
            let e = [r[0] - c2[i2 * 2], r[1] - c2[i2 * 2 + 1]];
            mse2 += (e[0] * e[0] + e[1] * e[1]) as f64;
        }
        mse1 /= (2 * n) as f64;
        mse2 /= (2 * n) as f64;
        assert!(mse2 < mse1 * 0.5, "residual stage must refine: {mse2} vs {mse1}");
    }
}
