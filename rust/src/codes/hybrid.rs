//! Algorithm 3 "HYB": hybrid computed-lookup code.
//!
//! The state is mixed with the invertible hash `X ← X² + X (mod 2³²)` (Klimov &
//! Shamir), bits `(15−Q)..14` index a `2^Q × V` LUT, and bit 15 flips the sign of
//! the last vector component — an effective codebook of `2^(Q+1)` entries while
//! storing half of them. With Q=9, V=2 the LUT is 2 KiB of FP16 on GPU (bank-
//! conflict-free in shared memory); we keep the same geometry so it stays
//! L1-resident on CPU too.
//!
//! Unlike the pure-computed codes the LUT is differentiable, so it can be
//! initialized by k-means on an empirical i.i.d. Gaussian (paper §3.1.2) and
//! fine-tuned afterwards.

use anyhow::{bail, ensure, Result};

use super::kmeans::kmeans;
use super::Code;
use crate::quant::method::{
    CodeSpec, KernelCall, MethodBuild, MethodInfo, QuantMethod, TableSink, TableSource,
};
use crate::quant::{QtipConfig, LANES};
use crate::trellis::Trellis;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// The Klimov–Shamir T-function hash used by HYB.
#[inline(always)]
pub fn hash(x: u32) -> u32 {
    x.wrapping_mul(x).wrapping_add(x)
}

/// Lane-array hash: elementwise [`hash`] over `N` states in a fixed-width
/// array (`N` = `quant::LANES`), the shape the lane-blocked matvec kernels
/// feed before their per-lane LUT gathers. Plain safe Rust so LLVM
/// auto-vectorizes the square-and-add across lanes; bit-identical per lane.
#[inline(always)]
pub fn hash_lanes<const N: usize>(states: [u32; N]) -> [u32; N] {
    let mut out = [0u32; N];
    for (o, s) in out.iter_mut().zip(states) {
        *o = hash(s);
    }
    out
}

/// Hybrid computed-lookup code.
#[derive(Clone, Debug)]
pub struct HybridCode {
    l: u32,
    v: u32,
    /// log2 LUT entries.
    pub q: u32,
    /// `2^Q × V` lookup table (row-major).
    pub lut: Vec<f32>,
}

impl HybridCode {
    /// Build from an existing LUT (e.g. the one shipped in the AOT artifact
    /// manifest, so Rust and the Pallas kernel agree bit-for-bit).
    pub fn from_lut(l: u32, v: u32, q: u32, lut: Vec<f32>) -> Self {
        assert!(v == 1 || v == 2, "HYB supports V in {{1,2}}");
        assert!(q <= 14, "index bits must fit below bit 15");
        assert_eq!(lut.len(), (1usize << q) * v as usize);
        HybridCode { l, v, q, lut }
    }

    /// Initialize the LUT with k-means on an empirical i.i.d. Gaussian, folding the
    /// sign symmetry: the last component is trained on |g| since bit 15 mirrors it.
    /// Default training budget is modest; `train_with` exposes the knobs for the
    /// quality-critical benches (Table 1 / Table 5).
    pub fn train(l: u32, v: u32, q: u32, seed: u64) -> Self {
        let k = 1usize << q;
        Self::train_with(l, v, q, seed, (k * 48).max(4096), 25)
    }

    /// See [`Self::train`].
    pub fn train_with(l: u32, v: u32, q: u32, seed: u64, n_points: usize, iters: usize) -> Self {
        assert!(v == 1 || v == 2);
        let k = 1usize << q;
        let mut rng = Rng::new(seed ^ 0x9_71B);
        let dim = v as usize;
        let mut pts = Vec::with_capacity(n_points * dim);
        for _ in 0..n_points {
            for j in 0..dim {
                let g = rng.gauss_f32();
                // Fold the mirrored component into the positive half-space.
                pts.push(if j == dim - 1 { g.abs() } else { g });
            }
        }
        let km = kmeans(&pts, dim, k, iters, &mut rng);
        HybridCode::from_lut(l, v, q, km.centroids)
    }

    /// LUT index and sign flip for a state.
    #[inline(always)]
    pub fn index(&self, state: u32) -> (usize, bool) {
        let x = hash(state);
        let idx = ((x >> (15 - self.q)) & ((1 << self.q) - 1)) as usize;
        let flip = x & (1 << 15) != 0;
        (idx, flip)
    }
}

impl Code for HybridCode {
    fn l(&self) -> u32 {
        self.l
    }

    fn v(&self) -> u32 {
        self.v
    }

    fn name(&self) -> &'static str {
        "hyb"
    }

    #[inline]
    fn decode(&self, state: u32, out: &mut [f32]) {
        let (idx, flip) = self.index(state);
        let v = self.v as usize;
        let base = idx * v;
        out[..v].copy_from_slice(&self.lut[base..base + v]);
        if flip {
            out[v - 1] = -out[v - 1];
        }
    }
}

/// Registry entry for the HYB computed-lookup code (V∈{1,2}, 2^Q×V LUT).
pub struct HybMethod;

impl HybMethod {
    /// `q` is the first (and only) method param of a HYB spec.
    fn q(spec: &CodeSpec) -> u32 {
        spec.params()[0]
    }
}

impl QuantMethod for HybMethod {
    fn name(&self) -> &'static str {
        "hyb"
    }

    fn info(&self) -> MethodInfo {
        MethodInfo {
            name: "hyb",
            summary: "hybrid code: Klimov-Shamir hash indexes a sign-folded 2^Q x V LUT",
            v_options: &[1, 2],
            bits_min: 1,
            bits_max: 8,
            // Paper default Q=9, V=2 -> 2 KiB fp16 (bank-conflict-free in smem).
            default_table_bytes: (1usize << 9) * 2 * 2,
        }
    }

    fn preferred_v(&self) -> u32 {
        2
    }

    fn build(&'static self, cfg: &QtipConfig) -> Result<MethodBuild> {
        ensure!(cfg.v == 1 || cfg.v == 2, "hyb supports V in {{1,2}} (got V={})", cfg.v);
        // Paper §3.1.2 geometries: Q=9 at V=2 (2 KiB LUT), Q=6 at V=1 (ARM).
        let q = if cfg.v == 2 { 9 } else { 6 };
        let hc = HybridCode::train(cfg.l, cfg.v, q, cfg.seed);
        let spec = CodeSpec::new(self, cfg.v, vec![q], hc.lut.clone());
        Ok(MethodBuild { code: Box::new(hc), spec })
    }

    fn decode_state(&self, spec: &CodeSpec, state: u32, out: &mut [f32]) {
        let q = Self::q(spec);
        let lut = spec.table();
        let x = hash(state);
        let idx = ((x >> (15 - q)) & ((1 << q) - 1)) as usize;
        let vv = spec.v() as usize;
        out[..vv].copy_from_slice(&lut[idx * vv..(idx + 1) * vv]);
        if x & (1 << 15) != 0 {
            out[vv - 1] = -out[vv - 1];
        }
    }

    fn spec_to_json(&self, spec: &CodeSpec, sink: &mut dyn TableSink) -> Json {
        let lut_off = sink.put_f32s(spec.table());
        Json::obj(vec![
            ("method", Json::Str("hyb".into())),
            ("q", Json::Num(Self::q(spec) as f64)),
            ("v", Json::Num(spec.v() as f64)),
            ("lut_off", Json::Num(lut_off as f64)),
            ("lut_len", Json::Num(spec.table().len() as f64)),
        ])
    }

    fn spec_from_json(
        &'static self,
        j: &Json,
        src: &dyn TableSource,
        _trellis: &Trellis,
    ) -> Result<CodeSpec> {
        let q = j.req_usize("q") as u32;
        let v = j.req_usize("v") as u32;
        if q > 14 || !(1..=2).contains(&v) {
            bail!("hyb code spec out of range (q={q}, v={v})");
        }
        let lut_len = j.req_usize("lut_len");
        ensure!(
            lut_len == (1usize << q) * v as usize,
            "hyb LUT length {lut_len} does not match q={q}, v={v}"
        );
        let lut = src.f32s(j.req_usize("lut_off"), lut_len)?;
        Ok(CodeSpec::new(self, v, vec![q], lut))
    }

    fn run_kernel(&self, spec: &CodeSpec, call: KernelCall<'_>) {
        let q = Self::q(spec);
        let lut = spec.table();
        if spec.v() == 1 {
            call.run_v1(
                move |s| {
                    let x = hash(s);
                    let idx = ((x >> (15 - q)) & ((1 << q) - 1)) as usize;
                    let val = lut[idx];
                    if x & (1 << 15) != 0 {
                        -val
                    } else {
                        val
                    }
                },
                move |s: [u32; LANES]| {
                    let h = hash_lanes(s);
                    let mut out = [0.0f32; LANES];
                    for (o, &x) in out.iter_mut().zip(h.iter()) {
                        let idx = ((x >> (15 - q)) & ((1 << q) - 1)) as usize;
                        let val = lut[idx];
                        *o = if x & (1 << 15) != 0 { -val } else { val };
                    }
                    out
                },
            )
        } else {
            call.run_v2(
                move |s| {
                    let x = hash(s);
                    let idx = ((x >> (15 - q)) & ((1 << q) - 1)) as usize;
                    let a = lut[idx * 2];
                    let mut b = lut[idx * 2 + 1];
                    if x & (1 << 15) != 0 {
                        b = -b;
                    }
                    (a, b)
                },
                move |s: [u32; LANES]| {
                    let h = hash_lanes(s);
                    let mut a = [0.0f32; LANES];
                    let mut b = [0.0f32; LANES];
                    for ((av, bv), &x) in a.iter_mut().zip(b.iter_mut()).zip(h.iter()) {
                        let idx = ((x >> (15 - q)) & ((1 << q) - 1)) as usize;
                        *av = lut[idx * 2];
                        let mut second = lut[idx * 2 + 1];
                        if x & (1 << 15) != 0 {
                            second = -second;
                        }
                        *bv = second;
                    }
                    (a, b)
                },
            )
        }
    }

    fn synthetic_entry(&'static self, l: u32, k: u32, seed: u64) -> (Trellis, CodeSpec) {
        let hc = HybridCode::train(l, 2, 9, seed);
        (Trellis::new(l, k, 2), CodeSpec::new(self, 2, vec![9], hc.lut))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn hash_golden() {
        // Klimov–Shamir T-function, wrapping mod 2^32.
        assert_eq!(hash(0), 0);
        assert_eq!(hash(1), 2);
        assert_eq!(hash(7), 56);
        assert_eq!(hash(1000), 1_001_000);
    }

    #[test]
    fn lane_hash_matches_scalar() {
        for base in [0u32, 3, 65531, u32::MAX - 7] {
            let states: [u32; 8] = std::array::from_fn(|j| base.wrapping_add(j as u32));
            let lanes = hash_lanes(states);
            for (j, &s) in states.iter().enumerate() {
                assert_eq!(lanes[j], hash(s), "lane {j}");
            }
        }
    }

    #[test]
    fn hash_wraps() {
        // 0xFFFF^2 + 0xFFFF = 0xFFFE0001 + 0xFFFF = 0xFFFF0000 (mod 2^32)
        assert_eq!(hash(0xFFFF), 0xFFFF_0000);
        assert_eq!(hash(0x10000), 0x0001_0000); // 2^32 + 2^16 wraps to 2^16
    }

    #[test]
    fn index_uses_expected_bits() {
        let code = HybridCode::from_lut(16, 2, 9, vec![0.0; 512 * 2]);
        for s in [0u32, 3, 1234, 65535] {
            let x = hash(s);
            let (idx, flip) = code.index(s);
            assert_eq!(idx, ((x >> 6) & 0x1FF) as usize);
            assert_eq!(flip, x & 0x8000 != 0);
        }
    }

    #[test]
    fn sign_flip_mirrors_last_component() {
        let mut lut = vec![0.0f32; 512 * 2];
        for i in 0..512 {
            lut[i * 2] = i as f32;
            lut[i * 2 + 1] = 1.0;
        }
        let code = HybridCode::from_lut(16, 2, 9, lut);
        let mut out = [0.0f32; 2];
        let mut seen_flip = false;
        let mut seen_noflip = false;
        for s in 0..4096u32 {
            code.decode(s, &mut out);
            let (idx, flip) = code.index(s);
            assert_eq!(out[0], idx as f32);
            assert_eq!(out[1], if flip { -1.0 } else { 1.0 });
            seen_flip |= flip;
            seen_noflip |= !flip;
        }
        assert!(seen_flip && seen_noflip, "both branches must occur");
    }

    #[test]
    fn trained_lut_covers_gaussian() {
        let code = HybridCode::train(12, 2, 7, 7);
        // The effective codebook must be symmetric in its last component and cover
        // the bulk + tails of N(0, I_2). (Marginal std over *states* is > 1 by
        // design: k-means spaces entries ~density^(1/3).)
        let values = code.materialize();
        let xs: Vec<f32> = values.iter().step_by(2).copied().collect();
        let ys: Vec<f32> = values.iter().skip(1).step_by(2).copied().collect();
        assert!(stats::mean(&xs).abs() < 0.08);
        assert!(stats::mean(&ys).abs() < 0.08, "sign flip must re-center ys");
        for comp in [&xs, &ys] {
            let min = comp.iter().cloned().fold(f32::INFINITY, f32::min);
            let max = comp.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(min < -2.0 && max > 2.0, "component must cover tails");
        }
    }

    #[test]
    fn v1_arm_variant() {
        // §4.3: Q=6, V=1 HYB for ARM NEON table lookup.
        let code = HybridCode::train(16, 1, 6, 3);
        assert_eq!(code.lut.len(), 64);
        let values = code.materialize();
        assert!(stats::mean(&values).abs() < 0.06);
        // 64 half-entries mirrored: all of N(0,1)'s mass must be within reach.
        let max = values.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        assert!(max > 2.5 && -max < values.iter().cloned().fold(f32::INFINITY, f32::min) + 0.5);
    }

    #[test]
    fn quantizing_gaussian_with_hyb_beats_scalar() {
        // The effective 2^(Q+1) 2D codebook must beat 2-bit scalar Lloyd-Max MSE
        // when used with a trellis (smoke version of Table 1's HYB column).
        use crate::trellis::{Trellis, Viterbi, ViterbiWorkspace};
        use crate::util::rng::Rng;
        let code = HybridCode::train(12, 2, 9, 11);
        let values = code.materialize();
        let trellis = Trellis::new(12, 2, 2);
        let vit = Viterbi::new(trellis, &values);
        let mut rng = Rng::new(5);
        let seq = rng.gauss_vec(256);
        let mut ws = ViterbiWorkspace::new();
        let (states, _) = vit.quantize(&seq, None, None, &mut ws);
        let dec = vit.decode(&states);
        let mse = stats::mse(&dec, &seq);
        assert!(mse < 0.118, "HYB trellis MSE {mse} should beat scalar 0.118");
    }
}
