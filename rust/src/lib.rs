//! # QTIP: Quantization with Trellises and Incoherence Processing
//!
//! (full crate docs land with the remaining modules)
pub mod util;
pub mod trellis;
pub mod codes;
pub mod baselines;
pub mod quant;
pub mod model;
pub mod hessian;
pub mod io;
pub mod eval;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod cli;
