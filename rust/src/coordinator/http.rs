//! HTTP/1.1 front-end for the generation server: the same continuous batcher
//! as the raw TCP front-end (`coordinator::tcp`), spoken over plain HTTP with
//! Server-Sent Events for streaming.
//!
//! Routes:
//!   - `POST /v1/generate` — body is the same JSON object the TCP protocol
//!     takes (`prompt`, `max_new_tokens`, `temperature`, `top_k`, `seed`,
//!     `model`, `stream`). Without `"stream": true` the response is one JSON
//!     object (the TCP terminal object). With `"stream": true` the response is
//!     `text/event-stream`: one `data: {...}` event per generated token, then
//!     a terminal event with `"done": true` carrying the full response.
//!   - `GET /v1/models` — names of the served models (index 0 is the default
//!     route for requests that omit `"model"`).
//!   - `GET /health` — real readiness: per-lane healthy/active/queued state
//!     plus free KV blocks. 200 with `"status": "ok"` (or `"degraded"` when
//!     some lanes are poisoned but others still serve), 503 once every lane
//!     has failed or the serving thread stops answering probes.
//!   - `GET /v1/stats` — point-in-time [`super::server::ServerStats`]
//!     snapshot (throughput, shed/expiry counters, KV geometry).
//!
//! Status codes are derived from the stable `"code"` field every rejection
//! carries ([`super::server::codes`]): 200 on success, 400 for malformed
//! requests and budget rejections, 404 for unknown paths and unknown model
//! names, 408 when a request does not arrive within the read deadline
//! (slow-loris defense), 413 for oversized bodies, 429 when the lane's
//! admission queue is full, 503 for deadline-expired / lane-failed /
//! shutting-down rejections. SSE responses commit to 200 before generation
//! starts, so in-stream failures arrive as a terminal event with `"error"`
//! and `"code"` fields rather than a status code.
//!
//! Connections are `Connection: close` — one request per connection, no
//! keep-alive state machine. A client that disconnects mid-request is
//! detected exactly as on the TCP path (failed event write for streams,
//! socket probe for unary waits) and its request is cancelled so the
//! scheduler reclaims the KV blocks immediately.
//!
//! Start with `qtip serve --http 127.0.0.1:8080` or [`HttpFrontend::spawn`].

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::server::{codes, GenRequest, HealthSnapshot, ServerHandle, ServerStats, StreamEvent};
use super::tcp::{conn_closed, final_json, next_event, server_gone_json, Wait};
use crate::util::fault;
use crate::util::json::Json;

/// Parsing caps: a front door for generation requests, not a general web
/// server — anything larger than these is a malformed or hostile request.
const MAX_HEAD_BYTES: usize = 64 << 10;
const MAX_BODY_BYTES: usize = 1 << 20;

/// A complete request (head + declared body) must arrive within this window,
/// or the connection is answered 408 and closed. Bounds how long a slow-loris
/// client dribbling one byte at a time can pin a connection thread.
const READ_DEADLINE: std::time::Duration = std::time::Duration::from_secs(10);

pub struct HttpFrontend {
    pub addr: std::net::SocketAddr,
    /// Shutdown flag polled by the accept and connection loops. All its
    /// accesses are `Relaxed` (allowlisted in scripts/relaxed_allowlist.txt):
    /// it is a standalone stop signal — no other memory is published through
    /// it, and the loops re-check it within a bounded poll interval.
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl HttpFrontend {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve until dropped.
    pub fn spawn(server: Arc<ServerHandle>, addr: &str) -> Result<HttpFrontend> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let next_id = Arc::new(AtomicU64::new(0));
        let join = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let srv = server.clone();
                        let ids = next_id.clone();
                        let conn_stop = stop2.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, &srv, &ids, &conn_stop);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(HttpFrontend { addr: local, stop, join: Some(join) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for HttpFrontend {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

/// One parsed request: method, path, and the (possibly empty) body.
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
}

/// How reading one request off the socket ended. Only `Req` carries work;
/// the other arms map to a closed connection or a structured HTTP rejection
/// (413 / 408) — never a silent close for a request the server refused.
enum ReadOutcome {
    Req(HttpRequest),
    /// Peer closed (or frontend shutdown was requested) before a full
    /// request arrived: nothing to answer.
    Closed,
    /// Declared `Content-Length` (or the head itself) exceeds the parsing
    /// caps: answered 413 without reading the body off the wire.
    TooLarge,
    /// The request did not complete within `deadline` (slow-loris client):
    /// answered 408 and closed.
    TimedOut,
}

/// Read one HTTP/1.1 request off the socket. Bounded reads poll `stop` so
/// frontend shutdown never hangs on an idle connection, and the whole
/// request (head and body) must arrive within `deadline`.
fn read_request(
    stream: &mut TcpStream,
    stop: &AtomicBool,
    deadline: std::time::Duration,
) -> Result<ReadOutcome> {
    let started = std::time::Instant::now();
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    // Head: everything through the blank line.
    let head_end = loop {
        if let Some(pos) = find_seq(&buf, b"\r\n\r\n") {
            break pos + 4;
        }
        if stop.load(Ordering::Relaxed) {
            return Ok(ReadOutcome::Closed);
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Ok(ReadOutcome::TooLarge);
        }
        if started.elapsed() > deadline {
            return Ok(ReadOutcome::TimedOut);
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(ReadOutcome::Closed),
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap_or(0);
            }
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Ok(ReadOutcome::TooLarge);
    }
    // Body: whatever followed the head in `buf`, plus the rest off the wire.
    let mut body: Vec<u8> = buf[head_end..].to_vec();
    while body.len() < content_length {
        if stop.load(Ordering::Relaxed) {
            return Ok(ReadOutcome::Closed);
        }
        if started.elapsed() > deadline {
            return Ok(ReadOutcome::TimedOut);
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(ReadOutcome::Closed),
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
    body.truncate(content_length);
    Ok(ReadOutcome::Req(HttpRequest { method, path, body }))
}

fn find_seq(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Write a complete non-streaming response and finish the connection.
fn write_response(stream: &mut TcpStream, status: u16, reason: &str, body: &Json) -> Result<()> {
    write_response_extra(stream, status, reason, "", body)
}

/// [`write_response`] with extra header lines (each `"Name: value\r\n"`,
/// CRLF-terminated by the caller) — the 429 path uses this to attach
/// `Retry-After` without every other response paying for an allocation.
fn write_response_extra(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    extra_headers: &str,
    body: &Json,
) -> Result<()> {
    let payload = body.to_string();
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n{extra_headers}Connection: close\r\n\r\n{payload}",
        payload.len()
    )?;
    stream.flush()?;
    Ok(())
}

/// Status for a terminal response object, keyed on the stable `"code"` field
/// every rejection carries (never on the human-readable message): unknown
/// model is a routing failure (404), a full admission queue is backpressure
/// the client should retry (429), deadline/lane/shutdown failures are
/// server-side unavailability (503), everything else the client sent wrong
/// (400). Success is 200.
fn status_for(resp: &Json) -> (u16, &'static str) {
    if resp.get("error").is_none() {
        return (200, "OK");
    }
    match resp.get("code").and_then(|c| c.as_str()) {
        Some(c) if c == codes::UNKNOWN_MODEL => (404, "Not Found"),
        Some(c) if c == codes::QUEUE_FULL => (429, "Too Many Requests"),
        Some(c)
            if c == codes::DEADLINE_EXCEEDED
                || c == codes::LANE_FAILED
                || c == codes::SERVER_SHUTDOWN =>
        {
            (503, "Service Unavailable")
        }
        Some(c) if c == codes::PAYLOAD_TOO_LARGE => (413, "Payload Too Large"),
        Some(c) if c == codes::READ_TIMEOUT => (408, "Request Timeout"),
        _ => (400, "Bad Request"),
    }
}

/// `GET /health` body: overall status plus the per-lane readiness detail the
/// batcher reported. `"ok"` → every lane serving; `"degraded"` → some lanes
/// poisoned but the rest still serve (200 — the server is usable);
/// `"failed"` → no lane can make progress (503).
fn health_json(h: &HealthSnapshot) -> Json {
    let status = if h.all_failed() {
        "failed"
    } else if h.degraded() {
        "degraded"
    } else {
        "ok"
    };
    Json::obj(vec![
        ("status", Json::Str(status.into())),
        (
            "lanes",
            Json::Arr(
                h.lanes
                    .iter()
                    .map(|l| {
                        Json::obj(vec![
                            ("model", Json::Str(l.name.clone())),
                            ("healthy", Json::Bool(l.healthy)),
                            ("active", Json::Num(l.active as f64)),
                            ("queued", Json::Num(l.queued as f64)),
                            ("kv_blocks_free", Json::Num(l.kv_blocks_free as f64)),
                            ("kv_blocks_total", Json::Num(l.kv_blocks_total as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// `GET /v1/stats` body: the operationally interesting subset of
/// [`ServerStats`] — throughput, queueing, and the overload counters
/// (shed / expired / panicked) this endpoint exists to expose.
fn stats_json(s: &ServerStats) -> Json {
    Json::obj(vec![
        ("completed", Json::Num(s.completed as f64)),
        ("rejected", Json::Num(s.rejected as f64)),
        ("cancelled", Json::Num(s.cancelled as f64)),
        ("total_generated_tokens", Json::Num(s.total_generated_tokens as f64)),
        ("peak_active", Json::Num(s.peak_active as f64)),
        ("queue_high_water", Json::Num(s.queue_high_water as f64)),
        ("evictions", Json::Num(s.evictions as f64)),
        ("prefix_hits", Json::Num(s.prefix_hits as f64)),
        ("blocks_shared", Json::Num(s.blocks_shared as f64)),
        ("kv_blocks_total", Json::Num(s.kv_blocks_total as f64)),
        ("kv_blocks_high_water", Json::Num(s.kv_blocks_high_water as f64)),
        ("kv_layout", Json::Str(s.kv_layout.clone())),
        ("kernel", Json::Str(s.kernel.clone())),
        ("workers", Json::Num(s.workers as f64)),
        ("fused_rounds", Json::Num(s.fused_rounds as f64)),
        ("prefill_chunks", Json::Num(s.prefill_chunks as f64)),
        ("prefill_tokens_chunked", Json::Num(s.prefill_tokens_chunked as f64)),
        ("budget_deferrals", Json::Num(s.budget_deferrals as f64)),
        ("shed_queue_full", Json::Num(s.shed_queue_full as f64)),
        ("shed_slow_clients", Json::Num(s.shed_slow_clients as f64)),
        ("expired_queued", Json::Num(s.expired_queued as f64)),
        ("expired_running", Json::Num(s.expired_running as f64)),
        ("lane_panics", Json::Num(s.lane_panics as f64)),
        ("watchdog_stalls", Json::Num(s.watchdog_stalls as f64)),
    ])
}

fn handle_conn(
    mut stream: TcpStream,
    server: &ServerHandle,
    ids: &AtomicU64,
    stop: &AtomicBool,
) -> Result<()> {
    // Deterministic chaos hook (`QTIP_FAULT=<seed>:io_err=<rate>`): fail the
    // connection before any protocol work, exactly like a peer reset.
    if let Some(plan) = fault::global() {
        if plan.fire(fault::IO_ERR) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "injected frontend IO error",
            )
            .into());
        }
    }
    stream.set_nodelay(true).ok();
    // Bounded reads: a connection parked on an idle client must re-check the
    // stop flag periodically, or frontend shutdown would hang in join() on
    // every open socket and the server could never drain and report stats.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    // Slow-client backpressure: a peer that accepts the connection but stops
    // draining its socket blocks this connection thread, never the batcher —
    // and only for as long as the write timeout allows.
    stream.set_write_timeout(Some(std::time::Duration::from_secs(5)))?;
    let req = match read_request(&mut stream, stop, READ_DEADLINE)? {
        ReadOutcome::Req(r) => r,
        ReadOutcome::Closed => return Ok(()),
        ReadOutcome::TooLarge => {
            let body = Json::obj(vec![
                (
                    "error",
                    Json::Str(format!(
                        "request exceeds caps ({MAX_BODY_BYTES} byte body, {MAX_HEAD_BYTES} byte head)"
                    )),
                ),
                ("code", Json::Str(codes::PAYLOAD_TOO_LARGE.into())),
            ]);
            return write_response(&mut stream, 413, "Payload Too Large", &body);
        }
        ReadOutcome::TimedOut => {
            let body = Json::obj(vec![
                (
                    "error",
                    Json::Str(format!(
                        "request did not complete within {} ms",
                        READ_DEADLINE.as_millis()
                    )),
                ),
                ("code", Json::Str(codes::READ_TIMEOUT.into())),
            ]);
            return write_response(&mut stream, 408, "Request Timeout", &body);
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/generate") => serve_generate(&req.body, server, ids, &mut stream),
        ("GET", "/v1/models") => {
            let names = server.models();
            let body = Json::obj(vec![
                (
                    "models",
                    Json::Arr(names.iter().map(|n| Json::Str(n.clone())).collect()),
                ),
                ("default", Json::Str(names[0].clone())),
            ]);
            write_response(&mut stream, 200, "OK", &body)
        }
        ("GET", "/health") => match server.health() {
            Some(h) if h.all_failed() => {
                write_response(&mut stream, 503, "Service Unavailable", &health_json(&h))
            }
            Some(h) => write_response(&mut stream, 200, "OK", &health_json(&h)),
            None => {
                // The serving thread did not answer the probe: wedged or gone.
                let body = Json::obj(vec![
                    ("status", Json::Str("unavailable".into())),
                    (
                        "error",
                        Json::Str("health probe timed out: serving thread unresponsive".into()),
                    ),
                ]);
                write_response(&mut stream, 503, "Service Unavailable", &body)
            }
        },
        ("GET", "/v1/stats") => match server.stats_snapshot() {
            Some(s) => write_response(&mut stream, 200, "OK", &stats_json(&s)),
            None => {
                let body = Json::obj(vec![
                    (
                        "error",
                        Json::Str("stats probe timed out: serving thread unresponsive".into()),
                    ),
                    ("code", Json::Str(codes::SERVER_SHUTDOWN.into())),
                ]);
                write_response(&mut stream, 503, "Service Unavailable", &body)
            }
        },
        (method, path) => write_response(
            &mut stream,
            404,
            "Not Found",
            &Json::obj(vec![("error", Json::Str(format!("no route {method} {path}")))]),
        ),
    }
}

/// `POST /v1/generate`: parse the body, submit to the batcher, and relay the
/// result — unary JSON or an SSE stream. IO errors on `stream` (client gone)
/// cancel the in-flight request so the scheduler frees its KV blocks.
fn serve_generate(
    body: &[u8],
    server: &ServerHandle,
    ids: &AtomicU64,
    stream: &mut TcpStream,
) -> Result<()> {
    let id = ids.fetch_add(1, Ordering::Relaxed);
    let j = match std::str::from_utf8(body).ok().and_then(|s| Json::parse(s).ok()) {
        Some(j) => j,
        None => {
            let body = Json::obj(vec![
                ("id", Json::Num(id as f64)),
                ("error", Json::Str("bad request: body is not valid JSON".into())),
                ("code", Json::Str(codes::BAD_REQUEST.into())),
            ]);
            return write_response(stream, 400, "Bad Request", &body);
        }
    };
    let stream_mode = j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
    // Same defaults as the TCP protocol, so the two front-ends are
    // interchangeable for the smoke tests that compare their outputs.
    let req = GenRequest {
        id,
        prompt: j.get("prompt").and_then(|p| p.as_str()).unwrap_or("").to_string(),
        max_new_tokens: j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(32),
        temperature: j.get("temperature").and_then(|v| v.as_f64()).unwrap_or(0.7) as f32,
        top_k: j.get("top_k").and_then(|v| v.as_usize()).unwrap_or(40),
        seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(id as f64) as u64,
        model: j.get("model").and_then(|m| m.as_str()).unwrap_or("").to_string(),
        deadline_ms: j.get("deadline_ms").and_then(|v| v.as_usize()).unwrap_or(0) as u64,
    };

    if stream_mode {
        let rx = server.submit_stream(req);
        // Commit the SSE response before the first token: the body is
        // EOF-delimited (`Connection: close`), no chunked framing needed.
        write!(
            stream,
            "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
             Cache-Control: no-cache\r\nConnection: close\r\n\r\n"
        )?;
        stream.flush()?;
        loop {
            match next_event(&rx, stream) {
                Wait::Event(StreamEvent::Token { id, index, token, text }) => {
                    let ev = Json::obj(vec![
                        ("id", Json::Num(id as f64)),
                        ("index", Json::Num(index as f64)),
                        ("token", Json::Num(token as f64)),
                        ("text", Json::Str(text)),
                        ("done", Json::Bool(false)),
                    ]);
                    if write!(stream, "data: {ev}\n\n").is_err() || stream.flush().is_err() {
                        // Client vanished mid-stream: cancel so the scheduler
                        // frees the sequence's KV blocks this round.
                        server.cancel(id);
                        return Ok(());
                    }
                }
                Wait::Event(StreamEvent::Done(r)) => {
                    let mut resp = final_json(r);
                    if let Json::Obj(map) = &mut resp {
                        map.insert("done".to_string(), Json::Bool(true));
                    }
                    write!(stream, "data: {resp}\n\n")?;
                    stream.flush()?;
                    return Ok(());
                }
                Wait::PeerGone => {
                    server.cancel(id);
                    return Ok(());
                }
                Wait::ServerGone => {
                    let mut resp = server_gone_json(id);
                    if let Json::Obj(map) = &mut resp {
                        map.insert("done".to_string(), Json::Bool(true));
                    }
                    write!(stream, "data: {resp}\n\n")?;
                    stream.flush()?;
                    return Ok(());
                }
            }
        }
    }

    let rx = server.submit(req);
    let resp = match next_event(&rx, stream) {
        Wait::Event(r) => final_json(r),
        Wait::PeerGone => {
            server.cancel(id);
            return Ok(());
        }
        Wait::ServerGone => server_gone_json(id),
    };
    let (status, reason) = status_for(&resp);
    // queue_full backpressure: mirror the response's retry_after_ms hint as a
    // standard `Retry-After` header (whole seconds, rounded up) so plain HTTP
    // clients and proxies can honor it without parsing the body.
    if status == 429 {
        if let Some(ms) = resp.get("retry_after_ms").and_then(|v| v.as_usize()) {
            let secs = ms.div_ceil(1000).max(1);
            let extra = format!("Retry-After: {secs}\r\n");
            return write_response_extra(stream, status, reason, &extra, &resp);
        }
    }
    write_response(stream, status, reason, &resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::model::{ModelConfig, Transformer, WeightStore};

    fn tiny_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 64;
        cfg
    }

    fn model_with_seed(seed: u64) -> Arc<Transformer> {
        Arc::new(Transformer::from_store(&WeightStore::random(&tiny_cfg(), seed)))
    }

    fn tiny_server() -> Arc<ServerHandle> {
        Arc::new(ServerHandle::spawn(model_with_seed(3), ServerConfig::default()))
    }

    /// Minimal HTTP client: one request, full response (head + body) as text.
    fn http(addr: std::net::SocketAddr, method: &str, path: &str, body: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(
            s,
            "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    fn status_of(resp: &str) -> u16 {
        resp.split_whitespace().nth(1).unwrap().parse().unwrap()
    }

    fn body_of(resp: &str) -> Json {
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        Json::parse(body).unwrap()
    }

    #[test]
    fn http_generate_matches_tcp_protocol_shape() {
        let fe = HttpFrontend::spawn(tiny_server(), "127.0.0.1:0").unwrap();
        let resp = http(
            fe.addr,
            "POST",
            "/v1/generate",
            r#"{"prompt": "hello", "max_new_tokens": 6, "temperature": 0, "top_k": 1}"#,
        );
        assert_eq!(status_of(&resp), 200, "{resp}");
        let j = body_of(&resp);
        assert_eq!(j.get("tokens").unwrap().as_usize(), Some(6));
        assert!(j.get("text").unwrap().as_str().is_some());
        assert!(j.get("tok_per_sec").unwrap().as_f64().unwrap() > 0.0);
        fe.shutdown();
    }

    #[test]
    fn http_sse_streams_tokens_then_done_matching_unary() {
        let fe = HttpFrontend::spawn(tiny_server(), "127.0.0.1:0").unwrap();
        let req = r#"{"prompt": "s", "max_new_tokens": 5, "temperature": 0, "top_k": 1, "seed": 9}"#;
        let unary = body_of(&http(fe.addr, "POST", "/v1/generate", req));
        let want_text = unary.get("text").unwrap().as_str().unwrap().to_string();

        let streaming = req.trim_end_matches('}').to_string() + r#", "stream": true}"#;
        let resp = http(fe.addr, "POST", "/v1/generate", &streaming);
        assert!(resp.contains("Content-Type: text/event-stream"), "{resp}");
        let events: Vec<Json> = resp
            .lines()
            .filter_map(|l| l.strip_prefix("data: "))
            .map(|d| Json::parse(d).unwrap())
            .collect();
        assert_eq!(events.len(), 6, "5 token events + terminal: {resp}");
        for (i, ev) in events[..5].iter().enumerate() {
            assert_eq!(ev.get("index").unwrap().as_usize(), Some(i));
            assert!(ev.get("token").unwrap().as_usize().unwrap() < 256, "byte-vocab token");
        }
        let done = &events[5];
        assert_eq!(done.get("done").unwrap().as_bool(), Some(true));
        assert_eq!(done.get("tokens").unwrap().as_usize(), Some(5));
        assert_eq!(done.get("text").unwrap().as_str().unwrap(), want_text);
        fe.shutdown();
    }

    #[test]
    fn http_models_and_health_and_404() {
        let server = Arc::new(ServerHandle::spawn_multi(
            vec![
                ("alpha".to_string(), model_with_seed(3)),
                ("beta".to_string(), model_with_seed(99)),
            ],
            ServerConfig::default(),
        ));
        let fe = HttpFrontend::spawn(server, "127.0.0.1:0").unwrap();

        let resp = http(fe.addr, "GET", "/v1/models", "");
        assert_eq!(status_of(&resp), 200);
        let j = body_of(&resp);
        let names: Vec<&str> =
            j.get("models").unwrap().as_arr().unwrap().iter().filter_map(|m| m.as_str()).collect();
        assert_eq!(names, ["alpha", "beta"]);
        assert_eq!(j.get("default").unwrap().as_str(), Some("alpha"));

        let health = http(fe.addr, "GET", "/health", "");
        assert_eq!(status_of(&health), 200);
        let h = body_of(&health);
        assert_eq!(h.get("status").unwrap().as_str(), Some("ok"));
        let lanes = h.get("lanes").unwrap().as_arr().unwrap();
        assert_eq!(lanes.len(), 2, "one health entry per lane: {health}");
        for lane in lanes {
            assert_eq!(lane.get("healthy").unwrap().as_bool(), Some(true));
            assert!(lane.get("kv_blocks_free").unwrap().as_usize().unwrap() > 0);
        }

        let missing = http(fe.addr, "GET", "/nope", "");
        assert_eq!(status_of(&missing), 404);
        fe.shutdown();
    }

    #[test]
    fn http_routes_models_and_rejects_unknown_with_404() {
        let server = Arc::new(ServerHandle::spawn_multi(
            vec![
                ("alpha".to_string(), model_with_seed(3)),
                ("beta".to_string(), model_with_seed(99)),
            ],
            ServerConfig::default(),
        ));
        let fe = HttpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let gen = |model: &str| {
            let body = format!(
                r#"{{"prompt": "h", "max_new_tokens": 6, "temperature": 0, "model": "{model}"}}"#
            );
            http(fe.addr, "POST", "/v1/generate", &body)
        };
        let a = gen("alpha");
        let b = gen("beta");
        assert_eq!(status_of(&a), 200);
        assert_eq!(status_of(&b), 200);
        assert_ne!(
            body_of(&a).get("text").unwrap().as_str(),
            body_of(&b).get("text").unwrap().as_str(),
            "different weights must generate differently"
        );
        let bad = gen("gamma");
        assert_eq!(status_of(&bad), 404, "{bad}");
        let err = body_of(&bad).get("error").unwrap().as_str().unwrap().to_string();
        assert!(err.contains("unknown model 'gamma'"), "{err}");
        assert!(err.contains("alpha") && err.contains("beta"), "{err}");
        fe.shutdown();
    }

    #[test]
    fn http_bad_json_is_400() {
        let fe = HttpFrontend::spawn(tiny_server(), "127.0.0.1:0").unwrap();
        let resp = http(fe.addr, "POST", "/v1/generate", "{not json");
        assert_eq!(status_of(&resp), 400);
        let j = body_of(&resp);
        assert!(j.get("error").is_some());
        assert_eq!(j.get("code").unwrap().as_str(), Some(codes::BAD_REQUEST));
        fe.shutdown();
    }

    #[test]
    fn http_unknown_model_carries_code_and_404() {
        let fe = HttpFrontend::spawn(tiny_server(), "127.0.0.1:0").unwrap();
        let resp = http(
            fe.addr,
            "POST",
            "/v1/generate",
            r#"{"prompt": "x", "max_new_tokens": 2, "model": "nope"}"#,
        );
        assert_eq!(status_of(&resp), 404, "{resp}");
        assert_eq!(body_of(&resp).get("code").unwrap().as_str(), Some(codes::UNKNOWN_MODEL));
        fe.shutdown();
    }

    #[test]
    fn http_oversized_content_length_is_413_not_silent_close() {
        let fe = HttpFrontend::spawn(tiny_server(), "127.0.0.1:0").unwrap();
        // Declare a body over MAX_BODY_BYTES without sending it: the server
        // must answer 413 off the head alone, not hang waiting or just close.
        let mut s = TcpStream::connect(fe.addr).unwrap();
        write!(
            s,
            "POST /v1/generate HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n",
            MAX_BODY_BYTES + 1
        )
        .unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        assert_eq!(status_of(&out), 413, "{out}");
        assert_eq!(body_of(&out).get("code").unwrap().as_str(), Some(codes::PAYLOAD_TOO_LARGE));
        fe.shutdown();
    }

    #[test]
    fn read_request_times_out_on_slow_loris() {
        // Unit-level: a client that sends a partial head and then stalls must
        // hit ReadOutcome::TimedOut once the deadline passes, not pin the
        // connection thread forever. Exercised directly so the test can use a
        // short deadline instead of the production READ_DEADLINE.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (mut server_side, _) = listener.accept().unwrap();
        write!(client, "POST /v1/generate HTT").unwrap();
        client.flush().unwrap();
        server_side
            .set_read_timeout(Some(std::time::Duration::from_millis(10)))
            .unwrap();
        let stop = AtomicBool::new(false);
        let out =
            read_request(&mut server_side, &stop, std::time::Duration::from_millis(60)).unwrap();
        assert!(matches!(out, ReadOutcome::TimedOut), "partial head must time out");
        drop(client);
    }

    #[test]
    fn http_stats_endpoint_reports_serving_counters() {
        let fe = HttpFrontend::spawn(tiny_server(), "127.0.0.1:0").unwrap();
        let gen = http(
            fe.addr,
            "POST",
            "/v1/generate",
            r#"{"prompt": "x", "max_new_tokens": 3, "temperature": 0}"#,
        );
        assert_eq!(status_of(&gen), 200);
        let resp = http(fe.addr, "GET", "/v1/stats", "");
        assert_eq!(status_of(&resp), 200, "{resp}");
        let j = body_of(&resp);
        assert!(j.get("completed").unwrap().as_usize().unwrap() >= 1, "{resp}");
        assert_eq!(j.get("shed_queue_full").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("lane_panics").unwrap().as_usize(), Some(0));
        assert!(j.get("kv_layout").unwrap().as_str().is_some());
        fe.shutdown();
    }

    #[test]
    fn http_shutdown_drains_with_idle_connection_open() {
        let fe = HttpFrontend::spawn(tiny_server(), "127.0.0.1:0").unwrap();
        let idle = TcpStream::connect(fe.addr).unwrap();
        let resp = http(
            fe.addr,
            "POST",
            "/v1/generate",
            r#"{"prompt": "x", "max_new_tokens": 2, "temperature": 0}"#,
        );
        assert_eq!(status_of(&resp), 200);
        let t = std::time::Instant::now();
        fe.shutdown();
        assert!(
            t.elapsed() < std::time::Duration::from_secs(5),
            "shutdown hung on an idle connection"
        );
        drop(idle);
    }
}
