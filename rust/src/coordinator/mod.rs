//! Layer-3 coordinator: the quantization job pipeline (`pipeline`) and the
//! batched generation server (`server`). Rust owns the event loop, process
//! topology, and metrics; compiled XLA artifacts and the native fused decoder do
//! the math.

pub mod http;
pub mod pipeline;
pub mod server;
pub mod tcp;

pub use http::HttpFrontend;
pub use pipeline::{
    layer_seed, quantize_model_baseline, quantize_model_qtip, LayerReport, QuantizeReport,
};
pub use server::{
    codes, GenError, GenRequest, GenResponse, HealthSnapshot, LaneHealth, ServerConfig,
    ServerHandle, ServerStats, StreamEvent,
};
pub use tcp::TcpFrontend;
