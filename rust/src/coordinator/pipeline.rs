//! The quantization job pipeline: per-layer jobs (RHT → BlockLDLQ(TCQ) → pack)
//! fanned across workers, with progress reporting and per-layer metrics. This is
//! what `qtip quantize` runs and what the perplexity benches call.

use anyhow::{bail, Context, Result};

use crate::hessian::HessianSet;
use crate::model::transformer::{Linear, Transformer};
use crate::quant::{
    quantize_matrix_baseline, quantize_matrix_qtip, BaselineKind, QtipConfig, QuantMetrics,
};
use crate::util::json::Json;
use crate::util::matrix::Matrix;
use crate::util::threadpool::ExecPool;
use crate::util::Timer;

/// Derive a per-layer quantization seed from the run's global seed.
///
/// Both pipelines (QTIP and the baselines) must mix the layer index through
/// `mix64` before XOR-ing: a plain `seed ^ i` leaves layer 0 with the raw
/// global seed and gives adjacent layers nearly-correlated RHT sign patterns,
/// which defeats the independence the incoherence argument assumes.
pub fn layer_seed(global: u64, layer_index: usize) -> u64 {
    global ^ crate::util::rng::mix64(layer_index as u64 + 1)
}

/// Per-layer outcome.
#[derive(Clone, Debug)]
pub struct LayerReport {
    pub name: String,
    pub rows: usize,
    pub cols: usize,
    pub bytes_before: usize,
    pub bytes_after: usize,
    pub metrics: QuantMetrics,
}

impl LayerReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("rows", Json::Num(self.rows as f64)),
            ("cols", Json::Num(self.cols as f64)),
            ("bytes_before", Json::Num(self.bytes_before as f64)),
            ("bytes_after", Json::Num(self.bytes_after as f64)),
            ("metrics", self.metrics.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> LayerReport {
        LayerReport {
            name: j.req_str("name").to_string(),
            rows: j.req_usize("rows"),
            cols: j.req_usize("cols"),
            bytes_before: j.req_usize("bytes_before"),
            bytes_after: j.req_usize("bytes_after"),
            metrics: QuantMetrics::from_json(
                j.get("metrics").expect("layer report missing 'metrics'"),
            ),
        }
    }
}

/// Whole-model quantization outcome.
#[derive(Clone, Debug)]
pub struct QuantizeReport {
    pub layers: Vec<LayerReport>,
    pub seconds: f64,
    pub bytes_before: usize,
    pub bytes_after: usize,
}

impl QuantizeReport {
    pub fn mean_relative_proxy(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        self.layers.iter().map(|l| l.metrics.relative_proxy).sum::<f64>()
            / self.layers.len() as f64
    }

    pub fn compression_ratio(&self) -> f64 {
        self.bytes_before as f64 / self.bytes_after.max(1) as f64
    }

    /// Manifest form: saved inside quantized artifacts (see `crate::io`) so a
    /// cold-started server reports the same compression/metric summary as the
    /// run that produced the artifact.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("layers", Json::Arr(self.layers.iter().map(|l| l.to_json()).collect())),
            ("seconds", Json::Num(self.seconds)),
            ("bytes_before", Json::Num(self.bytes_before as f64)),
            ("bytes_after", Json::Num(self.bytes_after as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> QuantizeReport {
        let layers = j
            .get("layers")
            .and_then(|l| l.as_arr())
            .map(|arr| arr.iter().map(LayerReport::from_json).collect())
            .unwrap_or_default();
        QuantizeReport {
            layers,
            seconds: j.req_f64("seconds"),
            bytes_before: j.req_usize("bytes_before"),
            bytes_after: j.req_usize("bytes_after"),
        }
    }
}

/// Quantize every decoder linear of `model` in place with QTIP.
/// Per-layer jobs fan out across `pool` (sequential when its width is 1, as
/// on the single-core CI machine). Results are independent of pool width:
/// each job is a pure function of its (weight, Hessian, per-layer seed).
///
/// Errors (already-quantized layer, missing Hessian, a layer the fan-out
/// never produced) name the offending layer instead of panicking, so the
/// serving coordinator can surface them as structured failures.
pub fn quantize_model_qtip(
    model: &mut Transformer,
    hessians: &HessianSet,
    cfg: &QtipConfig,
    pool: &ExecPool,
    mut progress: impl FnMut(&LayerReport),
) -> Result<QuantizeReport> {
    let timer = Timer::start();
    // Snapshot job inputs.
    let jobs: Vec<(String, Matrix, Matrix)> = {
        let linears = model.linears_mut();
        let mut jobs = Vec::with_capacity(linears.len());
        for (name, lin) in &linears {
            let w = match lin {
                Linear::Dense(w) => (*w).clone(),
                _ => bail!("layer '{name}' is already quantized"),
            };
            let h = hessians
                .by_layer
                .get(name)
                .with_context(|| format!("no Hessian collected for layer '{name}'"))?
                .clone();
            jobs.push((name.clone(), w, h));
        }
        jobs
    };

    // Fan the per-layer jobs across the pool; `map` writes each result into
    // its order-indexed slot directly (no Mutex per slot).
    let results = pool.map(jobs.len(), |i| {
        let (name, w, h) = &jobs[i];
        // Derive a per-layer seed so RHT signs differ across layers.
        let mut layer_cfg = cfg.clone();
        layer_cfg.seed = layer_seed(cfg.seed, i);
        let res = quantize_matrix_qtip(w, h, &layer_cfg);
        let before = w.data.len() * 4;
        (name.clone(), res, before)
    });

    // Install quantized layers + collect reports.
    let mut reports = Vec::new();
    let mut by_name = std::collections::BTreeMap::new();
    for (name, res, before) in results {
        let report = LayerReport {
            name: name.clone(),
            rows: res.qm.rows,
            cols: res.qm.cols,
            bytes_before: before,
            bytes_after: res.qm.size_bytes(),
            metrics: res.metrics,
        };
        progress(&report);
        reports.push(report);
        by_name.insert(name, res.qm);
    }
    for (name, lin) in model.linears_mut() {
        let Some(qm) = by_name.remove(&name) else {
            bail!("quantization pipeline produced no result for layer '{name}'");
        };
        *lin = Linear::Quantized { qm, cache: None };
    }

    let bytes_before: usize = reports.iter().map(|r| r.bytes_before).sum();
    let bytes_after: usize = reports.iter().map(|r| r.bytes_after).sum();
    Ok(QuantizeReport { layers: reports, seconds: timer.secs(), bytes_before, bytes_after })
}

/// Quantize with a baseline inner rounder (dense reconstructions installed —
/// baselines are quality comparators, not serving paths).
pub fn quantize_model_baseline(
    model: &mut Transformer,
    hessians: &HessianSet,
    kind: &BaselineKind,
    seed: u64,
    pool: &ExecPool,
) -> Result<QuantizeReport> {
    let timer = Timer::start();
    let jobs: Vec<(String, Matrix, Matrix)> = {
        let linears = model.linears_mut();
        let mut jobs = Vec::with_capacity(linears.len());
        for (name, lin) in &linears {
            let w = match lin {
                Linear::Dense(w) => (*w).clone(),
                _ => bail!("layer '{name}' is already quantized"),
            };
            let h = hessians
                .by_layer
                .get(name)
                .with_context(|| format!("no Hessian collected for layer '{name}'"))?
                .clone();
            jobs.push((name.clone(), w, h));
        }
        jobs
    };
    let results = pool.map(jobs.len(), |i| {
        let (name, w, h) = &jobs[i];
        let res = quantize_matrix_baseline(w, h, kind, layer_seed(seed, i));
        let w_hat = res.reconstruct_w();
        (name.clone(), w_hat, res.metrics, w.data.len() * 4)
    });

    let mut reports = Vec::new();
    let mut by_name = std::collections::BTreeMap::new();
    for (name, w_hat, metrics, before) in results {
        // Baseline storage estimate: k bits/weight.
        let after = (w_hat.data.len() as f64 * metrics.bits_per_weight / 8.0) as usize;
        reports.push(LayerReport {
            name: name.clone(),
            rows: w_hat.rows,
            cols: w_hat.cols,
            bytes_before: before,
            bytes_after: after,
            metrics,
        });
        by_name.insert(name, w_hat);
    }
    for (name, lin) in model.linears_mut() {
        let Some(w_hat) = by_name.remove(&name) else {
            bail!("baseline pipeline produced no result for layer '{name}'");
        };
        *lin = Linear::Dense(w_hat);
    }
    let bytes_before: usize = reports.iter().map(|r| r.bytes_before).sum();
    let bytes_after: usize = reports.iter().map(|r| r.bytes_after).sum();
    Ok(QuantizeReport { layers: reports, seconds: timer.secs(), bytes_before, bytes_after })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hessian::collect_hessians;
    use crate::model::{ModelConfig, Transformer, WeightStore};

    fn tiny() -> Transformer {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 32;
        Transformer::from_store(&WeightStore::random(&cfg, 5))
    }

    fn tiny_cfg() -> QtipConfig {
        QtipConfig { l: 10, k: 2, v: 1, tx: 8, ty: 8, code: "3inst".into(), seed: 3 }
    }

    #[test]
    fn quantizes_whole_model() {
        let mut model = tiny();
        let seqs = vec![vec![1u16, 5, 9, 13, 17, 21, 25, 29]];
        let hs = collect_hessians(&model, &seqs);
        let mut n = 0;
        let report =
            quantize_model_qtip(&mut model, &hs, &tiny_cfg(), &ExecPool::sequential(), |_| n += 1)
                .unwrap();
        assert_eq!(report.layers.len(), 7); // q,k,v,o,gate,up,down × 1 layer
        assert_eq!(n, 7);
        assert!(report.compression_ratio() > 8.0, "{}", report.compression_ratio());
        // Model must still run (batch path needs caches).
        model.ensure_caches();
        let logits = model.forward_batch(&[1, 2, 3]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
        // And the decode path.
        let mut cache = crate::model::KvCache::new(&model.cfg);
        let l = model.decode_step(&mut cache, 7);
        assert!(l.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_model_stays_close_to_dense() {
        let mut model = tiny();
        let dense_logits = model.forward_batch(&[10, 20, 30, 40]);
        let seqs = vec![
            vec![10u16, 20, 30, 40, 50, 60, 70, 80],
            vec![3u16, 1, 4, 1, 5, 9, 2, 6],
        ];
        let hs = collect_hessians(&model, &seqs);
        let mut cfg = tiny_cfg();
        cfg.k = 4; // 4-bit: near-lossless regime
        quantize_model_qtip(&mut model, &hs, &cfg, &ExecPool::sequential(), |_| {}).unwrap();
        model.ensure_caches();
        let q_logits = model.forward_batch(&[10, 20, 30, 40]);
        // Compare softmax-ish behaviour: logits should be highly correlated.
        let corr = crate::util::stats::pearson(&dense_logits.data, &q_logits.data);
        assert!(corr > 0.95, "4-bit quantization wrecked the model: corr {corr}");
    }

    #[test]
    fn layer_seed_is_mixed_and_distinct() {
        // Regression: the baseline pipeline used `seed ^ i`, so layer 0 ran on
        // the raw global seed and adjacent layers differed by one bit.
        let global = 0x5171_50u64;
        assert_ne!(layer_seed(global, 0), global, "layer 0 must not reuse the global seed");
        let seeds: Vec<u64> = (0..16).map(|i| layer_seed(global, i)).collect();
        for i in 0..seeds.len() {
            for j in i + 1..seeds.len() {
                assert_ne!(seeds[i], seeds[j], "layers {i} and {j} share a seed");
            }
        }
        // Adjacent seeds must differ in many bits, not one (mix64 avalanche).
        for w in seeds.windows(2) {
            let dist = (w[0] ^ w[1]).count_ones();
            assert!(dist >= 16, "adjacent layer seeds nearly correlated ({dist} bits)");
        }
    }

    #[test]
    fn per_layer_rht_signs_differ() {
        use crate::quant::RhtContext;
        let global = 7u64;
        let a = RhtContext::new(16, 16, layer_seed(global, 0));
        let b = RhtContext::new(16, 16, layer_seed(global, 1));
        let c = RhtContext::new(16, 16, layer_seed(global, 2));
        assert_ne!(a.sign_cols, b.sign_cols, "layers 0/1 share RHT column signs");
        assert_ne!(b.sign_cols, c.sign_cols, "layers 1/2 share RHT column signs");
        assert_ne!(a.sign_rows, b.sign_rows, "layers 0/1 share RHT row signs");
    }

    #[test]
    fn quantize_report_json_roundtrip() {
        let report = QuantizeReport {
            layers: vec![LayerReport {
                name: "l0.q".into(),
                rows: 128,
                cols: 128,
                bytes_before: 65536,
                bytes_after: 4212,
                metrics: QuantMetrics {
                    relative_proxy: 0.015625,
                    mse: 0.09375,
                    bits_per_weight: 2.0,
                    seconds: 0.25,
                },
            }],
            seconds: 1.25,
            bytes_before: 65536,
            bytes_after: 4212,
        };
        let text = report.to_json().to_string();
        let back = QuantizeReport::from_json(&Json::parse(&text).unwrap());
        assert_eq!(back.layers.len(), 1);
        assert_eq!(back.layers[0].name, "l0.q");
        assert_eq!(back.layers[0].bytes_after, 4212);
        assert_eq!(back.layers[0].metrics.mse, 0.09375);
        assert_eq!(back.bytes_before, report.bytes_before);
        assert_eq!(back.compression_ratio(), report.compression_ratio());
    }

    #[test]
    fn quantization_is_pool_width_invariant() {
        // Per-layer jobs are pure functions of (weight, Hessian, layer seed):
        // the packed artifacts must be byte-identical whether the pipeline
        // fans out over 1 worker or 4.
        let seqs = vec![vec![1u16, 5, 9, 13, 17, 21, 25, 29]];
        let quantize = |pool: &ExecPool| {
            let mut model = tiny();
            let hs = collect_hessians(&model, &seqs);
            quantize_model_qtip(&mut model, &hs, &tiny_cfg(), pool, |_| {}).unwrap();
            model
        };
        let a = quantize(&ExecPool::sequential());
        let b = quantize(&ExecPool::new(4));
        for ((name, la), (_, lb)) in a.linears().iter().zip(b.linears().iter()) {
            let (
                crate::model::transformer::Linear::Quantized { qm: qa, .. },
                crate::model::transformer::Linear::Quantized { qm: qb, .. },
            ) = (la, lb)
            else {
                panic!("expected quantized layers");
            };
            assert_eq!(qa.packed, qb.packed, "{name}: packed bits depend on pool width");
            assert_eq!(qa.scale.to_bits(), qb.scale.to_bits(), "{name}: scale differs");
        }
    }

    #[test]
    fn baseline_pipeline_installs_dense() {
        let mut model = tiny();
        let seqs = vec![vec![2u16, 4, 6, 8, 10, 12, 14, 16]];
        let hs = collect_hessians(&model, &seqs);
        let report = quantize_model_baseline(
            &mut model,
            &hs,
            &BaselineKind::Scalar { k: 2 },
            1,
            &ExecPool::sequential(),
        )
        .unwrap();
        assert_eq!(report.layers.len(), 7);
        let logits = model.forward_batch(&[5, 6]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn missing_hessian_errors_with_layer_name() {
        // Regression: an incomplete Hessian set used to panic deep inside the
        // fan-out; it must surface as an Err naming the layer instead.
        let mut model = tiny();
        let seqs = vec![vec![1u16, 5, 9, 13, 17, 21, 25, 29]];
        let mut hs = collect_hessians(&model, &seqs);
        hs.by_layer.remove("l0.gate");
        let err =
            quantize_model_qtip(&mut model, &hs, &tiny_cfg(), &ExecPool::sequential(), |_| {})
                .unwrap_err()
                .to_string();
        assert!(err.contains("l0.gate"), "error must name the missing layer: {err}");
    }

    #[test]
    fn already_quantized_model_errors() {
        let mut model = tiny();
        let seqs = vec![vec![1u16, 5, 9, 13, 17, 21, 25, 29]];
        let hs = collect_hessians(&model, &seqs);
        quantize_model_qtip(&mut model, &hs, &tiny_cfg(), &ExecPool::sequential(), |_| {})
            .unwrap();
        let err =
            quantize_model_qtip(&mut model, &hs, &tiny_cfg(), &ExecPool::sequential(), |_| {})
                .unwrap_err()
                .to_string();
        assert!(err.contains("already quantized"), "{err}");
    }
}
