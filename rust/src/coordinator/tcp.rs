//! TCP front-end for the generation server: newline-delimited JSON over a
//! socket, one connection per client, requests multiplexed into the shared
//! continuous batcher.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "fn main", "max_new_tokens": 32, "temperature": 0.7,
//!       "top_k": 40, "seed": 1}
//!   <- {"id": 0, "text": "...", "tokens": 32, "ttft_ms": 12.1,
//!       "tok_per_sec": 154.2}
//!
//! Start with `qtip serve --tcp 127.0.0.1:7171` or [`TcpFrontend::spawn`].

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::server::{GenRequest, ServerHandle};
use crate::util::json::Json;

pub struct TcpFrontend {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl TcpFrontend {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve until dropped.
    pub fn spawn(server: Arc<ServerHandle>, addr: &str) -> Result<TcpFrontend> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let next_id = Arc::new(AtomicU64::new(0));
        let join = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let srv = server.clone();
                        let ids = next_id.clone();
                        let conn_stop = stop2.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, &srv, &ids, &conn_stop);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(TcpFrontend { addr: local, stop, join: Some(join) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TcpFrontend {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn handle_conn(
    stream: TcpStream,
    server: &ServerHandle,
    ids: &AtomicU64,
    stop: &AtomicBool,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    // Bounded reads: a connection parked on an idle client must re-check the
    // stop flag periodically, or frontend shutdown would hang in join() on
    // every open socket and the server could never drain and report stats.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Accumulate raw bytes, not a String: read_line's UTF-8 guard discards
    // already-consumed bytes when a timeout lands mid multi-byte character;
    // read_until keeps everything appended across retries.
    let mut line: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match reader.read_until(b'\n', &mut line) {
            Ok(0) => {
                // Client closed. A timeout may have parked an unterminated
                // final request in `line` — serve it before hanging up.
                let text = String::from_utf8_lossy(&line);
                let trimmed = text.trim();
                if !trimmed.is_empty() {
                    let resp = respond(trimmed, server, ids);
                    writeln!(writer, "{resp}")?;
                }
                return Ok(());
            }
            Ok(_) => {
                let eof_tail = line.last() != Some(&b'\n');
                let text = String::from_utf8_lossy(&line);
                let trimmed = text.trim();
                if !trimmed.is_empty() {
                    let resp = respond(trimmed, server, ids);
                    writeln!(writer, "{resp}")?;
                }
                line.clear();
                if eof_tail {
                    return Ok(());
                }
            }
            // Timeout (named WouldBlock or TimedOut depending on platform):
            // the partial line stays buffered; poll the stop flag again.
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
}

fn respond(line: &str, server: &ServerHandle, ids: &AtomicU64) -> Json {
    let id = ids.fetch_add(1, Ordering::Relaxed);
    match Json::parse(line) {
        Ok(j) => {
            let req = GenRequest {
                id,
                prompt: j.get("prompt").and_then(|p| p.as_str()).unwrap_or("").to_string(),
                max_new_tokens: j
                    .get("max_new_tokens")
                    .and_then(|v| v.as_usize())
                    .unwrap_or(32),
                temperature: j
                    .get("temperature")
                    .and_then(|v| v.as_f64())
                    .unwrap_or(0.7) as f32,
                top_k: j.get("top_k").and_then(|v| v.as_usize()).unwrap_or(40),
                seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(id as f64) as u64,
            };
            match server.submit(req).recv() {
                Ok(r) => {
                    if let Some(err) = r.error {
                        // Rejected at admission (e.g. KV cache above the budget).
                        Json::obj(vec![
                            ("id", Json::Num(r.id as f64)),
                            ("error", Json::Str(err)),
                        ])
                    } else {
                        Json::obj(vec![
                            ("id", Json::Num(r.id as f64)),
                            ("text", Json::Str(r.text)),
                            ("tokens", Json::Num(r.tokens.len() as f64)),
                            ("ttft_ms", Json::Num(r.ttft * 1e3)),
                            ("tok_per_sec", Json::Num(r.decode_tok_per_sec)),
                        ])
                    }
                }
                Err(_) => Json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("error", Json::Str("server shut down before responding".into())),
                ]),
            }
        }
        Err(e) => Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("error", Json::Str(format!("bad request: {e}"))),
        ]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::model::{ModelConfig, Transformer, WeightStore};

    fn tiny_server() -> Arc<ServerHandle> {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 64;
        let model = Arc::new(Transformer::from_store(&WeightStore::random(&cfg, 3)));
        Arc::new(ServerHandle::spawn(model, ServerConfig::default()))
    }

    fn roundtrip(addr: std::net::SocketAddr, line: &str) -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{line}").unwrap();
        let mut r = BufReader::new(s);
        let mut out = String::new();
        r.read_line(&mut out).unwrap();
        Json::parse(&out).unwrap()
    }

    #[test]
    fn tcp_request_response() {
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let resp = roundtrip(
            fe.addr,
            r#"{"prompt": "hello", "max_new_tokens": 6, "temperature": 0, "top_k": 1}"#,
        );
        assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(6));
        assert!(resp.get("text").unwrap().as_str().is_some());
        assert!(resp.get("tok_per_sec").unwrap().as_f64().unwrap() > 0.0);
        fe.shutdown();
    }

    #[test]
    fn tcp_bad_request_reports_error() {
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let resp = roundtrip(fe.addr, "{not json");
        assert!(resp.get("error").is_some());
        fe.shutdown();
    }

    #[test]
    fn tcp_unservable_request_gets_error_line() {
        // A server whose KV budget can't hold even one sequence must answer
        // over the wire with an error object instead of hanging the connection.
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 64;
        let model = Arc::new(Transformer::from_store(&WeightStore::random(&cfg, 3)));
        let server = Arc::new(ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 2, kv_budget_bytes: 1, ..Default::default() },
        ));
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let resp = roundtrip(fe.addr, r#"{"prompt": "x", "max_new_tokens": 4}"#);
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("budget"));
        fe.shutdown();
    }

    #[test]
    fn shutdown_drains_with_idle_connection_open() {
        // Regression: shutdown joins every connection thread, and a thread
        // blocked on an idle client's socket used to block that join forever.
        // With bounded reads the frontend must close promptly even while a
        // client holds its connection open.
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let idle = TcpStream::connect(fe.addr).unwrap();
        // One served request proves the frontend was live before shutdown.
        let resp = roundtrip(
            fe.addr,
            r#"{"prompt": "x", "max_new_tokens": 2, "temperature": 0}"#,
        );
        assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(2));
        let t = std::time::Instant::now();
        fe.shutdown();
        assert!(
            t.elapsed() < std::time::Duration::from_secs(5),
            "shutdown hung on an idle connection"
        );
        drop(idle);
    }

    #[test]
    fn tcp_multiple_clients() {
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let addr = fe.addr;
        let handles: Vec<_> = (0..3)
            .map(|i| {
                std::thread::spawn(move || {
                    roundtrip(
                        addr,
                        &format!(r#"{{"prompt": "p{i}", "max_new_tokens": 4, "temperature": 0}}"#),
                    )
                })
            })
            .collect();
        for h in handles {
            let resp = h.join().unwrap();
            assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(4));
        }
        fe.shutdown();
    }
}
