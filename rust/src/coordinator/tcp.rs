//! TCP front-end for the generation server: newline-delimited JSON over a
//! socket, one connection per client, requests multiplexed into the shared
//! continuous batcher.
//!
//! Protocol (one JSON object per line):
//!   -> {"prompt": "fn main", "max_new_tokens": 32, "temperature": 0.7,
//!       "top_k": 40, "seed": 1}
//!   <- {"id": 0, "text": "...", "tokens": 32, "ttft_ms": 12.1,
//!       "tok_per_sec": 154.2}
//!
//! With `"stream": true` the server emits one line per generated token as it
//! is produced, then a terminal line:
//!   <- {"id": 0, "index": 0, "token": 102, "text": "f", "done": false}
//!   <- ...
//!   <- {"id": 0, "done": true, "text": "...", "tokens": 32, ...}
//!
//! A client that disconnects mid-request is detected (failed token write for
//! streams, socket EOF probe for unary waits) and its request is cancelled so
//! the scheduler reclaims the KV blocks immediately.
//!
//! Start with `qtip serve --tcp 127.0.0.1:7171` or [`TcpFrontend::spawn`].

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::server::{codes, GenRequest, GenResponse, ServerHandle, StreamEvent};
use crate::util::fault;
use crate::util::json::Json;

pub struct TcpFrontend {
    pub addr: std::net::SocketAddr,
    /// Shutdown flag polled by the accept and connection loops. All its
    /// accesses are `Relaxed` (allowlisted in scripts/relaxed_allowlist.txt):
    /// it is a standalone stop signal — no other memory is published through
    /// it, and the loops re-check it within a bounded poll interval.
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl TcpFrontend {
    /// Bind `addr` (use port 0 for an ephemeral port) and serve until dropped.
    pub fn spawn(server: Arc<ServerHandle>, addr: &str) -> Result<TcpFrontend> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let next_id = Arc::new(AtomicU64::new(0));
        let join = std::thread::spawn(move || {
            let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let srv = server.clone();
                        let ids = next_id.clone();
                        let conn_stop = stop2.clone();
                        conns.push(std::thread::spawn(move || {
                            let _ = handle_conn(stream, &srv, &ids, &conn_stop);
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
            for c in conns {
                let _ = c.join();
            }
        });
        Ok(TcpFrontend { addr: local, stop, join: Some(join) })
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TcpFrontend {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn handle_conn(
    stream: TcpStream,
    server: &ServerHandle,
    ids: &AtomicU64,
    stop: &AtomicBool,
) -> Result<()> {
    // Chaos hook: a fired io_err drops the connection at accept, exercising
    // the client-facing error paths without a flaky network.
    if let Some(plan) = fault::global() {
        if plan.fire(fault::IO_ERR) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionReset,
                "injected frontend IO error",
            )
            .into());
        }
    }
    stream.set_nodelay(true).ok();
    // Bounded reads: a connection parked on an idle client must re-check the
    // stop flag periodically, or frontend shutdown would hang in join() on
    // every open socket and the server could never drain and report stats.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    // Bounded writes: a client that stops draining its socket gets a failed
    // write (treated exactly like a disconnect — the request is cancelled)
    // instead of parking this thread on a full send buffer indefinitely.
    stream.set_write_timeout(Some(std::time::Duration::from_secs(5)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Accumulate raw bytes, not a String: read_line's UTF-8 guard discards
    // already-consumed bytes when a timeout lands mid multi-byte character;
    // read_until keeps everything appended across retries.
    let mut line: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        match reader.read_until(b'\n', &mut line) {
            Ok(0) => {
                // Client closed. A timeout may have parked an unterminated
                // final request in `line` — serve it before hanging up.
                let text = String::from_utf8_lossy(&line);
                let trimmed = text.trim();
                if !trimmed.is_empty() {
                    serve_line(trimmed, server, ids, &mut writer)?;
                }
                return Ok(());
            }
            Ok(_) => {
                let eof_tail = line.last() != Some(&b'\n');
                let text = String::from_utf8_lossy(&line).into_owned();
                let trimmed = text.trim();
                if !trimmed.is_empty() {
                    serve_line(trimmed, server, ids, &mut writer)?;
                }
                line.clear();
                if eof_tail {
                    return Ok(());
                }
            }
            // Timeout (named WouldBlock or TimedOut depending on platform):
            // the partial line stays buffered; poll the stop flag again.
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Has the peer's connection *failed* (reset/broken)? An orderly FIN
/// (`peek` = 0 bytes) is deliberately NOT treated as gone: a client may
/// half-close its write side after sending a request and still be reading
/// the response (`printf ... | nc` does exactly this), and `handle_conn`'s
/// EOF path serves that final request. A fully-closed peer is detected when
/// a token/response write fails (RST), which is the cancellation signal for
/// streams. Pending pipelined bytes read as "alive" and are left unconsumed.
/// Shared with the HTTP front-end (`coordinator::http`).
pub(super) fn conn_closed(stream: &TcpStream) -> bool {
    let mut probe = [0u8; 1];
    match stream.peek(&mut probe) {
        Ok(_) => false,
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::Interrupted
            ) =>
        {
            false
        }
        Err(_) => true,
    }
}

/// Parse one request line and serve it — unary or streaming — onto `writer`.
/// IO errors on `writer` (client gone) cancel the in-flight request so the
/// scheduler frees its KV blocks immediately.
fn serve_line(
    line: &str,
    server: &ServerHandle,
    ids: &AtomicU64,
    writer: &mut TcpStream,
) -> Result<()> {
    let id = ids.fetch_add(1, Ordering::Relaxed);
    let j = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            let resp = Json::obj(vec![
                ("id", Json::Num(id as f64)),
                ("error", Json::Str(format!("bad request: {e}"))),
                ("code", Json::Str(codes::BAD_REQUEST.into())),
            ]);
            writeln!(writer, "{resp}")?;
            return Ok(());
        }
    };
    let stream_mode = j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
    let req = GenRequest {
        id,
        prompt: j.get("prompt").and_then(|p| p.as_str()).unwrap_or("").to_string(),
        max_new_tokens: j.get("max_new_tokens").and_then(|v| v.as_usize()).unwrap_or(32),
        temperature: j.get("temperature").and_then(|v| v.as_f64()).unwrap_or(0.7) as f32,
        top_k: j.get("top_k").and_then(|v| v.as_usize()).unwrap_or(40),
        seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(id as f64) as u64,
        model: j.get("model").and_then(|m| m.as_str()).unwrap_or("").to_string(),
        deadline_ms: j.get("deadline_ms").and_then(|v| v.as_usize()).unwrap_or(0) as u64,
    };

    if stream_mode {
        let rx = server.submit_stream(req);
        loop {
            match next_event(&rx, writer) {
                Wait::Event(StreamEvent::Token { id, index, token, text }) => {
                    let ev = Json::obj(vec![
                        ("id", Json::Num(id as f64)),
                        ("index", Json::Num(index as f64)),
                        ("token", Json::Num(token as f64)),
                        ("text", Json::Str(text)),
                        ("done", Json::Bool(false)),
                    ]);
                    if writeln!(writer, "{ev}").is_err() {
                        // Client vanished mid-stream: cancel so the scheduler
                        // frees the sequence's KV blocks this round.
                        server.cancel(id);
                        return Ok(());
                    }
                }
                Wait::Event(StreamEvent::Done(r)) => {
                    let mut resp = final_json(r);
                    if let Json::Obj(map) = &mut resp {
                        map.insert("done".to_string(), Json::Bool(true));
                    }
                    writeln!(writer, "{resp}")?;
                    return Ok(());
                }
                Wait::PeerGone => {
                    server.cancel(id);
                    return Ok(());
                }
                Wait::ServerGone => {
                    let mut resp = server_gone_json(id);
                    if let Json::Obj(map) = &mut resp {
                        map.insert("done".to_string(), Json::Bool(true));
                    }
                    writeln!(writer, "{resp}")?;
                    return Ok(());
                }
            }
        }
    }

    let rx = server.submit(req);
    let resp = match next_event(&rx, writer) {
        Wait::Event(r) => final_json(r),
        Wait::PeerGone => {
            server.cancel(id);
            return Ok(());
        }
        Wait::ServerGone => server_gone_json(id),
    };
    writeln!(writer, "{resp}")?;
    Ok(())
}

/// Outcome of waiting on the batcher while watching the client's socket.
/// Shared with the HTTP front-end (`coordinator::http`).
pub(super) enum Wait<T> {
    Event(T),
    /// The connection failed (reset/broken) while waiting: cancel the request.
    PeerGone,
    /// The server shut down before responding.
    ServerGone,
}

/// Cancellation-aware wait shared by the unary and streaming paths: block on
/// the batcher in 50 ms slices, probing the socket between slices so a dead
/// client cancels the request instead of it decoding to completion against a
/// closed connection.
pub(super) fn next_event<T>(rx: &std::sync::mpsc::Receiver<T>, stream: &TcpStream) -> Wait<T> {
    loop {
        match rx.recv_timeout(std::time::Duration::from_millis(50)) {
            Ok(ev) => return Wait::Event(ev),
            Err(RecvTimeoutError::Timeout) => {
                if conn_closed(stream) {
                    return Wait::PeerGone;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return Wait::ServerGone,
        }
    }
}

pub(super) fn server_gone_json(id: u64) -> Json {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("error", Json::Str("server shut down before responding".into())),
        ("code", Json::Str(codes::SERVER_SHUTDOWN.into())),
    ])
}

/// The terminal response object shared by unary and streaming requests (and
/// by both wire front-ends). Rejections carry both the human message
/// (`"error"`) and the stable machine-readable `"code"` clients branch on.
pub(super) fn final_json(r: GenResponse) -> Json {
    if let Some(err) = r.error {
        let mut fields = vec![
            ("id", Json::Num(r.id as f64)),
            ("error", Json::Str(err.message)),
            ("code", Json::Str(err.code.into())),
        ];
        // Backpressure hint on queue_full sheds: both frontends carry it in
        // the JSON body, and the HTTP front door mirrors it as a standard
        // `Retry-After` header on the 429.
        if let Some(ms) = err.retry_after_ms {
            fields.push(("retry_after_ms", Json::Num(ms as f64)));
        }
        return Json::obj(fields);
    }
    Json::obj(vec![
        ("id", Json::Num(r.id as f64)),
        ("text", Json::Str(r.text)),
        ("tokens", Json::Num(r.tokens.len() as f64)),
        ("ttft_ms", Json::Num(r.ttft * 1e3)),
        ("tok_per_sec", Json::Num(r.decode_tok_per_sec)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServerConfig;
    use crate::model::kv::{KvArena, KvLayout};
    use crate::model::{ModelConfig, Transformer, WeightStore};

    fn tiny_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 64;
        cfg
    }

    fn tiny_server() -> Arc<ServerHandle> {
        let cfg = tiny_cfg();
        let model = Arc::new(Transformer::from_store(&WeightStore::random(&cfg, 3)));
        Arc::new(ServerHandle::spawn(model, ServerConfig::default()))
    }

    fn roundtrip(addr: std::net::SocketAddr, line: &str) -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        writeln!(s, "{line}").unwrap();
        let mut r = BufReader::new(s);
        let mut out = String::new();
        r.read_line(&mut out).unwrap();
        Json::parse(&out).unwrap()
    }

    #[test]
    fn tcp_request_response() {
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let resp = roundtrip(
            fe.addr,
            r#"{"prompt": "hello", "max_new_tokens": 6, "temperature": 0, "top_k": 1}"#,
        );
        assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(6));
        assert!(resp.get("text").unwrap().as_str().is_some());
        assert!(resp.get("tok_per_sec").unwrap().as_f64().unwrap() > 0.0);
        fe.shutdown();
    }

    #[test]
    fn tcp_streaming_emits_token_lines_then_done() {
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        // Reference: the same deterministic request served unary.
        let want = roundtrip(
            fe.addr,
            r#"{"prompt": "s", "max_new_tokens": 5, "temperature": 0, "top_k": 1, "seed": 9}"#,
        );
        let want_text = want.get("text").unwrap().as_str().unwrap().to_string();

        let mut s = TcpStream::connect(fe.addr).unwrap();
        let line = concat!(
            r#"{"prompt": "s", "max_new_tokens": 5, "temperature": 0, "top_k": 1,"#,
            r#" "seed": 9, "stream": true}"#
        );
        writeln!(s, "{line}").unwrap();
        let mut r = BufReader::new(s);
        let mut n_tokens = 0usize;
        loop {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            let j = Json::parse(&line).unwrap();
            if j.get("done").unwrap().as_bool().unwrap() {
                // The terminal line carries the same full response as unary.
                assert_eq!(j.get("tokens").unwrap().as_usize(), Some(5));
                assert_eq!(j.get("text").unwrap().as_str().unwrap(), want_text);
                break;
            }
            assert_eq!(j.get("index").unwrap().as_usize(), Some(n_tokens));
            assert!(j.get("token").unwrap().as_usize().unwrap() < 256, "byte-vocab token");
            n_tokens += 1;
        }
        assert_eq!(n_tokens, 5, "one token line per generated token");
        fe.shutdown();
    }

    #[test]
    fn tcp_disconnect_mid_generation_cancels_and_frees_blocks() {
        // A streaming client that vanishes mid-generation must not pin KV:
        // size the arena so a follow-up full-length request only fits once
        // the dead request's blocks are reclaimed.
        let cfg = tiny_cfg();
        let block = 8usize;
        let budget = cfg.max_seq.div_ceil(block) * KvArena::block_bytes(&cfg, block);
        let model = Arc::new(Transformer::from_store(&WeightStore::random(&cfg, 3)));
        let server = Arc::new(ServerHandle::spawn(
            model,
            ServerConfig {
                max_batch: 2,
                kv_budget_bytes: budget,
                kv_block: block,
                kv_layout: KvLayout::Paged,
                ..Default::default()
            },
        ));
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();

        // Start a long streaming request, read one token line, then vanish.
        let mut s = TcpStream::connect(fe.addr).unwrap();
        let line =
            r#"{"prompt": "long", "max_new_tokens": 60, "temperature": 0, "stream": true}"#;
        writeln!(s, "{line}").unwrap();
        let mut r = BufReader::new(s);
        let mut first = String::new();
        r.read_line(&mut first).unwrap();
        assert!(Json::parse(&first).unwrap().get("token").is_some());
        drop(r); // closes the socket: FIN / RST on the next token write

        // The follow-up needs most of the arena; it can only complete if the
        // cancelled request's blocks were reclaimed.
        let resp = roundtrip(
            fe.addr,
            r#"{"prompt": "after", "max_new_tokens": 50, "temperature": 0}"#,
        );
        assert_eq!(
            resp.get("tokens").and_then(|t| t.as_usize()),
            Some(50),
            "post-disconnect request failed: {resp}"
        );
        fe.shutdown();
    }

    #[test]
    fn tcp_half_close_client_still_gets_response() {
        // A client that sends a request and then shuts down its write side
        // (`printf ... | nc` style) is NOT a disconnect: the final request
        // must be served, not cancelled — the FIN only closes their send
        // direction while they keep reading.
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let mut s = TcpStream::connect(fe.addr).unwrap();
        writeln!(s, r#"{{"prompt": "half", "max_new_tokens": 24, "temperature": 0}}"#).unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut r = BufReader::new(s);
        let mut out = String::new();
        r.read_line(&mut out).unwrap();
        let resp = Json::parse(&out).unwrap();
        assert_eq!(
            resp.get("tokens").and_then(|t| t.as_usize()),
            Some(24),
            "half-closed client must still be answered: {resp}"
        );
        fe.shutdown();
    }

    #[test]
    fn tcp_bad_request_reports_error() {
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let resp = roundtrip(fe.addr, "{not json");
        assert!(resp.get("error").is_some());
        assert_eq!(resp.get("code").unwrap().as_str(), Some(codes::BAD_REQUEST));
        fe.shutdown();
    }

    #[test]
    fn tcp_unservable_request_gets_error_line() {
        // A server whose KV budget can't hold even one block must answer
        // over the wire with an error object instead of hanging the connection.
        let cfg = tiny_cfg();
        let model = Arc::new(Transformer::from_store(&WeightStore::random(&cfg, 3)));
        let server = Arc::new(ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 2, kv_budget_bytes: 1, ..Default::default() },
        ));
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let resp = roundtrip(fe.addr, r#"{"prompt": "x", "max_new_tokens": 4}"#);
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("budget"));
        assert_eq!(resp.get("code").unwrap().as_str(), Some(codes::KV_BUDGET));
        fe.shutdown();
    }

    #[test]
    fn shutdown_drains_with_idle_connection_open() {
        // Regression: shutdown joins every connection thread, and a thread
        // blocked on an idle client's socket used to block that join forever.
        // With bounded reads the frontend must close promptly even while a
        // client holds its connection open.
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let idle = TcpStream::connect(fe.addr).unwrap();
        // One served request proves the frontend was live before shutdown.
        let resp = roundtrip(
            fe.addr,
            r#"{"prompt": "x", "max_new_tokens": 2, "temperature": 0}"#,
        );
        assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(2));
        let t = std::time::Instant::now();
        fe.shutdown();
        assert!(
            t.elapsed() < std::time::Duration::from_secs(5),
            "shutdown hung on an idle connection"
        );
        drop(idle);
    }

    #[test]
    fn tcp_multiple_clients() {
        let server = tiny_server();
        let fe = TcpFrontend::spawn(server, "127.0.0.1:0").unwrap();
        let addr = fe.addr;
        let handles: Vec<_> = (0..3)
            .map(|i| {
                std::thread::spawn(move || {
                    roundtrip(
                        addr,
                        &format!(r#"{{"prompt": "p{i}", "max_new_tokens": 4, "temperature": 0}}"#),
                    )
                })
            })
            .collect();
        for h in handles {
            let resp = h.join().unwrap();
            assert_eq!(resp.get("tokens").unwrap().as_usize(), Some(4));
        }
        fe.shutdown();
    }
}
