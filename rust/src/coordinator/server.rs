//! The generation server: request queue → continuous batcher → token streaming.
//!
//! Table 4's serving context: decoding is memory-bound, so the quantized model's
//! fused decode-matvec is the hot path. The coordinator contributes the
//! vLLM-style machinery around it: admission control against a KV-memory budget
//! (requests that can never fit are rejected with an error response), a KV-cache
//! pool (allocate on admit, recycle on completion), continuous batching (new
//! requests join mid-flight), and per-request metrics (TTFT, decode tok/s).
//!
//! Each round advances *every* active sequence by one token through a single
//! [`Transformer::decode_step_batch`] call, so every packed weight tile is
//! decoded once per round and applied to all B sequences — instead of being
//! re-decoded B times by per-sequence `decode_step` calls. Prompt prefill also
//! runs inside these fused rounds (one prompt token per round per sequence)
//! rather than in the admission path, so a long prompt no longer head-of-line
//! blocks sequences that are mid-decode.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::model::transformer::{DecodeScratch, KvCache, Transformer};
use crate::model::ByteTokenizer;
use crate::util::rng::Rng;
use crate::util::threadpool::ExecPool;

/// A generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 => greedy.
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
}

/// Completion with per-request serving metrics.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<u16>,
    pub prompt_tokens: usize,
    /// Seconds from admission to first generated token.
    pub ttft: f64,
    pub total_secs: f64,
    pub decode_tok_per_sec: f64,
    /// Set when the request was rejected instead of served (e.g. its KV cache
    /// can never fit the server's memory budget). All other fields are zeroed.
    pub error: Option<String>,
}

impl GenResponse {
    fn rejected(id: u64, reason: String) -> GenResponse {
        GenResponse {
            id,
            text: String::new(),
            tokens: Vec::new(),
            prompt_tokens: 0,
            ttft: 0.0,
            total_secs: 0.0,
            decode_tok_per_sec: 0.0,
            error: Some(reason),
        }
    }
}

/// Fallback token fed through the model when a prompt encodes to nothing, so
/// sampling always sees logits over the real vocabulary (byte 0 acts as BOS).
const BOS_FALLBACK: u16 = 0;

struct Active {
    req: GenRequest,
    cache: KvCache,
    /// Prompt tokens not yet prefilled; drained front-to-back, one per fused
    /// round, so prefill interleaves with other sequences' decode steps.
    pending_prompt: VecDeque<u16>,
    prompt_len: usize,
    generated: Vec<u16>,
    rng: Rng,
    /// Next sampled token awaiting emission (None while still prefilling).
    next_token: Option<u16>,
    admitted_at: std::time::Instant,
    first_token_at: Option<std::time::Instant>,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max concurrently-decoding sequences.
    pub max_batch: usize,
    /// KV memory budget in bytes (admission control).
    pub kv_budget_bytes: usize,
    /// Intra-op worker threads for the decode kernels (total width, including
    /// the serving thread). `0` = auto: `QTIP_THREADS` env var, else available
    /// parallelism. The serve loop owns the resulting [`ExecPool`]; every
    /// matvec of every round runs tile-parallel across it.
    pub threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 8, kv_budget_bytes: 256 << 20, threads: 0 }
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub completed: usize,
    /// Requests rejected at admission (KV cache larger than the budget).
    pub rejected: usize,
    pub total_generated_tokens: usize,
    /// All tokens pushed through fused rounds, prefill included — the
    /// numerator matching `total_decode_secs`, which times whole rounds.
    pub total_step_tokens: usize,
    pub total_decode_secs: f64,
    pub peak_batch: usize,
    pub peak_kv_bytes: usize,
    /// Decode rounds executed (one `decode_step_batch` call, or a single
    /// `decode_step` when only one sequence stepped that round).
    pub fused_rounds: usize,
    /// Largest number of sequences advanced by a single fused round — ≥ 2
    /// proves the batcher actually amortized a weight decode across sequences.
    pub max_fused_batch: usize,
    /// Execution-pool width the loop served with (1 = sequential).
    pub workers: usize,
    /// Decode-kernel family of the served model's quantized layers
    /// (`"scalar"` | `"lanes"`; `"dense"` when no layer is quantized).
    pub kernel: String,
}

impl ServerStats {
    /// Aggregate model token throughput. Rounds interleave prefill and decode
    /// tokens since prefill moved into the fused rounds, so the honest rate is
    /// tokens *stepped* per round-second — not generated tokens, which would
    /// undercount whenever prompts dominate.
    pub fn throughput_tok_per_sec(&self) -> f64 {
        if self.total_decode_secs == 0.0 {
            return 0.0;
        }
        self.total_step_tokens as f64 / self.total_decode_secs
    }
}

enum Msg {
    Submit(GenRequest, Sender<GenResponse>),
    Shutdown(Sender<ServerStats>),
}

/// Handle for submitting requests to a running server.
pub struct ServerHandle {
    tx: Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Spawn the serving loop on its own thread.
    pub fn spawn(model: Arc<Transformer>, cfg: ServerConfig) -> ServerHandle {
        let (tx, rx) = channel::<Msg>();
        let join = std::thread::spawn(move || serve_loop(model, cfg, rx));
        ServerHandle { tx, join: Some(join) }
    }

    /// Submit a request; the response arrives on the returned receiver.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Submit(req, tx)).expect("server gone");
        rx
    }

    /// Graceful shutdown: drains in-flight work, returns aggregate stats.
    pub fn shutdown(mut self) -> ServerStats {
        let (tx, rx) = channel();
        let _ = self.tx.send(Msg::Shutdown(tx));
        let stats = rx.recv().unwrap_or_default();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        stats
    }
}

fn serve_loop(model: Arc<Transformer>, cfg: ServerConfig, rx: Receiver<Msg>) {
    let tok = ByteTokenizer;
    let mut waiting: VecDeque<(GenRequest, Sender<GenResponse>)> = VecDeque::new();
    let mut active: Vec<(Active, Sender<GenResponse>)> = Vec::new();
    let mut cache_pool: Vec<KvCache> = Vec::new();
    let mut stats = ServerStats::default();
    let mut shutting_down: Option<Sender<ServerStats>> = None;
    // The loop owns the execution pool and the scratch arena: workers persist
    // across rounds (spawned once, parked between jobs) and every activation
    // buffer is reused — the model forward allocates nothing per round. (The
    // one remaining per-round allocation is the B-pointer `caches` borrow
    // list below, which borrowck forces us to rebuild each round.)
    let pool = ExecPool::new(cfg.threads);
    let mut scratch = DecodeScratch::new(&model.cfg);
    stats.workers = pool.width();
    stats.kernel = model
        .decode_kernel()
        .map(|k| k.name().to_string())
        .unwrap_or_else(|| "dense".to_string());
    // Round bookkeeping buffers, reused across rounds.
    let mut step_idx: Vec<usize> = Vec::new();
    let mut step_tokens: Vec<u16> = Vec::new();
    let mut finished: Vec<usize> = Vec::new();
    // Computed once: the admission check must not allocate full K/V buffers
    // every round just to read their size.
    let kv_bytes_per_seq = KvCache::size_bytes_for(&model.cfg);
    let max_batch = cfg.max_batch.max(1);

    loop {
        // Drain the message queue (non-blocking while work exists; blocking idle).
        loop {
            let msg = if active.is_empty() && waiting.is_empty() && shutting_down.is_none() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Submit(req, tx) => waiting.push_back((req, tx)),
                Msg::Shutdown(tx) => shutting_down = Some(tx),
            }
        }

        // Reject requests that can never be admitted: a single sequence's KV
        // cache above the budget would otherwise sit in `waiting` forever while
        // the loop busy-spins (and shutdown would never complete).
        if kv_bytes_per_seq > cfg.kv_budget_bytes {
            while let Some((req, tx)) = waiting.pop_front() {
                stats.rejected += 1;
                let _ = tx.send(GenResponse::rejected(
                    req.id,
                    format!(
                        "KV cache per sequence ({kv_bytes_per_seq} B) exceeds the \
                         server budget ({} B)",
                        cfg.kv_budget_bytes
                    ),
                ));
            }
        }

        // Admission: fill the batch while the KV budget allows. No prefill here —
        // the prompt is queued and consumed inside the fused rounds below, so a
        // new long prompt cannot head-of-line block sequences mid-decode.
        while active.len() < max_batch
            && !waiting.is_empty()
            && (active.len() + 1) * kv_bytes_per_seq <= cfg.kv_budget_bytes
        {
            let (req, tx) = waiting.pop_front().unwrap();
            let mut cache = cache_pool.pop().unwrap_or_else(|| KvCache::new(&model.cfg));
            cache.clear();
            let budget = model.cfg.max_seq.saturating_sub(req.max_new_tokens + 1);
            let mut pending_prompt: VecDeque<u16> =
                tok.encode(&req.prompt).into_iter().take(budget.max(1)).collect();
            if pending_prompt.is_empty() {
                // An empty prompt must still produce real logits before the
                // first sample — never a fake 1-element "vocab".
                pending_prompt.push_back(BOS_FALLBACK);
            }
            let prompt_len = pending_prompt.len();
            active.push((
                Active {
                    rng: Rng::new(req.seed),
                    req,
                    cache,
                    pending_prompt,
                    prompt_len,
                    generated: Vec::new(),
                    next_token: None,
                    admitted_at: std::time::Instant::now(),
                    first_token_at: None,
                },
                tx,
            ));
            stats.peak_batch = stats.peak_batch.max(active.len());
            stats.peak_kv_bytes = stats.peak_kv_bytes.max(active.len() * kv_bytes_per_seq);
        }

        if active.is_empty() {
            if let Some(tx) = shutting_down.take() {
                if waiting.is_empty() {
                    let _ = tx.send(stats.clone());
                    return;
                }
                shutting_down = Some(tx);
            }
            continue;
        }

        // One fused round: every active sequence advances one token — prompt
        // tokens while prefilling, sampled tokens while decoding — through a
        // single decode_step_batch call, so each packed weight tile is decoded
        // once for the whole batch (continuous batching: admissions above
        // interleave between rounds).
        let round_start = std::time::Instant::now();
        finished.clear();
        step_idx.clear();
        step_tokens.clear();
        for (i, (a, _)) in active.iter_mut().enumerate() {
            if let Some(t) = a.pending_prompt.pop_front() {
                step_idx.push(i);
                step_tokens.push(t);
                continue;
            }
            let t = a.next_token.expect("decoding sequence always holds a sampled token");
            a.generated.push(t);
            if a.first_token_at.is_none() {
                a.first_token_at = Some(std::time::Instant::now());
            }
            let done = a.generated.len() >= a.req.max_new_tokens
                || a.cache.len + 1 >= a.cache.capacity;
            if done {
                finished.push(i);
                continue;
            }
            step_idx.push(i);
            step_tokens.push(t);
        }

        if !step_idx.is_empty() {
            let mut caches: Vec<&mut KvCache> = Vec::with_capacity(step_idx.len());
            {
                let mut want = step_idx.iter().peekable();
                for (i, (a, _)) in active.iter_mut().enumerate() {
                    if want.peek() == Some(&&i) {
                        want.next();
                        caches.push(&mut a.cache);
                    }
                }
            }
            // One allocation-free fused round: every temporary lives in the
            // persistent scratch arena, every linear is striped across the
            // pool, and a 1-sequence round takes the tighter single-column
            // kernels inside decode_step_batch_with — outputs are
            // bit-identical either way.
            let logits =
                model.decode_step_batch_with(&mut caches, &step_tokens, &mut scratch, &pool);
            stats.fused_rounds += 1;
            stats.max_fused_batch = stats.max_fused_batch.max(step_tokens.len());
            stats.total_step_tokens += step_tokens.len();
            for (j, &i) in step_idx.iter().enumerate() {
                let (a, _) = &mut active[i];
                if !a.pending_prompt.is_empty() {
                    // Mid-prefill: logits are discarded until the last prompt
                    // token has been consumed.
                    continue;
                }
                a.next_token = Some(Transformer::sample(
                    logits.row(j),
                    a.req.temperature,
                    a.req.top_k,
                    &mut a.rng,
                ));
            }
        }
        stats.total_decode_secs += round_start.elapsed().as_secs_f64();

        // Retire finished sequences (largest index first).
        for i in finished.drain(..).rev() {
            let (a, tx) = active.swap_remove(i);
            let now = std::time::Instant::now();
            let total = (now - a.admitted_at).as_secs_f64();
            let ttft = a
                .first_token_at
                .map(|t| (t - a.admitted_at).as_secs_f64())
                .unwrap_or(total);
            let decode_secs = (total - ttft).max(1e-9);
            stats.completed += 1;
            stats.total_generated_tokens += a.generated.len();
            let resp = GenResponse {
                id: a.req.id,
                text: tok.decode(&a.generated),
                tokens: a.generated.clone(),
                prompt_tokens: a.prompt_len,
                ttft,
                total_secs: total,
                decode_tok_per_sec: (a.generated.len() as f64 - 1.0).max(0.0) / decode_secs,
                error: None,
            };
            cache_pool.push(a.cache);
            let _ = tx.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, WeightStore};

    fn tiny_model() -> Arc<Transformer> {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 64;
        Arc::new(Transformer::from_store(&WeightStore::random(&cfg, 7)))
    }

    fn req(id: u64, prompt: &str, n: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: prompt.into(),
            max_new_tokens: n,
            temperature: 0.0,
            top_k: 1,
            seed: id,
        }
    }

    #[test]
    fn serves_single_request() {
        let server = ServerHandle::spawn(tiny_model(), ServerConfig::default());
        let rx = server.submit(req(1, "hello", 8));
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.tokens.len(), 8);
        assert!(resp.ttft >= 0.0 && resp.total_secs >= resp.ttft);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.total_generated_tokens, 8);
        // tiny_model is fully dense, so the stats must say so rather than
        // claim a decode-kernel family that never ran.
        assert_eq!(stats.kernel, "dense");
    }

    #[test]
    fn batched_equals_sequential() {
        // Correctness invariant of the batcher: per-request outputs must be
        // identical to running each request alone (caches are independent),
        // even though all sequences share one fused decode pass per round.
        let model = tiny_model();
        let server = ServerHandle::spawn(model.clone(), ServerConfig::default());
        let reqs: Vec<GenRequest> =
            (0..6).map(|i| req(i, &format!("prompt {i}"), 6 + i as usize)).collect();
        let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
        let batched: Vec<GenResponse> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let stats = server.shutdown();
        // The fused kernel must actually have been used: at least one round
        // advanced several sequences through a single decode_step_batch call.
        assert!(
            stats.max_fused_batch >= 2,
            "6 concurrent requests never shared a fused round (max fused batch {})",
            stats.max_fused_batch
        );
        assert!(stats.fused_rounds > 0);

        for (r, b) in reqs.iter().zip(&batched) {
            let solo_server = ServerHandle::spawn(model.clone(), ServerConfig::default());
            let solo = solo_server.submit(r.clone()).recv().unwrap();
            solo_server.shutdown();
            assert_eq!(solo.tokens, b.tokens, "request {} diverged under batching", r.id);
        }
    }

    #[test]
    fn oversized_kv_request_is_rejected_not_spun_on() {
        // Regression: a request whose KV cache exceeds the budget used to sit in
        // `waiting` forever while serve_loop busy-spun and shutdown never
        // completed. It must now be rejected with an error response.
        let model = tiny_model();
        let per_seq = KvCache::size_bytes_for(&model.cfg);
        let server = ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 4, kv_budget_bytes: per_seq - 1, ..Default::default() },
        );
        let resp = server.submit(req(7, "hello", 8)).recv().unwrap();
        assert!(resp.error.is_some(), "unservable request must carry an error");
        assert!(resp.tokens.is_empty());
        // Shutdown must complete (this used to hang).
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.rejected, 1);
    }

    #[test]
    fn empty_prompt_samples_from_real_logits() {
        // Regression: an empty prompt used to leave logits = [0.0], so sampling
        // ran over a 1-element "vocab" and the first token was always 0. The
        // server now feeds a BOS fallback token, which makes an empty prompt
        // behave exactly like a prompt containing only byte 0.
        let model = tiny_model();
        let server = ServerHandle::spawn(model, ServerConfig::default());
        let empty = server.submit(req(1, "", 6)).recv().unwrap();
        let bos = server.submit(req(2, "\0", 6)).recv().unwrap();
        server.shutdown();
        assert!(empty.error.is_none());
        assert_eq!(empty.tokens.len(), 6);
        assert_eq!(empty.tokens, bos.tokens, "empty prompt must equal explicit BOS prompt");
        assert_eq!(empty.prompt_tokens, 1);
    }

    #[test]
    fn prefill_runs_inside_fused_rounds() {
        // A request with a long prompt must not be prefilled in the admission
        // path: its prompt tokens are consumed one per fused round, so rounds
        // keep running while it prefills (fused_rounds ≥ prompt_len + decode).
        let server = ServerHandle::spawn(tiny_model(), ServerConfig::default());
        let resp = server.submit(req(1, "0123456789", 4)).recv().unwrap();
        let stats = server.shutdown();
        assert_eq!(resp.tokens.len(), 4);
        assert_eq!(resp.prompt_tokens, 10);
        assert!(
            stats.fused_rounds >= 10 + 3,
            "expected ≥ 13 fused rounds (10 prefill + 3 decode), got {}",
            stats.fused_rounds
        );
    }

    #[test]
    fn respects_max_batch() {
        let model = tiny_model();
        let server = ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 2, kv_budget_bytes: 1 << 30, ..Default::default() },
        );
        let rxs: Vec<_> = (0..5).map(|i| server.submit(req(i, "x", 4))).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().tokens.len(), 4);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 5);
        assert!(stats.peak_batch <= 2);
    }

    #[test]
    fn kv_budget_limits_admission() {
        let model = tiny_model();
        let per_seq = KvCache::new(&model.cfg).size_bytes();
        let server = ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 8, kv_budget_bytes: per_seq * 2, ..Default::default() },
        );
        let rxs: Vec<_> = (0..4).map(|i| server.submit(req(i, "y", 3))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = server.shutdown();
        assert!(stats.peak_kv_bytes <= per_seq * 2);
        assert_eq!(stats.completed, 4);
    }

    #[test]
    fn deterministic_sampling_given_seed() {
        let model = tiny_model();
        let server = ServerHandle::spawn(model, ServerConfig::default());
        let mk = || GenRequest {
            id: 9,
            prompt: "abc".into(),
            max_new_tokens: 10,
            temperature: 0.8,
            top_k: 20,
            seed: 1234,
        };
        let a = server.submit(mk()).recv().unwrap();
        let b = server.submit(mk()).recv().unwrap();
        server.shutdown();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn long_prompt_is_truncated_to_fit() {
        let server = ServerHandle::spawn(tiny_model(), ServerConfig::default());
        let long: String = "z".repeat(500);
        let resp = server.submit(req(1, &long, 4)).recv().unwrap();
        assert_eq!(resp.tokens.len(), 4);
        server.shutdown();
    }

    #[test]
    fn serving_is_deterministic_across_pool_widths() {
        // Thread-count invariance at the serving level: the same request mix
        // must produce identical tokens whether the loop decodes on one
        // worker or four — the tile-parallel kernels never reorder any
        // per-sequence accumulation.
        let model = tiny_model();
        let run = |threads: usize| -> Vec<Vec<u16>> {
            let server = ServerHandle::spawn(
                model.clone(),
                ServerConfig { max_batch: 4, threads, ..Default::default() },
            );
            let rxs: Vec<_> = (0..5)
                .map(|i| {
                    server.submit(GenRequest {
                        id: i,
                        prompt: format!("prompt {i}"),
                        max_new_tokens: 6 + i as usize,
                        temperature: 0.8,
                        top_k: 16,
                        seed: 99 + i,
                    })
                })
                .collect();
            let out = rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect();
            let stats = server.shutdown();
            assert_eq!(stats.workers, threads.max(1));
            out
        };
        let seq = run(1);
        for threads in [2usize, 4] {
            assert_eq!(
                run(threads),
                seq,
                "serve_loop output changed under a {threads}-worker pool"
            );
        }
    }
}
