//! The generation server: request queue → continuous batcher → token streaming.
//!
//! Table 4's serving context: decoding is memory-bound, so the quantized
//! model's fused decode-matvec is the hot path — and with weights
//! trellis-compressed to 2–4 bits, the **KV cache** becomes the dominant
//! serving allocation. The coordinator therefore schedules KV memory at block
//! granularity (vLLM-style):
//!
//! * **Paged scheduler** (default, [`KvLayout::Paged`]) — one shared
//!   [`KvArena`]; a request is admitted as soon as enough free blocks exist
//!   for its *prompt* (token-granular admission), sequences lease further
//!   blocks one position ahead of decode, blocks are reclaimed the moment a
//!   sequence finishes (or its client disconnects), and under pressure the
//!   youngest sequence is preempted-by-eviction: its blocks are freed and the
//!   request is re-queued at the front (restarted deterministically — same
//!   seed, same tokens).
//! * **Contiguous scheduler** ([`KvLayout::Contig`]) — the reference path:
//!   sequence-granular admission against full `max_seq × d_model` caches,
//!   kept selectable (like the scalar decode kernels) as the baseline the
//!   paged path is parity-tested and benchmarked against.
//!
//! Each round advances *every* active sequence by one token through a single
//! fused [`Transformer::decode_step_batch_with`] /
//! [`Transformer::decode_step_batch_paged`] call, so every packed weight tile
//! is decoded once per round and applied to all B sequences. Prompt prefill
//! runs inside these rounds too, but as **GEMM chunks**: each round plans a
//! per-sequence token count (`Lane::plan_round`) — every decoding sequence
//! gets its 1 token first, then the remaining `--round-budget` (0 = unlimited)
//! is dealt to prefilling sequences in admission order as chunks of up to
//! `--prefill-chunk` prompt positions, each executed by one
//! [`Transformer::prefill_chunk_paged`] call that decodes every weight tile
//! once for the whole chunk. Decode priority means a long prompt can neither
//! head-of-line block sequences mid-decode nor starve other prompts. Clients
//! may subscribe to incremental tokens ([`ServerHandle::submit_stream`]) and
//! cancel in-flight work ([`ServerHandle::cancel`]); a dropped stream
//! receiver cancels implicitly and frees the sequence's blocks immediately.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::model::kv::{
    chain_hash, resolve_kv_block, resolve_prefill_chunk, resolve_round_budget, KvArena, KvCache,
    KvLayout, KvSeq, PrefixIndex, PREFIX_HASH_SEED,
};
use crate::model::transformer::{DecodeScratch, Transformer};
use crate::model::ByteTokenizer;
use crate::util::fault::{self, FaultPlan};
use crate::util::rng::Rng;
use crate::util::threadpool::ExecPool;

/// A generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 => greedy.
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Target model for a multi-model coordinator ([`ServerHandle::spawn_multi`]).
    /// Empty routes to the default (first) model; an unknown name is rejected
    /// with a structured error response.
    pub model: String,
    /// Wall-clock budget for the whole request in milliseconds, measured from
    /// submission. `0` falls back to [`ServerConfig::default_deadline_ms`]
    /// (which may itself be 0 = no deadline). Enforced at admission, while
    /// queued, and at every decode round; expiry delivers a structured
    /// [`codes::DEADLINE_EXCEEDED`] error and frees KV blocks the same round.
    pub deadline_ms: u64,
}

impl Default for GenRequest {
    fn default() -> GenRequest {
        GenRequest {
            id: 0,
            prompt: String::new(),
            max_new_tokens: 32,
            temperature: 0.0,
            top_k: 1,
            seed: 0,
            model: String::new(),
            deadline_ms: 0,
        }
    }
}

/// Machine-readable error codes carried by every rejection ([`GenError::code`]).
/// Frontends map these to HTTP statuses (`http::status_for`); clients branch
/// on the code, never on message text.
pub mod codes {
    /// Malformed request (unparseable JSON, missing fields).
    pub const BAD_REQUEST: &str = "bad_request";
    /// The request's `model` field names no configured lane.
    pub const UNKNOWN_MODEL: &str = "unknown_model";
    /// The request's lifetime KV needs exceed the lane's whole memory budget.
    pub const KV_BUDGET: &str = "kv_budget";
    /// Bounded admission: the lane's waiting queue is at `--max-queue`.
    pub const QUEUE_FULL: &str = "queue_full";
    /// The request's deadline expired (queued or mid-decode).
    pub const DEADLINE_EXCEEDED: &str = "deadline_exceeded";
    /// The lane's decode panicked; the lane is marked unhealthy.
    pub const LANE_FAILED: &str = "lane_failed";
    /// The server is draining and no longer accepts work.
    pub const SERVER_SHUTDOWN: &str = "server_shutdown";
    /// HTTP front door: body larger than the configured cap (413).
    pub const PAYLOAD_TOO_LARGE: &str = "payload_too_large";
    /// HTTP front door: the client trickled the request past the read
    /// deadline (slow-loris defense, 408).
    pub const READ_TIMEOUT: &str = "read_timeout";
}

/// A structured rejection: a stable machine-readable `code` (one of
/// [`codes`]) plus a human-oriented message.
#[derive(Clone, Debug)]
pub struct GenError {
    pub code: &'static str,
    pub message: String,
    /// Backpressure hint carried by [`codes::QUEUE_FULL`] sheds: how long the
    /// client should wait before retrying, derived from queue depth × recent
    /// round time. Surfaced as an HTTP `Retry-After` header and a
    /// `retry_after_ms` JSON field on both frontends; `None` on every other
    /// error code.
    pub retry_after_ms: Option<u64>,
}

impl std::fmt::Display for GenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} [{}]", self.message, self.code)
    }
}

/// Completion with per-request serving metrics.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<u16>,
    pub prompt_tokens: usize,
    /// Seconds from admission to first generated token.
    pub ttft: f64,
    pub total_secs: f64,
    pub decode_tok_per_sec: f64,
    /// Set when the request was rejected or failed instead of served (e.g.
    /// its KV needs can never fit the budget, its deadline expired, or its
    /// lane panicked). All other fields are zeroed.
    pub error: Option<GenError>,
}

impl GenResponse {
    fn rejected(id: u64, code: &'static str, message: String) -> GenResponse {
        GenResponse {
            id,
            text: String::new(),
            tokens: Vec::new(),
            prompt_tokens: 0,
            ttft: 0.0,
            total_secs: 0.0,
            decode_tok_per_sec: 0.0,
            error: Some(GenError { code, message, retry_after_ms: None }),
        }
    }
}

/// Incremental output of a streaming request ([`ServerHandle::submit_stream`]).
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// One generated token, emitted the round it is produced. `index` is the
    /// 0-based position in the generated stream (contiguous; eviction and
    /// re-admission never re-send already-delivered indices). `text` is the
    /// incremental UTF-8 completion of the byte stream: it may be empty while
    /// a multi-byte sequence is still pending and may carry bytes from
    /// earlier tokens once the sequence completes, so concatenating `text`
    /// fields always yields valid UTF-8 that prefixes the final response
    /// text (single bytes are never lossy-decoded in isolation).
    Token { id: u64, index: usize, token: u16, text: String },
    /// Terminal event: the complete response (also carries rejections).
    Done(GenResponse),
}

/// Decode as much of `pending` as ends on a UTF-8 boundary: definitively
/// invalid bytes become U+FFFD, but an incomplete trailing sequence is held
/// back (`consumed < pending.len()`) until later bytes complete it. Returns
/// (bytes consumed, text). The streaming path uses this so multi-byte
/// characters split across tokens reach clients intact instead of as one
/// replacement character per byte.
fn utf8_flush(pending: &[u8]) -> (usize, String) {
    let mut out = String::new();
    let mut consumed = 0;
    while consumed < pending.len() {
        match std::str::from_utf8(&pending[consumed..]) {
            Ok(s) => {
                out.push_str(s);
                consumed = pending.len();
            }
            Err(e) => {
                let valid = e.valid_up_to();
                out.push_str(
                    std::str::from_utf8(&pending[consumed..consumed + valid]).unwrap(),
                );
                consumed += valid;
                match e.error_len() {
                    Some(n) => {
                        out.push('\u{FFFD}');
                        consumed += n;
                    }
                    // Incomplete tail: hold it back for the next token.
                    None => break,
                }
            }
        }
    }
    (consumed, out)
}

/// Fallback token fed through the model when a prompt encodes to nothing, so
/// sampling always sees logits over the real vocabulary (byte 0 acts as BOS).
const BOS_FALLBACK: u16 = 0;

/// Where a request's output goes. Streams are **bounded**
/// ([`ServerConfig::stream_buffer`]): the batcher only ever `try_send`s into
/// them, so one stalled client can neither grow memory unboundedly nor block
/// the round for everyone else.
enum Sink {
    Unary(Sender<GenResponse>),
    Stream(SyncSender<StreamEvent>),
}

impl Sink {
    fn send_done(&self, resp: GenResponse) {
        match self {
            Sink::Unary(tx) => {
                let _ = tx.send(resp);
            }
            Sink::Stream(tx) => {
                // Non-blocking even for the terminal event: a client that let
                // its bounded buffer fill loses the Done and observes the
                // disconnect when the sink drops instead — the batcher never
                // waits on a slow reader.
                let _ = tx.try_send(StreamEvent::Done(resp));
            }
        }
    }
}

/// A queued request (possibly re-queued by preemption; `emitted` counts the
/// streamed tokens already delivered so a restart does not re-send them, and
/// the timing fields carry the *original* admission across restarts so
/// TTFT/total metrics cover the whole request lifetime).
struct Pending {
    req: GenRequest,
    sink: Sink,
    emitted: usize,
    /// Bytes of the generated stream already flushed as stream text (lags
    /// `emitted` tokens while a multi-byte UTF-8 sequence is incomplete).
    text_emitted: usize,
    admitted_at: Option<std::time::Instant>,
    first_token_at: Option<std::time::Instant>,
    /// Resolved once at submission (request field, else the server default);
    /// carried across eviction/re-queue so a restart never extends the budget.
    deadline: Option<Instant>,
    submitted_at: Instant,
}

impl Pending {
    fn new(req: GenRequest, sink: Sink, deadline: Option<Instant>) -> Pending {
        Pending {
            req,
            sink,
            emitted: 0,
            text_emitted: 0,
            admitted_at: None,
            first_token_at: None,
            deadline,
            submitted_at: Instant::now(),
        }
    }
}

/// A sequence's KV residency, matching the server's layout.
enum SeqKv {
    Contig(KvCache),
    Paged(KvSeq),
}

struct Active {
    req: GenRequest,
    sink: Sink,
    kv: SeqKv,
    /// Prompt tokens not yet prefilled; drained front-to-back, one per fused
    /// round, so prefill interleaves with other sequences' decode steps.
    pending_prompt: VecDeque<u16>,
    prompt_len: usize,
    generated: Vec<u16>,
    rng: Rng,
    /// Next sampled token awaiting emission (None while still prefilling).
    next_token: Option<u16>,
    admitted_at: std::time::Instant,
    first_token_at: Option<std::time::Instant>,
    /// Generated tokens already delivered to a streaming client (survives
    /// eviction + re-admission).
    stream_sent: usize,
    /// Generated *bytes* already flushed as stream text — lags `stream_sent`
    /// while a multi-byte UTF-8 sequence awaits completion.
    text_flushed: usize,
    /// Streaming client vanished: retire silently and free KV immediately.
    dropped: bool,
    /// Token ids whose K/V rows this sequence's positions hold (full prompt,
    /// then each accepted generation) — the registration source for the
    /// prefix index: position `p`'s row is the K/V of `context[p]`.
    context: Vec<u16>,
    /// Chain hash over the `registered` leading blocks (the `parent` for the
    /// next registration); starts at [`PREFIX_HASH_SEED`], advanced past
    /// admission-aliased blocks.
    chain: u64,
    /// Leading blocks already registered in (or aliased out of) the prefix
    /// index.
    registered: usize,
    /// Set by the capacity phase when this sequence waits one round for a
    /// finisher's blocks instead of forcing an eviction; cleared (and the
    /// sequence skipped) by the next round.
    stalled: bool,
    /// Tokens [`Lane::plan_round`] granted this sequence for the current
    /// round: 1 for a decode step, up to `prefill_chunk` for a prefill chunk,
    /// 0 when budget-deferred or not stepping. The capacity phase leases
    /// exactly this many positions; the round executes exactly this plan
    /// (unless the capacity phase shrank or stalled it).
    planned: usize,
    /// Expiry instant (None = no deadline); checked before every round.
    deadline: Option<Instant>,
    submitted_at: Instant,
}

impl Active {
    fn kv_len(&self) -> usize {
        match &self.kv {
            SeqKv::Contig(c) => c.len,
            SeqKv::Paged(s) => s.len,
        }
    }

    fn kv_cap(&self, max_seq: usize) -> usize {
        match &self.kv {
            SeqKv::Contig(c) => c.capacity,
            SeqKv::Paged(_) => max_seq,
        }
    }

    /// Whether this sequence advances the KV state this round (prefill or a
    /// non-final decode step). Mirrors the emission loop's `done` check
    /// exactly, so the paged capacity phase leases blocks only for sequences
    /// that will actually write a position.
    fn will_step(&self, max_seq: usize) -> bool {
        !self.pending_prompt.is_empty()
            || (self.generated.len() + 1 < self.req.max_new_tokens
                && self.kv_len() + 1 < self.kv_cap(max_seq))
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max concurrently-decoding sequences.
    pub max_batch: usize,
    /// KV memory budget in bytes. Paged layout: sized into whole arena
    /// blocks. Contiguous layout: sequence-granular admission control.
    pub kv_budget_bytes: usize,
    /// Intra-op worker threads for the decode kernels (total width, including
    /// the serving thread). `0` = auto: `QTIP_THREADS` env var, else available
    /// parallelism. The serve loop owns the resulting [`ExecPool`]; every
    /// matvec of every round runs tile-parallel across it.
    pub threads: usize,
    /// KV layout / scheduler selection (`Auto` resolves to `Paged`).
    pub kv_layout: KvLayout,
    /// Positions per KV block for the paged layout (`0` = auto:
    /// `QTIP_KV_BLOCK` env var, else 32). Ignored by the contiguous layout.
    pub kv_block: usize,
    /// Max prompt positions a prefilling sequence advances per round through
    /// one GEMM [`Transformer::prefill_chunk_paged`] call (`0` = auto:
    /// `QTIP_PREFILL_CHUNK` env var, else 32). `1` reproduces the legacy
    /// token-at-a-time prefill; the contiguous layout always uses 1.
    pub prefill_chunk: usize,
    /// Per-round token budget with decode priority: every decoding sequence
    /// gets its 1 token first, the remainder is split across prefilling
    /// sequences in admission order (`0` = auto: `QTIP_ROUND_BUDGET` env var,
    /// else unlimited). Deployment policy, not artifact geometry — there is
    /// no manifest fallback.
    pub round_budget: usize,
    /// Prefix sharing (paged layout only): keep a per-lane hashed-block
    /// [`PrefixIndex`] and alias a new sequence's leading blocks onto
    /// resident blocks covering the same token prefix instead of
    /// re-prefilling them, with copy-on-write on first divergence. Outputs
    /// are bit-identical with sharing on or off; off exists for A/B
    /// benchmarking and as a hedge.
    pub prefix_share: bool,
    /// Bounded admission: per-lane waiting-queue depth above which new
    /// submissions are shed immediately with [`codes::QUEUE_FULL`] (HTTP 429)
    /// instead of queueing forever. `0` = unbounded (the pre-hardening
    /// behavior).
    pub max_queue: usize,
    /// Default per-request deadline in milliseconds applied when a request
    /// leaves [`GenRequest::deadline_ms`] at 0. `0` = no default deadline.
    pub default_deadline_ms: u64,
    /// Bounded per-stream token buffer (events). The batcher only `try_send`s
    /// into stream sinks: a client that falls this many events behind is
    /// cancelled ([`ServerStats::shed_slow_clients`]) rather than buffered
    /// unboundedly. Clamped to ≥ 1.
    pub stream_buffer: usize,
    /// Round watchdog: if the batcher sits inside the same round for longer
    /// than this many milliseconds, a diagnosis with per-lane state is logged
    /// (once per stuck round) and [`ServerStats::watchdog_stalls`] counts it.
    /// `0` disables the watchdog.
    pub watchdog_ms: u64,
    /// Deterministic fault-injection plan for chaos tests. `None` falls back
    /// to the process-wide `QTIP_FAULT` plan ([`fault::global`]), which is
    /// itself `None` when the variable is unset — the production case, where
    /// every injection point is a never-taken branch.
    pub fault: Option<Arc<FaultPlan>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            kv_budget_bytes: 256 << 20,
            threads: 0,
            kv_layout: KvLayout::Auto,
            kv_block: 0,
            prefill_chunk: 0,
            round_budget: 0,
            prefix_share: true,
            max_queue: 0,
            default_deadline_ms: 0,
            stream_buffer: 256,
            watchdog_ms: 10_000,
            fault: None,
        }
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub completed: usize,
    /// Requests rejected at admission (KV needs larger than the budget).
    pub rejected: usize,
    /// Requests cancelled mid-flight (explicit cancel or client disconnect);
    /// their KV blocks were reclaimed immediately.
    pub cancelled: usize,
    pub total_generated_tokens: usize,
    /// All tokens pushed through fused rounds, prefill included — the
    /// numerator matching `total_decode_secs`, which times whole rounds.
    pub total_step_tokens: usize,
    pub total_decode_secs: f64,
    /// Legacy alias of [`Self::peak_active`] (both are set from the same
    /// expression at the same admission site); kept for older tooling/tests.
    pub peak_batch: usize,
    /// Most sequences simultaneously resident (prefilling or decoding).
    pub peak_active: usize,
    /// Deepest the waiting queue ever got.
    pub queue_high_water: usize,
    /// Sequences preempted-by-eviction under block pressure (re-queued and
    /// restarted; their output is unaffected).
    pub evictions: usize,
    /// Rounds a blocked sequence waited for a same-round finisher's blocks
    /// instead of evicting a mid-flight victim.
    pub stalls_instead_of_evictions: usize,
    /// Admissions that aliased at least one block out of the prefix index.
    pub prefix_hits: usize,
    /// Blocks aliased at admission instead of re-prefilled (each one is a
    /// whole block of prompt forward passes skipped).
    pub blocks_shared: usize,
    /// Shared blocks privatized by copy-on-write before a write landed.
    pub cow_copies: usize,
    pub peak_kv_bytes: usize,
    /// Paged arena geometry: total blocks and the most ever leased at once
    /// (0 when serving the contiguous layout).
    pub kv_blocks_total: usize,
    pub kv_blocks_high_water: usize,
    /// Positions per KV block (0 when serving the contiguous layout).
    pub kv_block_positions: usize,
    /// Resolved KV layout the loop served with (`"paged"` | `"contig"`).
    pub kv_layout: String,
    /// Decode rounds executed (one fused batch call, or a single-sequence
    /// round through the same path).
    pub fused_rounds: usize,
    /// Largest number of sequences advanced by a single fused round — ≥ 2
    /// proves the batcher actually amortized a weight decode across sequences.
    pub max_fused_batch: usize,
    /// Execution-pool width the loop served with (1 = sequential).
    pub workers: usize,
    /// Decode-kernel family of the served model's quantized layers
    /// (`"scalar"` | `"lanes"`; `"dense"` when no layer is quantized).
    pub kernel: String,
    /// Requests shed at submission because the lane's queue was at
    /// `--max-queue` ([`codes::QUEUE_FULL`]); not counted in `rejected`.
    pub shed_queue_full: usize,
    /// Streaming requests cancelled because the client fell a full
    /// `stream_buffer` behind the generated tokens (also counted in
    /// `cancelled`, like any other mid-flight cancellation).
    pub shed_slow_clients: usize,
    /// Requests whose deadline expired while still waiting in the queue.
    pub expired_queued: usize,
    /// Requests whose deadline expired mid-decode (their KV blocks were
    /// freed the same round).
    pub expired_running: usize,
    /// Lanes poisoned by a panic inside their decode round; each one failed
    /// its in-flight requests with [`codes::LANE_FAILED`] and stopped
    /// admitting, while the batcher kept serving the other lanes.
    pub lane_panics: usize,
    /// Rounds the watchdog flagged as stuck (no progress for
    /// [`ServerConfig::watchdog_ms`]).
    pub watchdog_stalls: usize,
    /// Multi-position GEMM prefill calls ([`Transformer::prefill_chunk_paged`]
    /// with ≥ 2 positions) — each one decoded every weight tile once for a
    /// whole chunk of prompt positions.
    pub prefill_chunks: usize,
    /// Prompt positions advanced through those chunked calls (excludes
    /// positions that went through the one-token fused path).
    pub prefill_tokens_chunked: usize,
    /// Times a prefilling sequence received less than its full chunk in a
    /// round because the `--round-budget` ran out (decode priority: decoding
    /// sequences are never deferred).
    pub budget_deferrals: usize,
}

impl ServerStats {
    /// Aggregate model token throughput. Rounds interleave prefill and decode
    /// tokens since prefill moved into the fused rounds, so the honest rate is
    /// tokens *stepped* per round-second — not generated tokens, which would
    /// undercount whenever prompts dominate.
    pub fn throughput_tok_per_sec(&self) -> f64 {
        if self.total_decode_secs == 0.0 {
            return 0.0;
        }
        self.total_step_tokens as f64 / self.total_decode_secs
    }
}

/// Per-lane readiness, as reported by [`ServerHandle::health`] and
/// `GET /health`.
#[derive(Clone, Debug)]
pub struct LaneHealth {
    pub name: String,
    /// False once the lane was poisoned by a decode panic.
    pub healthy: bool,
    /// Sequences currently resident (prefilling or decoding).
    pub active: usize,
    /// Requests waiting in the lane's admission queue.
    pub queued: usize,
    /// Free / total KV arena blocks (0/0 under the contiguous layout, whose
    /// admission is budget- rather than block-accounted).
    pub kv_blocks_free: usize,
    pub kv_blocks_total: usize,
}

/// Snapshot answered by [`ServerHandle::health`]: real readiness, not a
/// constant "ok".
#[derive(Clone, Debug)]
pub struct HealthSnapshot {
    pub lanes: Vec<LaneHealth>,
}

impl HealthSnapshot {
    /// Every lane is poisoned: the server can make no progress (503).
    pub fn all_failed(&self) -> bool {
        self.lanes.iter().all(|l| !l.healthy)
    }

    /// At least one lane is poisoned (reported as "degraded", still 200:
    /// the healthy lanes keep serving).
    pub fn degraded(&self) -> bool {
        self.lanes.iter().any(|l| !l.healthy)
    }
}

/// State shared between the serving thread and its watchdog. The serving
/// thread bumps `beat` after every completed pass over the lanes and flips
/// `busy` around the decode rounds; the watchdog alarms when `busy` holds and
/// `beat` has not advanced for `watchdog_ms` — a stuck round (deadlocked
/// pool, wedged kernel, injected stall), diagnosed with the per-lane state
/// captured at round entry. SeqCst throughout: this is cold telemetry, not a
/// hot path.
struct WatchdogShared {
    stop: AtomicBool,
    beat: AtomicU64,
    busy: AtomicBool,
    alarms: AtomicU64,
    lanes: Mutex<Vec<(String, usize, usize, bool)>>,
}

struct Watchdog {
    shared: Arc<WatchdogShared>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Watchdog {
    /// Spawn the watchdog thread; `watchdog_ms == 0` disables it (no thread).
    fn spawn(watchdog_ms: u64) -> Watchdog {
        let shared = Arc::new(WatchdogShared {
            stop: AtomicBool::new(false),
            beat: AtomicU64::new(0),
            busy: AtomicBool::new(false),
            alarms: AtomicU64::new(0),
            lanes: Mutex::new(Vec::new()),
        });
        if watchdog_ms == 0 {
            return Watchdog { shared, join: None };
        }
        let sh = Arc::clone(&shared);
        let join = std::thread::spawn(move || {
            let poll = Duration::from_millis((watchdog_ms / 4).clamp(5, 250));
            let limit = Duration::from_millis(watchdog_ms);
            let mut last_beat = sh.beat.load(Ordering::SeqCst);
            let mut since = Instant::now();
            let mut alarmed = false;
            loop {
                std::thread::sleep(poll);
                if sh.stop.load(Ordering::SeqCst) {
                    return;
                }
                let beat = sh.beat.load(Ordering::SeqCst);
                if beat != last_beat || !sh.busy.load(Ordering::SeqCst) {
                    last_beat = beat;
                    since = Instant::now();
                    alarmed = false;
                    continue;
                }
                if !alarmed && since.elapsed() > limit {
                    alarmed = true;
                    sh.alarms.fetch_add(1, Ordering::SeqCst);
                    let lanes = sh.lanes.lock().unwrap();
                    eprintln!(
                        "[watchdog] round stuck for {:.0} ms (beat {beat}); per-lane state:",
                        since.elapsed().as_secs_f64() * 1e3
                    );
                    for (name, active, waiting, failed) in lanes.iter() {
                        eprintln!(
                            "[watchdog]   lane '{name}': {active} active, {waiting} queued{}",
                            if *failed { ", FAILED" } else { "" }
                        );
                    }
                }
            }
        });
        Watchdog { shared, join: Some(join) }
    }

    /// Entering the decode rounds: snapshot lane state for the diagnosis.
    fn enter_rounds(&self, lanes: &[Lane]) {
        if self.join.is_none() {
            return;
        }
        *self.shared.lanes.lock().unwrap() = lanes
            .iter()
            .map(|l| (l.name.clone(), l.active.len(), l.waiting.len(), l.failed))
            .collect();
        self.shared.busy.store(true, Ordering::SeqCst);
    }

    /// Rounds completed: progress was made.
    fn exit_rounds(&self) {
        self.shared.beat.fetch_add(1, Ordering::SeqCst);
        self.shared.busy.store(false, Ordering::SeqCst);
    }

    fn alarms(&self) -> usize {
        self.shared.alarms.load(Ordering::SeqCst) as usize
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

enum Msg {
    Submit(GenRequest, Sink),
    Cancel(u64),
    Health(Sender<HealthSnapshot>),
    Stats(Sender<ServerStats>),
    Shutdown(Sender<ServerStats>),
}

/// Handle for submitting requests to a running server.
pub struct ServerHandle {
    tx: Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
    models: Vec<String>,
    /// Capacity of each stream sink (from [`ServerConfig::stream_buffer`]).
    stream_buffer: usize,
}

impl ServerHandle {
    /// Spawn the serving loop on its own thread (single model, named
    /// "default").
    pub fn spawn(model: Arc<Transformer>, cfg: ServerConfig) -> ServerHandle {
        ServerHandle::spawn_multi(vec![("default".to_string(), model)], cfg)
    }

    /// Spawn one serving loop over several models. Each model gets its own KV
    /// backend (paged arena or contiguous pool, each sized against
    /// `cfg.kv_budget_bytes`) and its own queues, but every lane's fused
    /// rounds run on the one shared [`ExecPool`]. Requests route on
    /// [`GenRequest::model`]; an empty field selects the first entry.
    ///
    /// Panics if `models` is empty or contains a duplicate name.
    pub fn spawn_multi(models: Vec<(String, Arc<Transformer>)>, cfg: ServerConfig) -> ServerHandle {
        assert!(!models.is_empty(), "spawn_multi needs at least one model");
        let names: Vec<String> = models.iter().map(|(n, _)| n.clone()).collect();
        for (i, n) in names.iter().enumerate() {
            assert!(!names[..i].contains(n), "duplicate model name '{n}'");
        }
        let (tx, rx) = channel::<Msg>();
        let stream_buffer = cfg.stream_buffer.max(1);
        let join = std::thread::spawn(move || serve_loop(models, cfg, rx));
        ServerHandle { tx, join: Some(join), models: names, stream_buffer }
    }

    /// Names of the served models in registration order; index 0 is the
    /// default route for requests that leave [`GenRequest::model`] empty.
    pub fn models(&self) -> &[String] {
        &self.models
    }

    /// Submit a request; the response arrives on the returned receiver.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Submit(req, Sink::Unary(tx))).expect("server gone");
        rx
    }

    /// Submit a request and receive tokens incrementally as they are
    /// produced, terminated by [`StreamEvent::Done`]. Dropping the receiver
    /// cancels the request: the scheduler notices the dead stream at its next
    /// token and frees the sequence's KV blocks immediately. The channel is
    /// bounded ([`ServerConfig::stream_buffer`]): a client that stops reading
    /// and lets it fill is cancelled (the stream ends without a `Done`, like
    /// a reset) instead of buffering tokens unboundedly.
    pub fn submit_stream(&self, req: GenRequest) -> Receiver<StreamEvent> {
        let (tx, rx) = sync_channel(self.stream_buffer);
        self.tx.send(Msg::Submit(req, Sink::Stream(tx))).expect("server gone");
        rx
    }

    /// Cancel a queued or in-flight request by id (e.g. on client
    /// disconnect). The scheduler drops it at the next round boundary and
    /// reclaims its KV blocks; no response is sent.
    pub fn cancel(&self, id: u64) {
        let _ = self.tx.send(Msg::Cancel(id));
    }

    /// Real readiness: per-lane health, queue depth, and free KV blocks.
    /// `None` when the serving thread is gone or wedged (did not answer
    /// within the probe timeout) — callers should report unavailable.
    pub fn health(&self) -> Option<HealthSnapshot> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Health(tx)).ok()?;
        rx.recv_timeout(Duration::from_secs(2)).ok()
    }

    /// Point-in-time [`ServerStats`] snapshot without shutting down. Same
    /// `None`-when-wedged contract as [`Self::health`].
    pub fn stats_snapshot(&self) -> Option<ServerStats> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Stats(tx)).ok()?;
        rx.recv_timeout(Duration::from_secs(2)).ok()
    }

    /// Graceful shutdown: drains in-flight work, returns aggregate stats.
    pub fn shutdown(mut self) -> ServerStats {
        let (tx, rx) = channel();
        let _ = self.tx.send(Msg::Shutdown(tx));
        let stats = rx.recv().unwrap_or_default();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        stats
    }
}

/// The KV backend the loop schedules over.
enum KvBackend {
    Contig {
        free: Vec<KvCache>,
        per_seq_bytes: usize,
    },
    Paged {
        arena: KvArena,
        block_bytes: usize,
        /// Hashed-block prefix index (None = sharing disabled). Per lane:
        /// token ids only identify content within one tokenizer/model pair.
        prefix: Option<PrefixIndex>,
    },
}

impl KvBackend {
    /// Free / total arena blocks for health reporting (0/0 for the
    /// contiguous layout, whose admission is budget-accounted instead).
    fn blocks_free(&self) -> usize {
        match self {
            KvBackend::Contig { .. } => 0,
            KvBackend::Paged { arena, .. } => arena.blocks_free(),
        }
    }

    fn blocks_total(&self) -> usize {
        match self {
            KvBackend::Contig { .. } => 0,
            KvBackend::Paged { arena, .. } => arena.blocks_total(),
        }
    }
}

/// Return a retired/evicted/cancelled sequence's KV residency to its backend.
fn release_seq(kv: SeqKv, backend: &mut KvBackend) {
    match (kv, backend) {
        (SeqKv::Contig(c), KvBackend::Contig { free, .. }) => free.push(c),
        (SeqKv::Paged(mut s), KvBackend::Paged { arena, .. }) => arena.release(&mut s),
        _ => unreachable!("sequence KV does not match the server's layout"),
    }
}

/// Prompt-token budget shared by admission and rejection: the prompt is
/// truncated so prompt + generation fits `max_seq`, and an empty prompt
/// counts as one BOS token.
fn effective_prompt_len(req: &GenRequest, max_seq: usize) -> usize {
    let budget = max_seq.saturating_sub(req.max_new_tokens + 1).max(1);
    req.prompt.len().min(budget).max(1)
}

/// KV positions a request can consume over its whole lifetime (prefill plus
/// decode steps; the final sampled token is never fed back, and generation
/// stops one position short of `max_seq`).
fn need_positions(prompt_len: usize, max_new: usize, max_seq: usize) -> usize {
    (prompt_len + max_new.saturating_sub(1)).min(max_seq.saturating_sub(1).max(1)).max(1)
}

/// One served model inside the coordinator: its own KV backend, decode
/// scratch, and request queues. Every lane's fused rounds run on the single
/// serving thread and its shared [`ExecPool`]; isolation between models is at
/// the KV/memory level, not the compute level.
struct Lane {
    name: String,
    model: Arc<Transformer>,
    backend: KvBackend,
    scratch: DecodeScratch,
    waiting: VecDeque<Pending>,
    // Admission-ordered: index 0 is the oldest sequence (eviction picks from
    // the back, so the oldest always runs to completion — the progress
    // guarantee that makes preemption deadlock-free).
    active: Vec<Active>,
    max_seq: usize,
    /// Resolved chunk width for GEMM prefill (≥ 1; 1 = token-at-a-time, and
    /// always 1 on the contiguous backend, which has no chunked path).
    prefill_chunk: usize,
    /// Resolved per-round token budget (0 = unlimited).
    round_budget: usize,
    /// Exponentially-smoothed wall time of this lane's recent rounds, the
    /// basis for queue-full `Retry-After` hints (0.0 until a round completes).
    recent_round_secs: f64,
    // Round bookkeeping buffers, reused across rounds.
    step_idx: Vec<usize>,
    step_tokens: Vec<u16>,
    // Sequences whose plan is a multi-position prefill chunk this round, and
    // the chunk token staging buffer (reused; allocation-free steady state).
    chunk_idx: Vec<usize>,
    chunk_tokens: Vec<u16>,
    finished: Vec<usize>,
    /// Poisoned by a panic inside this lane's round: in-flight work was
    /// failed with [`codes::LANE_FAILED`], the backend is abandoned (its
    /// arena may have been mid-mutation), and the lane neither admits nor
    /// decodes again. Other lanes are unaffected.
    failed: bool,
    /// Fault-injection plan (config override, else the `QTIP_FAULT` process
    /// plan, else None = production).
    fault: Option<Arc<FaultPlan>>,
}

impl Lane {
    fn new(
        name: String,
        model: Arc<Transformer>,
        cfg: &ServerConfig,
        stats: &mut ServerStats,
    ) -> Lane {
        let max_batch = cfg.max_batch.max(1);
        let max_seq = model.cfg.max_seq;
        let fault = cfg.fault.clone().or_else(|| fault::global().cloned());
        let backend = match cfg.kv_layout.resolve() {
            KvLayout::Contig => KvBackend::Contig {
                free: Vec::new(),
                per_seq_bytes: KvCache::size_bytes_for(&model.cfg),
            },
            _ => {
                let block = resolve_kv_block(cfg.kv_block, 0);
                let block_bytes = KvArena::block_bytes(&model.cfg, block);
                // Whole blocks under the budget, but never more than max_batch
                // full-length sequences could touch — the arena is eagerly
                // allocated, so an oversized budget must not balloon it.
                let by_budget = cfg.kv_budget_bytes / block_bytes;
                let by_batch = max_batch * KvArena::blocks_for_positions(max_seq, block);
                let n_blocks = by_budget.min(by_batch);
                stats.kv_block_positions = block;
                stats.kv_blocks_total += n_blocks;
                let mut arena = KvArena::new(&model.cfg, block, n_blocks);
                if let Some(plan) = &fault {
                    arena.set_fault_plan(Arc::clone(plan));
                }
                KvBackend::Paged {
                    arena,
                    block_bytes,
                    prefix: cfg.prefix_share.then(PrefixIndex::new),
                }
            }
        };
        let scratch = DecodeScratch::new(&model.cfg);
        // Chunked prefill is a paged-backend path (it bulk-appends K/V rows
        // through the arena); the contiguous reference lane keeps the legacy
        // one-token-per-round prefill by pinning its chunk width to 1.
        let prefill_chunk = match &backend {
            KvBackend::Contig { .. } => 1,
            KvBackend::Paged { .. } => resolve_prefill_chunk(cfg.prefill_chunk, 0),
        };
        let round_budget = resolve_round_budget(cfg.round_budget);
        Lane {
            name,
            model,
            backend,
            scratch,
            waiting: VecDeque::new(),
            active: Vec::new(),
            max_seq,
            prefill_chunk,
            round_budget,
            recent_round_secs: 0.0,
            step_idx: Vec::new(),
            step_tokens: Vec::new(),
            chunk_idx: Vec::new(),
            chunk_tokens: Vec::new(),
            finished: Vec::new(),
            failed: false,
            fault,
        }
    }

    /// Render the once-per-request can-this-ever-fit verdict and enqueue.
    /// Can-this-ever-fit is invariant once the backend exists, so the
    /// verdict is rendered exactly once, here — not by re-scanning the whole
    /// queue every round. (A request that can never fit must be rejected, not
    /// queued forever: the loop would busy-spin and shutdown would never
    /// drain.)
    fn submit(&mut self, req: GenRequest, sink: Sink, cfg: &ServerConfig, stats: &mut ServerStats) {
        if self.failed {
            stats.rejected += 1;
            sink.send_done(GenResponse::rejected(
                req.id,
                codes::LANE_FAILED,
                format!("model lane '{}' failed (panic during decode)", self.name),
            ));
            return;
        }
        let reject = match &self.backend {
            KvBackend::Contig { per_seq_bytes, .. } if *per_seq_bytes > cfg.kv_budget_bytes => {
                Some(format!(
                    "KV cache per sequence ({per_seq_bytes} B) exceeds the \
                     server budget ({} B)",
                    cfg.kv_budget_bytes
                ))
            }
            KvBackend::Paged { arena, .. } => {
                let plen = effective_prompt_len(&req, self.max_seq);
                let need = need_positions(plen, req.max_new_tokens, self.max_seq);
                let bp = arena.block_positions();
                let blocks = KvArena::blocks_for_positions(need, bp);
                let total = arena.blocks_total();
                (blocks > total).then(|| {
                    format!(
                        "request needs {blocks} KV blocks ({need} positions × \
                         {bp}-position blocks) but the whole arena holds {total} \
                         under the {} B budget",
                        cfg.kv_budget_bytes
                    )
                })
            }
            _ => None,
        };
        if let Some(reason) = reject {
            stats.rejected += 1;
            sink.send_done(GenResponse::rejected(req.id, codes::KV_BUDGET, reason));
            return;
        }
        // Bounded admission: shed instead of queueing forever. Checked after
        // the can-ever-fit verdict so an unservable request reports its real
        // problem, not transient queue depth.
        if cfg.max_queue > 0 && self.waiting.len() >= cfg.max_queue {
            stats.shed_queue_full += 1;
            let mut resp = GenResponse::rejected(
                req.id,
                codes::QUEUE_FULL,
                format!(
                    "lane '{}' admission queue is full ({} waiting, --max-queue {})",
                    self.name,
                    self.waiting.len(),
                    cfg.max_queue
                ),
            );
            if let Some(err) = resp.error.as_mut() {
                err.retry_after_ms = Some(self.retry_after_hint_ms());
            }
            sink.send_done(resp);
            return;
        }
        // Resolve the deadline once: request field, else the server default,
        // else none. The queue scan and the per-round check both compare
        // against this single instant, so eviction/restart never extends it.
        let deadline_ms = if req.deadline_ms > 0 { req.deadline_ms } else { cfg.default_deadline_ms };
        let deadline = (deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(deadline_ms));
        self.waiting.push_back(Pending::new(req, sink, deadline));
    }

    /// How long a shed client should wait before retrying: queue depth ×
    /// smoothed recent round time — roughly when the queue's head should have
    /// drained one slot. Clamped to ≥ 1 ms so clients always see a positive
    /// hint; a lane that has not completed a round yet guesses from 10 ms.
    fn retry_after_hint_ms(&self) -> u64 {
        let round_secs = if self.recent_round_secs > 0.0 { self.recent_round_secs } else { 0.010 };
        ((self.waiting.len().max(1) as f64) * round_secs * 1e3).ceil().max(1.0) as u64
    }

    /// Cancel a queued or active request; true if it lived on this lane.
    fn cancel(&mut self, id: u64, stats: &mut ServerStats) -> bool {
        if let Some(pos) = self.waiting.iter().position(|p| p.req.id == id) {
            let _ = self.waiting.remove(pos);
            stats.cancelled += 1;
            true
        } else if let Some(pos) = self.active.iter().position(|a| a.req.id == id) {
            let a = self.active.remove(pos);
            release_seq(a.kv, &mut self.backend);
            stats.cancelled += 1;
            true
        } else {
            false
        }
    }

    /// Deadline enforcement, run once per scheduler pass (i.e. at every round
    /// boundary): queued requests past their deadline are rejected without
    /// ever being admitted, and active sequences past theirs are retired with
    /// a structured error — their KV blocks return to the arena *this* round,
    /// not when the generation would have finished.
    fn expire_deadlines(&mut self, stats: &mut ServerStats) {
        let now = Instant::now();
        let mut i = 0;
        while i < self.waiting.len() {
            if self.waiting[i].deadline.is_some_and(|d| d <= now) {
                let p = self.waiting.remove(i).expect("index checked");
                stats.expired_queued += 1;
                p.sink.send_done(GenResponse::rejected(
                    p.req.id,
                    codes::DEADLINE_EXCEEDED,
                    format!(
                        "deadline exceeded after {:.0} ms waiting in queue",
                        p.submitted_at.elapsed().as_secs_f64() * 1e3
                    ),
                ));
            } else {
                i += 1;
            }
        }
        let mut j = 0;
        while j < self.active.len() {
            if self.active[j].deadline.is_some_and(|d| d <= now) {
                let a = self.active.remove(j);
                release_seq(a.kv, &mut self.backend);
                if a.dropped {
                    stats.cancelled += 1;
                    continue;
                }
                stats.expired_running += 1;
                a.sink.send_done(GenResponse::rejected(
                    a.req.id,
                    codes::DEADLINE_EXCEEDED,
                    format!(
                        "deadline exceeded after {:.0} ms ({} of {} tokens generated)",
                        a.submitted_at.elapsed().as_secs_f64() * 1e3,
                        a.generated.len(),
                        a.req.max_new_tokens
                    ),
                ));
            } else {
                j += 1;
            }
        }
    }

    /// A panic escaped this lane's round: fail everything in flight with a
    /// structured error and stop admitting. The KV backend is deliberately
    /// abandoned rather than drained — the panic may have interrupted an
    /// arena mutation mid-way, so its free list can no longer be trusted.
    /// Each lane owns its KV memory outright, so nothing leaks into the
    /// still-healthy lanes, which keep serving.
    fn poison(&mut self, stats: &mut ServerStats) {
        self.failed = true;
        stats.lane_panics += 1;
        eprintln!(
            "[serve] lane '{}' poisoned by a panic; failing {} active and {} queued request(s)",
            self.name,
            self.active.len(),
            self.waiting.len()
        );
        let msg = || format!("model lane '{}' failed (panic during decode)", self.name);
        for a in self.active.drain(..) {
            if a.dropped {
                stats.cancelled += 1;
                continue;
            }
            a.sink.send_done(GenResponse::rejected(a.req.id, codes::LANE_FAILED, msg()));
        }
        for p in self.waiting.drain(..) {
            p.sink.send_done(GenResponse::rejected(p.req.id, codes::LANE_FAILED, msg()));
        }
    }

    /// Admission. Paged: token-granular — a request joins as soon as free
    /// (or index-reclaimable) blocks cover the *unshared* part of its prompt
    /// (acquired here so concurrent admissions never double-count a block);
    /// decode blocks are acquired on demand. With prefix sharing on, the
    /// prompt's leading tokens are first matched against the lane's
    /// [`PrefixIndex`]: every matched full block is aliased (refcount + 1)
    /// instead of re-prefilled, and the sequence's cursor starts past the
    /// shared prefix — prefill work is O(unique prompt tokens). The position
    /// of the **last** prompt token is never aliased (its forward pass
    /// produces the logits the first sample draws from), so a fully-matched
    /// prompt starts one position back inside a shared block and the first
    /// write copy-on-writes that block.
    /// Contiguous: sequence-granular — a whole max_seq cache must fit.
    fn admit(&mut self, cfg: &ServerConfig, tok: &ByteTokenizer, stats: &mut ServerStats) {
        let max_batch = cfg.max_batch.max(1);
        loop {
            if self.active.len() >= max_batch || self.waiting.is_empty() {
                break;
            }
            // One source of truth for truncation: the same effective_prompt_len
            // that sizes the block acquisition and the rejection verdict.
            let plen = effective_prompt_len(&self.waiting.front().unwrap().req, self.max_seq);
            let mut ptoks: Vec<u16> = tok
                .encode(&self.waiting.front().unwrap().req.prompt)
                .into_iter()
                .take(plen)
                .collect();
            if ptoks.is_empty() {
                // An empty prompt must still produce real logits before the
                // first sample — never a fake 1-element "vocab".
                ptoks.push(BOS_FALLBACK);
            }
            debug_assert_eq!(ptoks.len(), plen, "block sizing diverged from prompt");
            let mut shared_len = 0usize;
            let mut chain = PREFIX_HASH_SEED;
            let mut registered = 0usize;
            let kv = match &mut self.backend {
                KvBackend::Contig { free, per_seq_bytes } => {
                    if (self.active.len() + 1) * *per_seq_bytes > cfg.kv_budget_bytes {
                        break;
                    }
                    let mut cache = free.pop().unwrap_or_else(|| KvCache::new(&self.model.cfg));
                    cache.clear();
                    stats.peak_kv_bytes =
                        stats.peak_kv_bytes.max((self.active.len() + 1) * *per_seq_bytes);
                    SeqKv::Contig(cache)
                }
                KvBackend::Paged { arena, prefix, .. } => {
                    let bp = arena.block_positions();
                    let (aliased, parent) = match prefix.as_mut() {
                        Some(idx) => idx.match_chain(&ptoks, bp),
                        None => (Vec::new(), PREFIX_HASH_SEED),
                    };
                    let n_alias = aliased.len();
                    // The aliased blocks cover n_alias × bp leading positions,
                    // but the cursor starts no later than plen - 1: the last
                    // prompt token is always recomputed for its logits. A
                    // fully-covered prompt therefore re-enters its final
                    // shared block, and that recompute-write (same token
                    // prefix ⇒ bit-identical row) is the copy-on-write case —
                    // reserve the free block it will need.
                    shared_len = (n_alias * bp).min(plen - 1);
                    chain = parent;
                    registered = n_alias;
                    let fresh = arena.blocks_for(plen) - n_alias;
                    let cow_reserve = usize::from(n_alias * bp >= plen);
                    // Alias first (refcount ≥ 2 shields these blocks from the
                    // reclaim below), then turn index-only LRU entries back
                    // into free blocks until the unshared part fits.
                    let mut seq = KvSeq::new();
                    for &b in &aliased {
                        arena.retain(&mut seq, b);
                    }
                    if let Some(idx) = prefix.as_mut() {
                        while arena.blocks_free() < fresh + cow_reserve
                            && idx.reclaim_one(arena).is_some()
                        {}
                    }
                    if arena.blocks_free() < fresh + cow_reserve {
                        // Not admittable yet: undo the aliases and keep the
                        // request queued (admission order is preserved).
                        arena.release(&mut seq);
                        break;
                    }
                    // The free-list check above makes this succeed in normal
                    // operation, but an injected kv_alloc fault (chaos tests)
                    // can still fail it — undo and retry a later round, same
                    // as the not-admittable-yet path.
                    let ok = arena.ensure(&mut seq, plen);
                    if !ok {
                        arena.release(&mut seq);
                        break;
                    }
                    seq.len = shared_len;
                    if n_alias > 0 {
                        stats.prefix_hits += 1;
                        stats.blocks_shared += n_alias;
                    }
                    SeqKv::Paged(seq)
                }
            };
            let p = self.waiting.pop_front().unwrap();
            // Prompt tokens covered by the shared prefix advance position
            // without a forward pass: prefill starts at the cursor.
            let pending_prompt: VecDeque<u16> = ptoks[shared_len..].iter().copied().collect();
            debug_assert!(!pending_prompt.is_empty(), "the last prompt token is never aliased");
            let prompt_len = ptoks.len();
            self.active.push(Active {
                rng: Rng::new(p.req.seed),
                stream_sent: p.emitted,
                text_flushed: p.text_emitted,
                // A preempted request keeps its original clock so TTFT and
                // total_secs span the whole lifetime, not just the restart.
                admitted_at: p.admitted_at.unwrap_or_else(std::time::Instant::now),
                first_token_at: p.first_token_at,
                deadline: p.deadline,
                submitted_at: p.submitted_at,
                req: p.req,
                sink: p.sink,
                kv,
                pending_prompt,
                prompt_len,
                generated: Vec::new(),
                next_token: None,
                dropped: false,
                context: ptoks,
                chain,
                registered,
                stalled: false,
                planned: 0,
            });
        }
    }

    /// Decode-priority round planning: decide how many tokens each active
    /// sequence advances this round. Every decoding sequence is granted its 1
    /// token first — decode steps are mandatory and never budget-deferred, so
    /// a flood of long prompts cannot stall in-flight generations. Whatever
    /// remains of `round_budget` (0 = unlimited) is then dealt to prefilling
    /// sequences in admission order (index order — deterministic) as chunks
    /// of at most `prefill_chunk` prompt positions; a sequence granted less
    /// than its full chunk counts one [`ServerStats::budget_deferrals`].
    /// The capacity phase leases exactly `planned` positions and the round
    /// executes exactly this plan.
    fn plan_round(&mut self, stats: &mut ServerStats) {
        let max_seq = self.max_seq;
        let budget = self.round_budget;
        let mut remaining = budget;
        for a in self.active.iter_mut() {
            a.planned = 0;
            if !a.pending_prompt.is_empty() || !a.will_step(max_seq) {
                continue;
            }
            a.planned = 1;
            remaining = remaining.saturating_sub(1);
        }
        for a in self.active.iter_mut() {
            if a.pending_prompt.is_empty() {
                continue;
            }
            let want = a.pending_prompt.len().min(self.prefill_chunk).max(1);
            let take = if budget == 0 { want } else { want.min(remaining) };
            if take < want {
                stats.budget_deferrals += 1;
            }
            a.planned = take;
            if budget > 0 {
                remaining -= take;
            }
        }
    }

    /// Paged capacity phase: every sequence that will write a position this
    /// round must hold a **writable** block for it —
    /// [`KvArena::prepare_append`] both acquires capacity and privatizes a
    /// shared tail block (copy-on-write) before the round's stores. The lease
    /// covers all `planned` positions (a whole prefill chunk at once). Under
    /// pressure, relief is tried cheapest-first: shrink a multi-position
    /// chunk to a single token, then reclaim an index-only
    /// prefix block (cached capacity, not live state), then stall one round
    /// when a sequence retiring this round is about to free blocks anyway,
    /// and only then evict the youngest sequence (blocks released, request
    /// re-queued at the front); the oldest is never evicted for a younger
    /// one, so it always completes and the arena always drains.
    fn capacity_phase(&mut self, stats: &mut ServerStats) {
        let max_seq = self.max_seq;
        if let KvBackend::Paged { arena, block_bytes, prefix } = &mut self.backend {
            let mut i = 0;
            while i < self.active.len() {
                if self.active[i].planned == 0 {
                    i += 1;
                    continue;
                }
                let mut evicted_self = false;
                loop {
                    let a = &mut self.active[i];
                    let need = a.kv_len() + a.planned;
                    let SeqKv::Paged(seq) = &mut a.kv else {
                        unreachable!("paged backend holds paged sequences")
                    };
                    if let Some(did_cow) = arena.prepare_append(seq, need) {
                        if did_cow {
                            stats.cow_copies += 1;
                        }
                        break;
                    }
                    // Starved. Cheapest relief first: a multi-position prefill
                    // chunk shrinks to a single token — exactly what the
                    // pre-chunking scheduler would have leased, so the ladder
                    // below keeps its old meaning — and the lease retries.
                    if a.planned > 1 {
                        a.planned = 1;
                        continue;
                    }
                    // Next: evict the LRU prefix-index entry nothing else
                    // references and retry.
                    if let Some(idx) = prefix.as_mut() {
                        if idx.reclaim_one(arena).is_some() {
                            continue;
                        }
                    }
                    // A sequence retiring this round releases its blocks at
                    // retirement: stall this sequence one round rather than
                    // discarding a mid-flight victim's work. Deadlock-free:
                    // next round the finisher is gone, so a still-starved
                    // sequence falls through to eviction.
                    let finisher_pending = self
                        .active
                        .iter()
                        .enumerate()
                        .any(|(j, s)| j != i && !s.will_step(max_seq));
                    if finisher_pending {
                        self.active[i].stalled = true;
                        stats.stalls_instead_of_evictions += 1;
                        break;
                    }
                    debug_assert!(
                        self.active.len() > 1 || self.fault.is_some(),
                        "a solo sequence always fits: admission rejects requests whose \
                         lifetime blocks exceed the whole arena and reserves the \
                         copy-on-write block for a fully-shared prompt (an injected \
                         kv_alloc fault is the one legitimate way to get here solo — \
                         the sequence self-evicts below and is re-queued)"
                    );
                    // Evict the youngest sequence that is still prefilling or
                    // decoding — never one finishing this round, whose blocks
                    // free at retirement anyway (evicting it would discard a
                    // complete generation). Victims are always ≥ `i`, so a
                    // sequence is only ever preempted for an equal-or-older
                    // one; `i` self-evicts only when every younger sequence
                    // retires this round, and those retirements release the
                    // blocks it needs to re-admit — no deadlock either way.
                    let victim = (i..self.active.len())
                        .rev()
                        .find(|&j| self.active[j].will_step(max_seq))
                        .expect("sequence i itself is stepping");
                    let v = self.active.remove(victim);
                    if let SeqKv::Paged(mut s) = v.kv {
                        arena.release(&mut s);
                    }
                    stats.evictions += 1;
                    self.waiting.push_front(Pending {
                        req: v.req,
                        sink: v.sink,
                        emitted: v.stream_sent,
                        text_emitted: v.text_flushed,
                        admitted_at: Some(v.admitted_at),
                        first_token_at: v.first_token_at,
                        deadline: v.deadline,
                        submitted_at: v.submitted_at,
                    });
                    if victim == i {
                        evicted_self = true;
                        break;
                    }
                }
                if !evicted_self {
                    i += 1;
                }
                // On self-eviction a younger sequence shifted into slot `i`;
                // re-process that slot without advancing.
            }
            stats.kv_blocks_high_water = stats.kv_blocks_high_water.max(arena.high_water());
            stats.peak_kv_bytes = stats.peak_kv_bytes.max(arena.high_water() * *block_bytes);
        }
    }

    /// One round: every active sequence executes its plan — decoding
    /// sequences advance one sampled token through a single fused decode call
    /// (each packed weight tile decoded once for the whole batch), and
    /// prefilling sequences advance up to `prefill_chunk` prompt positions
    /// through one GEMM [`Transformer::prefill_chunk_paged`] call each (each
    /// tile decoded once per chunk). Single-token prefill plans join the
    /// fused batch so cross-sequence amortization is never lost. Finishes by
    /// retiring completed sequences and reclaiming their KV the same round.
    fn round(&mut self, pool: &ExecPool, tok: &ByteTokenizer, stats: &mut ServerStats) {
        let max_seq = self.max_seq;
        // Chaos hooks: an injected stall exercises the watchdog; an injected
        // panic exercises lane poisoning (caught by serve_loop's
        // catch_unwind). Both are never-taken branches without a plan.
        if let Some(plan) = &self.fault {
            if plan.fire_keyed(fault::ROUND_STALL, &self.name) {
                std::thread::sleep(Duration::from_millis(plan.stall_ms()));
            }
            if plan.fire_keyed(fault::DECODE_PANIC, &self.name) {
                panic!("injected decode panic (lane '{}')", self.name);
            }
        }
        let round_start = std::time::Instant::now();
        self.finished.clear();
        self.step_idx.clear();
        self.step_tokens.clear();
        self.chunk_idx.clear();
        for (i, a) in self.active.iter_mut().enumerate() {
            if a.stalled {
                // Waiting out one round for a finisher's blocks (capacity
                // phase); neither prefill nor emission advances.
                a.stalled = false;
                continue;
            }
            if !a.pending_prompt.is_empty() {
                match a.planned {
                    // Budget-deferred this round: the prompt waits its turn.
                    0 => {}
                    // A 1-token plan rides the fused batch with the decode
                    // steps — cross-sequence amortization is never lost.
                    1 => {
                        let t = a.pending_prompt.pop_front().expect("non-empty checked");
                        self.step_idx.push(i);
                        self.step_tokens.push(t);
                    }
                    // Multi-position chunk: executed below, after the fused
                    // round (tokens drained there, against the staging buffer).
                    _ => self.chunk_idx.push(i),
                }
                continue;
            }
            let t = a.next_token.expect("decoding sequence always holds a sampled token");
            a.generated.push(t);
            a.context.push(t);
            if a.first_token_at.is_none() {
                a.first_token_at = Some(std::time::Instant::now());
            }
            let idx = a.generated.len() - 1;
            if let Sink::Stream(txs) = &a.sink {
                // Deliver the token the round it is produced. A dead receiver
                // means the client is gone: cancel the sequence so its blocks
                // free this round instead of decoding to completion. A *full*
                // buffer means the client stopped reading: cancel it too
                // (slow-client backpressure) — the batcher never blocks on a
                // reader and never buffers more than `stream_buffer` events.
                if idx >= a.stream_sent {
                    // Text = whatever newly-complete UTF-8 the byte stream now
                    // holds (a multi-byte character split across tokens is
                    // held back until whole, never emitted as per-byte U+FFFD).
                    let pending: Vec<u8> = a.generated[a.text_flushed..]
                        .iter()
                        .map(|&b| (b & 0xFF) as u8)
                        .collect();
                    let (consumed, text) = utf8_flush(&pending);
                    let ev = StreamEvent::Token { id: a.req.id, index: idx, token: t, text };
                    match txs.try_send(ev) {
                        Ok(()) => {
                            a.stream_sent = idx + 1;
                            a.text_flushed += consumed;
                        }
                        Err(TrySendError::Full(_)) => {
                            stats.shed_slow_clients += 1;
                            a.dropped = true;
                            self.finished.push(i);
                            continue;
                        }
                        Err(TrySendError::Disconnected(_)) => {
                            a.dropped = true;
                            self.finished.push(i);
                            continue;
                        }
                    }
                }
            }
            let done = a.generated.len() >= a.req.max_new_tokens
                || a.kv_len() + 1 >= a.kv_cap(max_seq);
            if done {
                self.finished.push(i);
                continue;
            }
            self.step_idx.push(i);
            self.step_tokens.push(t);
        }

        if !self.step_idx.is_empty() {
            // One allocation-free fused round: every temporary lives in the
            // persistent scratch arena, every linear is striped across the
            // pool, and a 1-sequence round takes the tighter single-column
            // kernels — outputs are bit-identical either way, and identical
            // between the paged and contiguous KV layouts.
            let logits = match &mut self.backend {
                KvBackend::Contig { .. } => {
                    let mut caches: Vec<&mut KvCache> = Vec::with_capacity(self.step_idx.len());
                    let mut want = self.step_idx.iter().peekable();
                    for (i, a) in self.active.iter_mut().enumerate() {
                        if want.peek() == Some(&&i) {
                            want.next();
                            let SeqKv::Contig(c) = &mut a.kv else {
                                unreachable!("contiguous backend holds contiguous caches")
                            };
                            caches.push(c);
                        }
                    }
                    self.model.decode_step_batch_with(
                        &mut caches,
                        &self.step_tokens,
                        &mut self.scratch,
                        pool,
                    )
                }
                KvBackend::Paged { arena, .. } => {
                    let mut seqs: Vec<&mut KvSeq> = Vec::with_capacity(self.step_idx.len());
                    let mut want = self.step_idx.iter().peekable();
                    for (i, a) in self.active.iter_mut().enumerate() {
                        if want.peek() == Some(&&i) {
                            want.next();
                            let SeqKv::Paged(s) = &mut a.kv else {
                                unreachable!("paged backend holds paged sequences")
                            };
                            seqs.push(s);
                        }
                    }
                    self.model.decode_step_batch_paged(
                        arena,
                        &mut seqs,
                        &self.step_tokens,
                        &mut self.scratch,
                        pool,
                    )
                }
            };
            stats.fused_rounds += 1;
            stats.max_fused_batch = stats.max_fused_batch.max(self.step_tokens.len());
            stats.total_step_tokens += self.step_tokens.len();
            for (j, &i) in self.step_idx.iter().enumerate() {
                let a = &mut self.active[i];
                if !a.pending_prompt.is_empty() {
                    // Mid-prefill: logits are discarded until the last prompt
                    // token has been consumed.
                    continue;
                }
                a.next_token = Some(Transformer::sample(
                    logits.row(j),
                    a.req.temperature,
                    a.req.top_k,
                    &mut a.rng,
                ));
            }
        }

        // Chunked GEMM prefill: each multi-position plan runs one
        // `prefill_chunk_paged` call, decoding every weight tile once for the
        // whole chunk instead of once per position. Runs after the fused
        // round so every sequence's plan executes exactly once; sequences are
        // independent, so per-chunk order cannot affect any output.
        for ci in 0..self.chunk_idx.len() {
            let i = self.chunk_idx[ci];
            let a = &mut self.active[i];
            let take = a.planned.min(a.pending_prompt.len());
            debug_assert!(take >= 2, "1-token plans join the fused batch");
            self.chunk_tokens.clear();
            for _ in 0..take {
                self.chunk_tokens
                    .push(a.pending_prompt.pop_front().expect("plan never exceeds the prompt"));
            }
            let KvBackend::Paged { arena, .. } = &mut self.backend else {
                unreachable!("prefill chunks are planned only for the paged backend")
            };
            let SeqKv::Paged(seq) = &mut a.kv else {
                unreachable!("paged backend holds paged sequences")
            };
            let logits = self.model.prefill_chunk_paged(
                arena,
                seq,
                &self.chunk_tokens,
                &mut self.scratch,
                pool,
            );
            stats.prefill_chunks += 1;
            stats.prefill_tokens_chunked += take;
            stats.total_step_tokens += take;
            if a.pending_prompt.is_empty() {
                // The chunk consumed the final prompt position: its logits
                // seed the first sample, exactly like the fused path's.
                a.next_token = Some(Transformer::sample(
                    logits,
                    a.req.temperature,
                    a.req.top_k,
                    &mut a.rng,
                ));
            }
        }

        if !self.step_idx.is_empty() || !self.chunk_idx.is_empty() {
            // Register every block the round just completed in the prefix
            // index (whole blocks only — a block's hash covers all of its
            // token ids). The index takes its own reference so the prefix
            // outlives the sequence; an already-registered logical prefix
            // (e.g. the privatized copy of a fully-shared prompt block)
            // dedupes and takes no reference.
            if let KvBackend::Paged { arena, prefix: Some(idx), .. } = &mut self.backend {
                let bp = arena.block_positions();
                for &i in self.step_idx.iter().chain(self.chunk_idx.iter()) {
                    let a = &mut self.active[i];
                    let SeqKv::Paged(seq) = &a.kv else {
                        unreachable!("paged backend holds paged sequences")
                    };
                    while (a.registered + 1) * bp <= seq.len {
                        let lo = a.registered * bp;
                        let toks = &a.context[lo..lo + bp];
                        let blk = seq.blocks()[a.registered];
                        if idx.insert(a.chain, toks, blk) {
                            arena.retain_block(blk);
                        }
                        a.chain = chain_hash(a.chain, toks);
                        a.registered += 1;
                    }
                }
            }
        }
        let round_secs = round_start.elapsed().as_secs_f64();
        stats.total_decode_secs += round_secs;
        // Smooth the round time for Retry-After hints: one slow round (a
        // watchdog-scale hiccup) shouldn't spike what shed clients are told.
        self.recent_round_secs = if self.recent_round_secs > 0.0 {
            0.8 * self.recent_round_secs + 0.2 * round_secs
        } else {
            round_secs
        };

        // Retire finished sequences (descending index; `remove` keeps the
        // survivors in admission order for the eviction policy). Blocks are
        // reclaimed here — the same round the sequence finishes.
        for i in self.finished.drain(..).rev() {
            let a = self.active.remove(i);
            release_seq(a.kv, &mut self.backend);
            if a.dropped {
                stats.cancelled += 1;
                continue;
            }
            let now = std::time::Instant::now();
            let total = (now - a.admitted_at).as_secs_f64();
            let ttft = a
                .first_token_at
                .map(|t| (t - a.admitted_at).as_secs_f64())
                .unwrap_or(total);
            let decode_secs = (total - ttft).max(1e-9);
            stats.completed += 1;
            stats.total_generated_tokens += a.generated.len();
            let resp = GenResponse {
                id: a.req.id,
                text: tok.decode(&a.generated),
                prompt_tokens: a.prompt_len,
                ttft,
                total_secs: total,
                decode_tok_per_sec: (a.generated.len() as f64 - 1.0).max(0.0) / decode_secs,
                tokens: a.generated,
                error: None,
            };
            a.sink.send_done(resp);
        }

        // Round boundary: every reference the scheduler knows about lives on
        // an active sequence's table or in the prefix index
        // (retired/evicted/cancelled tables were just released), so in debug
        // builds re-verify the arena's partition invariant —
        // free ⊎ uniquely-leased ⊎ shared = pool, every refcount equal to
        // the references held — before the next admission/eviction round can
        // compound a bookkeeping bug into KV corruption. Release builds skip
        // the O(blocks) walk.
        if cfg!(debug_assertions) {
            if let KvBackend::Paged { arena, prefix, .. } = &self.backend {
                let index_blocks: Vec<u32> =
                    prefix.as_ref().map(|p| p.blocks().collect()).unwrap_or_default();
                arena.assert_partition_with(
                    self.active.iter().map(|a| match &a.kv {
                        SeqKv::Paged(s) => s,
                        SeqKv::Contig(_) => {
                            unreachable!("paged backend holds paged sequences")
                        }
                    }),
                    index_blocks,
                );
            }
        }
    }
}

fn serve_loop(models: Vec<(String, Arc<Transformer>)>, cfg: ServerConfig, rx: Receiver<Msg>) {
    let tok = ByteTokenizer;
    let mut stats = ServerStats::default();
    let mut shutting_down: Option<Sender<ServerStats>> = None;
    // The loop owns the execution pool: workers persist across rounds
    // (spawned once, parked between jobs) and are shared by every lane —
    // per-lane scratch arenas mean the model forwards allocate nothing per
    // round.
    let mut pool = ExecPool::new(cfg.threads);
    // Arm the pool's chaos hook (`pool_panic`) from the same plan the lanes
    // use; a worker panic then surfaces through the lane round's
    // catch_unwind exactly like a kernel bug would.
    if let Some(plan) = cfg.fault.clone().or_else(|| fault::global().cloned()) {
        pool.set_fault_plan(plan);
    }
    let pool = pool;
    // Stuck-round detector; its Drop joins the thread on every return path.
    let watchdog = Watchdog::spawn(cfg.watchdog_ms);
    stats.workers = pool.width();
    stats.kv_layout = cfg.kv_layout.resolve().name().to_string();
    let mut lanes: Vec<Lane> = models
        .into_iter()
        .map(|(name, model)| Lane::new(name, model, &cfg, &mut stats))
        .collect();
    assert!(!lanes.is_empty(), "serve_loop needs at least one model");
    // Stats report the default lane's decode-kernel family (lanes may mix).
    stats.kernel = lanes[0]
        .model
        .decode_kernel()
        .map(|k| k.name().to_string())
        .unwrap_or_else(|| "dense".to_string());

    loop {
        // Drain the message queue (non-blocking while work exists; blocking idle).
        loop {
            let idle = lanes.iter().all(|l| l.active.is_empty() && l.waiting.is_empty());
            let msg = if idle && shutting_down.is_none() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Submit(req, sink) => {
                    if shutting_down.is_some() {
                        stats.rejected += 1;
                        sink.send_done(GenResponse::rejected(
                            req.id,
                            codes::SERVER_SHUTDOWN,
                            "server is shutting down".to_string(),
                        ));
                        continue;
                    }
                    // Route on the request's model field: empty selects the
                    // default (first) lane; an unknown name is a structured
                    // rejection, mirroring the admission-time verdicts.
                    let lane = if req.model.is_empty() {
                        Some(0)
                    } else {
                        lanes.iter().position(|l| l.name == req.model)
                    };
                    match lane {
                        Some(li) => lanes[li].submit(req, sink, &cfg, &mut stats),
                        None => {
                            stats.rejected += 1;
                            let avail = lanes
                                .iter()
                                .map(|l| l.name.as_str())
                                .collect::<Vec<_>>()
                                .join(", ");
                            sink.send_done(GenResponse::rejected(
                                req.id,
                                codes::UNKNOWN_MODEL,
                                format!("unknown model '{}' (available: {avail})", req.model),
                            ));
                        }
                    }
                }
                Msg::Cancel(id) => {
                    for lane in &mut lanes {
                        if lane.cancel(id, &mut stats) {
                            break;
                        }
                    }
                }
                Msg::Health(tx) => {
                    let snapshot = HealthSnapshot {
                        lanes: lanes
                            .iter()
                            .map(|l| LaneHealth {
                                name: l.name.clone(),
                                healthy: !l.failed,
                                active: l.active.len(),
                                queued: l.waiting.len(),
                                kv_blocks_free: l.backend.blocks_free(),
                                kv_blocks_total: l.backend.blocks_total(),
                            })
                            .collect(),
                    };
                    let _ = tx.send(snapshot);
                }
                Msg::Stats(tx) => {
                    stats.watchdog_stalls = watchdog.alarms();
                    let _ = tx.send(stats.clone());
                }
                Msg::Shutdown(tx) => shutting_down = Some(tx),
            }
        }
        stats.queue_high_water = stats
            .queue_high_water
            .max(lanes.iter().map(|l| l.waiting.len()).sum());

        // Deadline sweep + admission, panic-isolated per lane: a panic while
        // a lane manipulates its own arena poisons that lane only.
        for lane in &mut lanes {
            if lane.failed {
                continue;
            }
            let ok = catch_unwind(AssertUnwindSafe(|| {
                lane.expire_deadlines(&mut stats);
                lane.admit(&cfg, &tok, &mut stats);
            }));
            if ok.is_err() {
                lane.poison(&mut stats);
            }
        }
        let total_active: usize = lanes.iter().map(|l| l.active.len()).sum();
        stats.peak_batch = stats.peak_batch.max(total_active);
        stats.peak_active = stats.peak_active.max(total_active);

        if total_active == 0 {
            if let Some(tx) = shutting_down.take() {
                if lanes.iter().all(|l| l.waiting.is_empty()) {
                    stats.watchdog_stalls = watchdog.alarms();
                    let _ = tx.send(stats.clone());
                    return;
                }
                shutting_down = Some(tx);
            }
            continue;
        }

        // Decode rounds, panic-isolated per lane: `catch_unwind` confines an
        // escaped panic (a kernel bug, or an injected `decode_panic` fault)
        // to the lane whose round raised it — its requests fail with
        // structured errors and the other lanes keep serving. The watchdog
        // brackets the rounds so a wedged round (as opposed to a panicking
        // one) gets diagnosed with the lane state captured on entry.
        watchdog.enter_rounds(&lanes);
        for lane in &mut lanes {
            if lane.failed || lane.active.is_empty() {
                continue;
            }
            let ok = catch_unwind(AssertUnwindSafe(|| {
                lane.plan_round(&mut stats);
                lane.capacity_phase(&mut stats);
                lane.round(&pool, &tok, &mut stats);
            }));
            if ok.is_err() {
                lane.poison(&mut stats);
            }
        }
        watchdog.exit_rounds();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, WeightStore};

    fn tiny_model() -> Arc<Transformer> {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 64;
        Arc::new(Transformer::from_store(&WeightStore::random(&cfg, 7)))
    }

    fn req(id: u64, prompt: &str, n: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: prompt.into(),
            max_new_tokens: n,
            temperature: 0.0,
            top_k: 1,
            seed: id,
            model: String::new(),
            deadline_ms: 0,
        }
    }

    #[test]
    fn serves_single_request() {
        let server = ServerHandle::spawn(tiny_model(), ServerConfig::default());
        let rx = server.submit(req(1, "hello", 8));
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.tokens.len(), 8);
        assert!(resp.ttft >= 0.0 && resp.total_secs >= resp.ttft);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.total_generated_tokens, 8);
        // tiny_model is fully dense, so the stats must say so rather than
        // claim a decode-kernel family that never ran.
        assert_eq!(stats.kernel, "dense");
        // Default layout resolves to the paged arena, and the stats carry its
        // geometry.
        assert_eq!(stats.kv_layout, "paged");
        assert!(stats.kv_block_positions > 0);
        assert!(stats.kv_blocks_total > 0);
        assert!(stats.kv_blocks_high_water >= 1);
        assert_eq!(stats.peak_active, 1);
    }

    #[test]
    fn batched_equals_sequential() {
        // Correctness invariant of the batcher: per-request outputs must be
        // identical to running each request alone (sequences are
        // independent), even though all sequences share one fused decode
        // pass per round — and, under the paged layout, one block arena.
        let model = tiny_model();
        let server = ServerHandle::spawn(model.clone(), ServerConfig::default());
        let reqs: Vec<GenRequest> =
            (0..6).map(|i| req(i, &format!("prompt {i}"), 6 + i as usize)).collect();
        let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
        let batched: Vec<GenResponse> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        let stats = server.shutdown();
        // The fused kernel must actually have been used: at least one round
        // advanced several sequences through a single fused call.
        assert!(
            stats.max_fused_batch >= 2,
            "6 concurrent requests never shared a fused round (max fused batch {})",
            stats.max_fused_batch
        );
        assert!(stats.fused_rounds > 0);

        for (r, b) in reqs.iter().zip(&batched) {
            let solo_server = ServerHandle::spawn(model.clone(), ServerConfig::default());
            let solo = solo_server.submit(r.clone()).recv().unwrap();
            solo_server.shutdown();
            assert_eq!(solo.tokens, b.tokens, "request {} diverged under batching", r.id);
        }
    }

    #[test]
    fn contig_and_paged_serve_identical_tokens() {
        // The paged arena is bit-identical to the contiguous reference
        // layout, so the same request mix must produce the same tokens under
        // both schedulers (including a deliberately tiny block size that
        // forces mid-sequence block-table boundaries).
        let model = tiny_model();
        let run = |layout: KvLayout, kv_block: usize| -> Vec<Vec<u16>> {
            let server = ServerHandle::spawn(
                model.clone(),
                ServerConfig { kv_layout: layout, kv_block, ..Default::default() },
            );
            let rxs: Vec<_> = (0..5)
                .map(|i| server.submit(req(i, &format!("p{i}"), 5 + i as usize)))
                .collect();
            let out = rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect();
            server.shutdown();
            out
        };
        let reference = run(KvLayout::Contig, 0);
        for block in [1usize, 3, 32] {
            assert_eq!(
                run(KvLayout::Paged, block),
                reference,
                "paged serving (block={block}) diverged from the contiguous reference"
            );
        }
    }

    #[test]
    fn contig_oversized_kv_request_is_rejected_not_spun_on() {
        // Regression (contiguous reference scheduler): a request whose KV
        // cache exceeds the budget used to sit in `waiting` forever while
        // serve_loop busy-spun and shutdown never completed. It must be
        // rejected with an error response.
        let model = tiny_model();
        let per_seq = KvCache::size_bytes_for(&model.cfg);
        let server = ServerHandle::spawn(
            model,
            ServerConfig {
                max_batch: 4,
                kv_budget_bytes: per_seq - 1,
                kv_layout: KvLayout::Contig,
                ..Default::default()
            },
        );
        let resp = server.submit(req(7, "hello", 8)).recv().unwrap();
        assert!(resp.error.is_some(), "unservable request must carry an error");
        assert!(resp.tokens.is_empty());
        // Shutdown must complete (this used to hang).
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.kv_layout, "contig");
    }

    #[test]
    fn paged_serves_where_sequence_granular_admission_rejects() {
        // The point of the arena: a budget below one full contiguous cache
        // still serves requests whose actual footprint fits in blocks.
        let model = tiny_model();
        let per_seq = KvCache::size_bytes_for(&model.cfg);
        let server = ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 4, kv_budget_bytes: per_seq - 1, ..Default::default() },
        );
        let resp = server.submit(req(7, "hello", 8)).recv().unwrap();
        assert!(resp.error.is_none(), "paged layout must serve: {:?}", resp.error);
        assert_eq!(resp.tokens.len(), 8);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.rejected, 0);
    }

    #[test]
    fn paged_unservable_request_is_rejected_not_spun_on() {
        // A budget too small for even one block can never serve anything:
        // reject (with shutdown completing), don't busy-spin.
        let server = ServerHandle::spawn(
            tiny_model(),
            ServerConfig { max_batch: 2, kv_budget_bytes: 1, ..Default::default() },
        );
        let resp = server.submit(req(3, "x", 4)).recv().unwrap();
        assert!(resp.error.is_some());
        let err = resp.error.unwrap();
        assert_eq!(err.code, codes::KV_BUDGET);
        assert!(err.message.contains("budget"));
        let stats = server.shutdown();
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.kv_blocks_total, 0);
    }

    #[test]
    fn continuous_batching_admits_more_than_sequence_granular() {
        // Acceptance: under the same kv_budget_bytes, token-granular
        // admission must reach strictly higher concurrency on mixed-length
        // traffic than reserving a full max_seq cache per sequence.
        let model = tiny_model();
        let per_seq = KvCache::size_bytes_for(&model.cfg);
        let budget = 2 * per_seq; // contiguous admission caps at 2 sequences
        let run = |layout: KvLayout| {
            let server = ServerHandle::spawn(
                model.clone(),
                ServerConfig {
                    max_batch: 8,
                    kv_budget_bytes: budget,
                    kv_layout: layout,
                    ..Default::default()
                },
            );
            let rxs: Vec<_> =
                (0..6).map(|i| server.submit(req(i, "q", 40 + i as usize))).collect();
            for rx in rxs {
                assert!(rx.recv().unwrap().error.is_none());
            }
            server.shutdown()
        };
        let contig = run(KvLayout::Contig);
        let paged = run(KvLayout::Paged);
        assert_eq!(contig.completed, 6);
        assert_eq!(paged.completed, 6);
        assert!(contig.peak_active <= 2, "budget admits 2 full caches, got {}", contig.peak_active);
        assert!(
            paged.peak_active > contig.peak_active,
            "paged admission ({}) must beat sequence-granular ({}) under the same budget",
            paged.peak_active,
            contig.peak_active
        );
    }

    #[test]
    fn eviction_under_pressure_requeues_and_preserves_outputs() {
        // Two long generations that cannot both fit the arena: the youngest
        // is preempted (blocks freed, re-queued, restarted) and both must
        // still complete with tokens identical to running each alone.
        let model = tiny_model();
        let block = 8usize;
        let blocks_for_max = model.cfg.max_seq.div_ceil(block); // 8 blocks
        let budget = blocks_for_max * KvArena::block_bytes(&model.cfg, block);
        let pressured = ServerConfig {
            max_batch: 2,
            kv_budget_bytes: budget,
            kv_block: block,
            kv_layout: KvLayout::Paged,
            ..Default::default()
        };
        let server = ServerHandle::spawn(model.clone(), pressured);
        let ra = req(1, "a", 40);
        let rb = req(2, "b", 40);
        let rx_a = server.submit(ra.clone());
        let rx_b = server.submit(rb.clone());
        let a = rx_a.recv().unwrap();
        let b = rx_b.recv().unwrap();
        let stats = server.shutdown();
        assert_eq!(stats.completed, 2);
        assert!(
            stats.evictions >= 1,
            "40+40 generated positions in an {blocks_for_max}-block arena must evict"
        );
        for (r, got) in [(ra, a), (rb, b)] {
            let solo = ServerHandle::spawn(model.clone(), ServerConfig::default());
            let want = solo.submit(r.clone()).recv().unwrap();
            solo.shutdown();
            assert_eq!(want.tokens, got.tokens, "request {} corrupted by eviction", r.id);
        }
    }

    #[test]
    fn streaming_emits_every_token_then_done() {
        let model = tiny_model();
        let server = ServerHandle::spawn(model.clone(), ServerConfig::default());
        let unary = server.submit(req(1, "stream me", 9)).recv().unwrap();
        let rx = server.submit_stream(req(2, "stream me", 9));
        let mut streamed: Vec<u16> = Vec::new();
        let mut done: Option<GenResponse> = None;
        for ev in rx.iter() {
            match ev {
                StreamEvent::Token { id, index, token, .. } => {
                    assert_eq!(id, 2);
                    assert_eq!(index, streamed.len(), "token indices must be contiguous");
                    streamed.push(token);
                }
                StreamEvent::Done(r) => {
                    done = Some(r);
                    break;
                }
            }
        }
        let done = done.expect("stream must terminate with Done");
        assert_eq!(streamed, unary.tokens, "streamed tokens diverged from unary response");
        assert_eq!(done.tokens, streamed);
        assert!(done.error.is_none());
        server.shutdown();
    }

    #[test]
    fn dropped_stream_receiver_cancels_and_frees_blocks() {
        // A disconnected streaming client must not hold KV blocks: size the
        // arena so a second full-length request can only be admitted once the
        // first's blocks are reclaimed, drop the first mid-generation, and
        // require the second to complete.
        let model = tiny_model();
        let block = 8usize;
        let budget = model.cfg.max_seq.div_ceil(block) * KvArena::block_bytes(&model.cfg, block);
        let server = ServerHandle::spawn(
            model,
            ServerConfig {
                max_batch: 2,
                kv_budget_bytes: budget,
                kv_block: block,
                kv_layout: KvLayout::Paged,
                ..Default::default()
            },
        );
        let rx = server.submit_stream(req(1, "long", 60));
        // Wait for generation to actually start, then vanish.
        match rx.recv().unwrap() {
            StreamEvent::Token { .. } => {}
            ev => panic!("expected a token first, got {ev:?}"),
        }
        drop(rx);
        let resp = server.submit(req(2, "after", 50)).recv().unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens.len(), 50);
        let stats = server.shutdown();
        assert_eq!(stats.cancelled, 1, "dropped stream must be cancelled");
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn explicit_cancel_reclaims_a_waiting_or_active_request() {
        let server = ServerHandle::spawn(tiny_model(), ServerConfig::default());
        let rx = server.submit(req(5, "cancel me", 60));
        server.cancel(5);
        let follow = server.submit(req(6, "serve me", 4)).recv().unwrap();
        assert_eq!(follow.tokens.len(), 4);
        // The cancelled request never answers: its sender is dropped.
        assert!(rx.recv().is_err(), "cancelled request must not receive a response");
        let stats = server.shutdown();
        assert_eq!(stats.cancelled, 1);
        assert_eq!(stats.completed, 1);
    }

    #[test]
    fn utf8_flush_reassembles_multibyte_sequences() {
        // 'é' = 0xC3 0xA9 split across two tokens: the lone lead byte is held
        // back (nothing emitted), then the pair flushes as one character.
        assert_eq!(utf8_flush(&[0xC3]), (0, String::new()));
        assert_eq!(utf8_flush(&[0xC3, 0xA9]), (2, "é".to_string()));
        // ASCII passes straight through.
        assert_eq!(utf8_flush(b"ab"), (2, "ab".to_string()));
        // A definitively invalid byte becomes exactly one replacement char
        // and does not block the bytes after it.
        assert_eq!(utf8_flush(&[0xFF, b'x']), (2, "\u{FFFD}x".to_string()));
        let (c, s) = utf8_flush(&[0xC3, b'x']);
        assert_eq!((c, s.as_str()), (2, "\u{FFFD}x"));
        assert_eq!(utf8_flush(&[]), (0, String::new()));
    }

    #[test]
    fn empty_prompt_samples_from_real_logits() {
        // Regression: an empty prompt used to leave logits = [0.0], so sampling
        // ran over a 1-element "vocab" and the first token was always 0. The
        // server now feeds a BOS fallback token, which makes an empty prompt
        // behave exactly like a prompt containing only byte 0.
        let model = tiny_model();
        let server = ServerHandle::spawn(model, ServerConfig::default());
        let empty = server.submit(req(1, "", 6)).recv().unwrap();
        let bos = server.submit(req(2, "\0", 6)).recv().unwrap();
        server.shutdown();
        assert!(empty.error.is_none());
        assert_eq!(empty.tokens.len(), 6);
        assert_eq!(empty.tokens, bos.tokens, "empty prompt must equal explicit BOS prompt");
        assert_eq!(empty.prompt_tokens, 1);
    }

    #[test]
    fn prefill_is_chunked_through_the_gemm_path() {
        // Default config: a 10-token prompt fits one GEMM prefill chunk, so
        // the whole prompt advances in a single chunked call instead of 10
        // one-token fused rounds.
        let server = ServerHandle::spawn(tiny_model(), ServerConfig::default());
        let resp = server.submit(req(1, "0123456789", 4)).recv().unwrap();
        let stats = server.shutdown();
        assert_eq!(resp.tokens.len(), 4);
        assert_eq!(resp.prompt_tokens, 10);
        assert_eq!(stats.prefill_chunks, 1, "10 tokens ≤ default chunk ⇒ one chunked call");
        assert_eq!(stats.prefill_tokens_chunked, 10);
        assert!(
            stats.fused_rounds < 10,
            "chunked prefill must collapse the 10 one-token prefill rounds, got {}",
            stats.fused_rounds
        );

        // --prefill-chunk 1 reproduces the legacy behavior bit-for-bit: one
        // prompt token per fused round, no chunked calls — and the same
        // output tokens either way.
        let server = ServerHandle::spawn(
            tiny_model(),
            ServerConfig { prefill_chunk: 1, ..Default::default() },
        );
        let legacy = server.submit(req(1, "0123456789", 4)).recv().unwrap();
        let stats = server.shutdown();
        assert_eq!(legacy.tokens, resp.tokens, "chunked prefill changed the output");
        assert_eq!(stats.prefill_chunks, 0);
        assert_eq!(stats.prefill_tokens_chunked, 0);
        assert!(
            stats.fused_rounds >= 10 + 3,
            "expected ≥ 13 fused rounds (10 prefill + 3 decode), got {}",
            stats.fused_rounds
        );
    }

    #[test]
    fn round_budget_defers_prefill_without_changing_outputs() {
        // Two long prompts through a round budget smaller than their combined
        // chunk demand: the scheduler must defer (counting budget_deferrals)
        // but never change what either request generates.
        let model = tiny_model();
        let long = "a".repeat(48);
        let run = |round_budget: usize| {
            let server = ServerHandle::spawn(
                model.clone(),
                ServerConfig {
                    max_batch: 4,
                    prefill_chunk: 8,
                    round_budget,
                    ..Default::default()
                },
            );
            let rx1 = server.submit(req(1, &long, 6));
            let rx2 = server.submit(req(2, &long, 6));
            let out = (rx1.recv().unwrap().tokens, rx2.recv().unwrap().tokens);
            (out, server.shutdown())
        };
        let (free_out, free_stats) = run(0);
        let (tight_out, tight_stats) = run(8);
        assert_eq!(free_out.0.len(), 6);
        assert_eq!(free_out, tight_out, "a round budget must never change outputs");
        assert_eq!(free_stats.budget_deferrals, 0, "no budget ⇒ no deferrals");
        assert!(
            tight_stats.budget_deferrals > 0,
            "two 48-token prompts through an 8-token round budget must defer"
        );
    }

    #[test]
    fn retry_after_hint_scales_with_queue_depth_and_round_time() {
        let mut stats = ServerStats::default();
        let mut lane =
            Lane::new("l".into(), tiny_model(), &ServerConfig::default(), &mut stats);
        // Cold lane (no completed round): 10 ms guess, floor of one queued.
        assert_eq!(lane.retry_after_hint_ms(), 10);
        lane.recent_round_secs = 0.002;
        for _ in 0..3 {
            lane.waiting.push_back(Pending::new(
                GenRequest::default(),
                Sink::Unary(channel().0),
                None,
            ));
        }
        // 3 queued × 2 ms/round = 6 ms.
        assert_eq!(lane.retry_after_hint_ms(), 6);
    }

    #[test]
    fn respects_max_batch() {
        let model = tiny_model();
        let server = ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 2, kv_budget_bytes: 1 << 30, ..Default::default() },
        );
        let rxs: Vec<_> = (0..5).map(|i| server.submit(req(i, "x", 4))).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().tokens.len(), 4);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 5);
        assert!(stats.peak_batch <= 2);
        assert!(stats.queue_high_water >= 1, "5 requests through a 2-wide batch must queue");
    }

    #[test]
    fn kv_budget_limits_admission() {
        let model = tiny_model();
        let per_seq = KvCache::new(&model.cfg).size_bytes();
        let server = ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 8, kv_budget_bytes: per_seq * 2, ..Default::default() },
        );
        let rxs: Vec<_> = (0..4).map(|i| server.submit(req(i, "y", 3))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = server.shutdown();
        assert!(stats.peak_kv_bytes <= per_seq * 2);
        assert_eq!(stats.completed, 4);
    }

    #[test]
    fn deterministic_sampling_given_seed() {
        let model = tiny_model();
        let server = ServerHandle::spawn(model, ServerConfig::default());
        let mk = || GenRequest {
            id: 9,
            prompt: "abc".into(),
            max_new_tokens: 10,
            temperature: 0.8,
            top_k: 20,
            seed: 1234,
            model: String::new(),
            deadline_ms: 0,
        };
        let a = server.submit(mk()).recv().unwrap();
        let b = server.submit(mk()).recv().unwrap();
        server.shutdown();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn long_prompt_is_truncated_to_fit() {
        let server = ServerHandle::spawn(tiny_model(), ServerConfig::default());
        let long: String = "z".repeat(500);
        let resp = server.submit(req(1, &long, 4)).recv().unwrap();
        assert_eq!(resp.tokens.len(), 4);
        server.shutdown();
    }

    #[test]
    fn serving_is_deterministic_across_pool_widths() {
        // Thread-count invariance at the serving level: the same request mix
        // must produce identical tokens whether the loop decodes on one
        // worker or four — the tile-parallel kernels never reorder any
        // per-sequence accumulation.
        let model = tiny_model();
        let run = |threads: usize| -> Vec<Vec<u16>> {
            let server = ServerHandle::spawn(
                model.clone(),
                ServerConfig { max_batch: 4, threads, ..Default::default() },
            );
            let rxs: Vec<_> = (0..5)
                .map(|i| {
                    server.submit(GenRequest {
                        id: i,
                        prompt: format!("prompt {i}"),
                        max_new_tokens: 6 + i as usize,
                        temperature: 0.8,
                        top_k: 16,
                        seed: 99 + i,
                        model: String::new(),
                        deadline_ms: 0,
                    })
                })
                .collect();
            let out = rxs.into_iter().map(|rx| rx.recv().unwrap().tokens).collect();
            let stats = server.shutdown();
            assert_eq!(stats.workers, threads.max(1));
            out
        };
        let seq = run(1);
        for threads in [2usize, 4] {
            assert_eq!(
                run(threads),
                seq,
                "serve_loop output changed under a {threads}-worker pool"
            );
        }
    }

    fn second_model() -> Arc<Transformer> {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 64;
        Arc::new(Transformer::from_store(&WeightStore::random(&cfg, 99)))
    }

    #[test]
    fn multi_model_routes_by_name_with_isolated_kv() {
        // Two models behind one coordinator: routing on the request's model
        // field must reach the right weights (different seeds => different
        // greedy generations) while each lane's KV arena stays isolated and
        // both share one ExecPool.
        let (ma, mb) = (tiny_model(), second_model());
        let solo_a = {
            let s = ServerHandle::spawn(ma.clone(), ServerConfig::default());
            let t = s.submit(req(1, "hello", 8)).recv().unwrap().tokens;
            s.shutdown();
            t
        };
        let solo_b = {
            let s = ServerHandle::spawn(mb.clone(), ServerConfig::default());
            let t = s.submit(req(1, "hello", 8)).recv().unwrap().tokens;
            s.shutdown();
            t
        };
        assert_ne!(solo_a, solo_b, "test models must diverge for routing to be observable");

        let server = ServerHandle::spawn_multi(
            vec![("alpha".to_string(), ma), ("beta".to_string(), mb)],
            ServerConfig { max_batch: 4, ..Default::default() },
        );
        assert_eq!(server.models(), ["alpha".to_string(), "beta".to_string()]);
        let mut ra = req(1, "hello", 8);
        ra.model = "alpha".into();
        let mut rb = req(2, "hello", 8);
        rb.model = "beta".into();
        // Submit both before receiving either so the lanes serve concurrently.
        let (rx_a, rx_b) = (server.submit(ra), server.submit(rb));
        let (out_a, out_b) = (rx_a.recv().unwrap(), rx_b.recv().unwrap());
        assert_eq!(out_a.tokens, solo_a, "lane 'alpha' diverged from a solo server");
        assert_eq!(out_b.tokens, solo_b, "lane 'beta' diverged from a solo server");
        let stats = server.shutdown();
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn multi_model_unknown_name_is_rejected_and_empty_routes_default() {
        let server = ServerHandle::spawn_multi(
            vec![("alpha".to_string(), tiny_model()), ("beta".to_string(), second_model())],
            ServerConfig::default(),
        );
        let mut bad = req(7, "x", 4);
        bad.model = "gamma".into();
        let resp = server.submit(bad).recv().unwrap();
        let err = resp.error.expect("unknown model must yield a structured error");
        assert_eq!(err.code, codes::UNKNOWN_MODEL);
        assert!(err.message.contains("unknown model 'gamma'"), "error was: {err}");
        assert!(
            err.message.contains("alpha") && err.message.contains("beta"),
            "error lists lanes: {err}"
        );

        // Empty model field falls back to the default (first) lane.
        let default_out = server.submit(req(8, "x", 4)).recv().unwrap();
        let mut explicit = req(8, "x", 4);
        explicit.model = "alpha".into();
        let explicit_out = server.submit(explicit).recv().unwrap();
        assert_eq!(default_out.tokens, explicit_out.tokens);
        let stats = server.shutdown();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.completed, 2);
    }
}
