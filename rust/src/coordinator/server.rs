//! The generation server: request queue → continuous batcher → token streaming.
//!
//! Table 4's serving context: batch-1 decoding is memory-bound, so the quantized
//! model's fused decode-matvec is the hot path. The coordinator contributes the
//! vLLM-style machinery around it: admission control against a KV-memory budget,
//! a KV-cache pool (allocate on admit, recycle on completion), round-robin
//! continuous batching (new requests join mid-flight), and per-request metrics
//! (TTFT, decode tok/s).

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use crate::model::transformer::{KvCache, Transformer};
use crate::model::ByteTokenizer;
use crate::util::rng::Rng;

/// A generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
    /// 0.0 => greedy.
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
}

/// Completion with per-request serving metrics.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    pub text: String,
    pub tokens: Vec<u16>,
    pub prompt_tokens: usize,
    /// Seconds from admission to first generated token.
    pub ttft: f64,
    pub total_secs: f64,
    pub decode_tok_per_sec: f64,
}

struct Active {
    req: GenRequest,
    cache: KvCache,
    generated: Vec<u16>,
    rng: Rng,
    next_token: u16,
    admitted_at: std::time::Instant,
    first_token_at: Option<std::time::Instant>,
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Max concurrently-decoding sequences.
    pub max_batch: usize,
    /// KV memory budget in bytes (admission control).
    pub kv_budget_bytes: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 8, kv_budget_bytes: 256 << 20 }
    }
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub completed: usize,
    pub total_generated_tokens: usize,
    pub total_decode_secs: f64,
    pub peak_batch: usize,
    pub peak_kv_bytes: usize,
}

impl ServerStats {
    pub fn throughput_tok_per_sec(&self) -> f64 {
        if self.total_decode_secs == 0.0 {
            return 0.0;
        }
        self.total_generated_tokens as f64 / self.total_decode_secs
    }
}

enum Msg {
    Submit(GenRequest, Sender<GenResponse>),
    Shutdown(Sender<ServerStats>),
}

/// Handle for submitting requests to a running server.
pub struct ServerHandle {
    tx: Sender<Msg>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Spawn the serving loop on its own thread.
    pub fn spawn(model: Arc<Transformer>, cfg: ServerConfig) -> ServerHandle {
        let (tx, rx) = channel::<Msg>();
        let join = std::thread::spawn(move || serve_loop(model, cfg, rx));
        ServerHandle { tx, join: Some(join) }
    }

    /// Submit a request; the response arrives on the returned receiver.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        self.tx.send(Msg::Submit(req, tx)).expect("server gone");
        rx
    }

    /// Graceful shutdown: drains in-flight work, returns aggregate stats.
    pub fn shutdown(mut self) -> ServerStats {
        let (tx, rx) = channel();
        let _ = self.tx.send(Msg::Shutdown(tx));
        let stats = rx.recv().unwrap_or_default();
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
        stats
    }
}

fn serve_loop(model: Arc<Transformer>, cfg: ServerConfig, rx: Receiver<Msg>) {
    let tok = ByteTokenizer;
    let mut waiting: VecDeque<(GenRequest, Sender<GenResponse>)> = VecDeque::new();
    let mut active: Vec<(Active, Sender<GenResponse>)> = Vec::new();
    let mut cache_pool: Vec<KvCache> = Vec::new();
    let mut stats = ServerStats::default();
    let mut shutting_down: Option<Sender<ServerStats>> = None;

    loop {
        // Drain the message queue (non-blocking while work exists; blocking idle).
        loop {
            let msg = if active.is_empty() && waiting.is_empty() && shutting_down.is_none() {
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => return,
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            };
            match msg {
                Msg::Submit(req, tx) => waiting.push_back((req, tx)),
                Msg::Shutdown(tx) => shutting_down = Some(tx),
            }
        }

        // Admission: fill the batch while the KV budget allows.
        let kv_bytes_per_seq = KvCache::new(&model.cfg).size_bytes();
        while active.len() < cfg.max_batch
            && !waiting.is_empty()
            && (active.len() + 1) * kv_bytes_per_seq <= cfg.kv_budget_bytes
        {
            let (req, tx) = waiting.pop_front().unwrap();
            let mut cache = cache_pool.pop().unwrap_or_else(|| KvCache::new(&model.cfg));
            cache.clear();
            // Prefill: run the prompt through the decode path.
            let prompt_tokens = tok.encode(&req.prompt);
            let budget = model.cfg.max_seq.saturating_sub(req.max_new_tokens + 1);
            let prompt_tokens: Vec<u16> =
                prompt_tokens.into_iter().take(budget.max(1)).collect();
            let admitted_at = std::time::Instant::now();
            let mut logits = vec![0.0];
            for &t in &prompt_tokens {
                logits = model.decode_step(&mut cache, t);
            }
            let mut rng = Rng::new(req.seed);
            let next = Transformer::sample(&logits, req.temperature, req.top_k, &mut rng);
            active.push((
                Active {
                    req,
                    cache,
                    generated: Vec::new(),
                    rng,
                    next_token: next,
                    admitted_at,
                    first_token_at: None,
                },
                tx,
            ));
            stats.peak_batch = stats.peak_batch.max(active.len());
            stats.peak_kv_bytes = stats.peak_kv_bytes.max(active.len() * kv_bytes_per_seq);
        }

        if active.is_empty() {
            if let Some(tx) = shutting_down.take() {
                if waiting.is_empty() {
                    let _ = tx.send(stats.clone());
                    return;
                }
                shutting_down = Some(tx);
            }
            continue;
        }

        // One decode round: each active sequence advances one token (round-robin
        // continuous batching — new admissions interleave between rounds).
        let round_start = std::time::Instant::now();
        let mut finished = Vec::new();
        for (i, (a, _)) in active.iter_mut().enumerate() {
            let t = a.next_token;
            a.generated.push(t);
            if a.first_token_at.is_none() {
                a.first_token_at = Some(std::time::Instant::now());
            }
            let done = a.generated.len() >= a.req.max_new_tokens
                || a.cache.len + 1 >= a.cache.capacity;
            if done {
                finished.push(i);
                continue;
            }
            let logits = model.decode_step(&mut a.cache, t);
            a.next_token =
                Transformer::sample(&logits, a.req.temperature, a.req.top_k, &mut a.rng);
        }
        stats.total_decode_secs += round_start.elapsed().as_secs_f64();

        // Retire finished sequences (largest index first).
        for i in finished.into_iter().rev() {
            let (a, tx) = active.swap_remove(i);
            let now = std::time::Instant::now();
            let total = (now - a.admitted_at).as_secs_f64();
            let ttft = a
                .first_token_at
                .map(|t| (t - a.admitted_at).as_secs_f64())
                .unwrap_or(total);
            let decode_secs = (total - ttft).max(1e-9);
            stats.completed += 1;
            stats.total_generated_tokens += a.generated.len();
            let resp = GenResponse {
                id: a.req.id,
                text: tok.decode(&a.generated),
                tokens: a.generated.clone(),
                prompt_tokens: a.cache.len - a.generated.len() + 1,
                ttft,
                total_secs: total,
                decode_tok_per_sec: (a.generated.len() as f64 - 1.0).max(0.0) / decode_secs,
            };
            cache_pool.push(a.cache);
            let _ = tx.send(resp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, WeightStore};

    fn tiny_model() -> Arc<Transformer> {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 64;
        Arc::new(Transformer::from_store(&WeightStore::random(&cfg, 7)))
    }

    fn req(id: u64, prompt: &str, n: usize) -> GenRequest {
        GenRequest {
            id,
            prompt: prompt.into(),
            max_new_tokens: n,
            temperature: 0.0,
            top_k: 1,
            seed: id,
        }
    }

    #[test]
    fn serves_single_request() {
        let server = ServerHandle::spawn(tiny_model(), ServerConfig::default());
        let rx = server.submit(req(1, "hello", 8));
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 1);
        assert_eq!(resp.tokens.len(), 8);
        assert!(resp.ttft >= 0.0 && resp.total_secs >= resp.ttft);
        let stats = server.shutdown();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.total_generated_tokens, 8);
    }

    #[test]
    fn batched_equals_sequential() {
        // Correctness invariant of the batcher: per-request outputs must be
        // identical to running each request alone (caches are independent).
        let model = tiny_model();
        let server = ServerHandle::spawn(model.clone(), ServerConfig::default());
        let reqs: Vec<GenRequest> =
            (0..6).map(|i| req(i, &format!("prompt {i}"), 6 + i as usize)).collect();
        let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
        let batched: Vec<GenResponse> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        server.shutdown();

        for (r, b) in reqs.iter().zip(&batched) {
            let solo_server = ServerHandle::spawn(model.clone(), ServerConfig::default());
            let solo = solo_server.submit(r.clone()).recv().unwrap();
            solo_server.shutdown();
            assert_eq!(solo.tokens, b.tokens, "request {} diverged under batching", r.id);
        }
    }

    #[test]
    fn respects_max_batch() {
        let model = tiny_model();
        let server = ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 2, kv_budget_bytes: 1 << 30 },
        );
        let rxs: Vec<_> = (0..5).map(|i| server.submit(req(i, "x", 4))).collect();
        for rx in rxs {
            assert_eq!(rx.recv().unwrap().tokens.len(), 4);
        }
        let stats = server.shutdown();
        assert_eq!(stats.completed, 5);
        assert!(stats.peak_batch <= 2);
    }

    #[test]
    fn kv_budget_limits_admission() {
        let model = tiny_model();
        let per_seq = KvCache::new(&model.cfg).size_bytes();
        let server = ServerHandle::spawn(
            model,
            ServerConfig { max_batch: 8, kv_budget_bytes: per_seq * 2 },
        );
        let rxs: Vec<_> = (0..4).map(|i| server.submit(req(i, "y", 3))).collect();
        for rx in rxs {
            rx.recv().unwrap();
        }
        let stats = server.shutdown();
        assert!(stats.peak_kv_bytes <= per_seq * 2);
        assert_eq!(stats.completed, 4);
    }

    #[test]
    fn deterministic_sampling_given_seed() {
        let model = tiny_model();
        let server = ServerHandle::spawn(model, ServerConfig::default());
        let mk = || GenRequest {
            id: 9,
            prompt: "abc".into(),
            max_new_tokens: 10,
            temperature: 0.8,
            top_k: 20,
            seed: 1234,
        };
        let a = server.submit(mk()).recv().unwrap();
        let b = server.submit(mk()).recv().unwrap();
        server.shutdown();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn long_prompt_is_truncated_to_fit() {
        let server = ServerHandle::spawn(tiny_model(), ServerConfig::default());
        let long: String = "z".repeat(500);
        let resp = server.submit(req(1, &long, 4)).recv().unwrap();
        assert_eq!(resp.tokens.len(), 4);
        server.shutdown();
    }
}
