//! Quantized-artifact persistence: the "pay quantization once, serve many"
//! subsystem.
//!
//! `qtip quantize --save <name>` writes a versioned two-file artifact into the
//! artifacts directory:
//!
//! * `quant_<name>.json` — manifest: format version, model config, the
//!   [`QuantizeReport`] of the run that produced it, and per-layer decode
//!   metadata (trellis params, code spec, tile geometry, exact `f32` scale
//!   bits, blob offsets);
//! * `quant_<name>.bin`  — binary blob (little-endian): per-layer packed u32
//!   trellis bitstreams, RHT sign bits, Hyb/Lut decode tables, and the dense
//!   non-quantized tensors (embeddings, norms, head), guarded by an FNV-1a64
//!   checksum recorded in the manifest.
//!
//! [`load_quantized_model`] reassembles a serving-ready [`Transformer`] whose
//! `Linear::Quantized` layers are **bit-identical** to the freshly quantized
//! model — every quantity the decode hot path touches (packed words, scale
//! bits, sign bits, LUT entries) round-trips exactly, so `serve`/`generate`/
//! `eval --artifact` cold-start without re-running calibration or
//! BlockLDLQ+Viterbi. Workers in a future sharded deployment can load layers
//! from the same blob independently: every section is offset-addressed.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::QuantizeReport;
use crate::model::transformer::{Attention, Layer, Linear, Mlp, Transformer};
use crate::model::weights::{f32s_to_le_bytes, le_bytes_to_f32s, WeightStore};
use crate::model::ModelConfig;
use crate::quant::{
    registry, CodeSpec, QuantMetrics, QuantizedMatrix, RhtContext, TableSink, TableSource,
};
use crate::trellis::Trellis;
use crate::util::json::Json;
use crate::util::matrix::Matrix;
use crate::util::threadpool::ExecPool;

/// On-disk format version; bump on any incompatible layout change. v2 keys
/// each per-layer code object by a registry `method` id and delegates its
/// contents to the owning [`crate::quant::QuantMethod`].
pub const FORMAT_VERSION: usize = 2;
/// Oldest manifest version this build still reads. v1 manifests key the code
/// object by `name` with the same per-method fields, so the method parsers
/// read both; writes always use the current version.
pub const MIN_FORMAT_VERSION: usize = 1;
/// Manifest `kind` discriminator (shares the artifacts dir with model weights
/// and AOT kernels).
pub const ARTIFACT_KIND: &str = "qtip-quantized-model";

/// FNV-1a 64-bit checksum (offline stand-in for a real digest — stable,
/// dependency-free, and plenty to catch truncation/corruption).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Manifest path for artifact `name` under `dir`.
pub fn quant_manifest_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("quant_{name}.json"))
}

/// Summary of a saved quantized artifact (for `qtip info` and save/load logs).
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub manifest_path: PathBuf,
    pub blob_bytes: usize,
    pub config: ModelConfig,
    /// e.g. `"3inst L=12 k=2 V=1 tiles 16x16"`.
    pub quant_desc: String,
    /// Registry id of the quant method used (e.g. "3inst"); for v1 manifests
    /// this is recovered from the first token of `quant_desc`.
    pub method: String,
    pub quantized_layers: usize,
    /// KV-block geometry (positions per arena block) recorded at save time —
    /// no KV data lives in the artifact, but the manifest carries the serving
    /// geometry so a cold-started server defaults to it (0 when the manifest
    /// predates the field).
    pub kv_block: usize,
    /// Prefill-chunk geometry (prompt positions per GEMM prefill pass)
    /// recorded at save time, same contract as `kv_block`: a serving default
    /// the CLI/env can override, 0 when the manifest predates the field.
    pub prefill_chunk: usize,
}

/// Append-only blob builder; returns byte offsets for the manifest.
struct BlobWriter {
    buf: Vec<u8>,
}

impl BlobWriter {
    fn put_u32s(&mut self, words: &[u32]) -> usize {
        let off = self.buf.len();
        for &w in words {
            self.buf.extend_from_slice(&w.to_le_bytes());
        }
        off
    }

    fn put_f32s(&mut self, vals: &[f32]) -> usize {
        let off = self.buf.len();
        self.buf.extend_from_slice(&f32s_to_le_bytes(vals));
        off
    }
}

/// Methods write their decode tables through this bridge — they never see the
/// blob layout, only section offsets.
impl TableSink for BlobWriter {
    fn put_f32s(&mut self, vals: &[f32]) -> usize {
        BlobWriter::put_f32s(self, vals)
    }
}

/// Bounds-checked blob sections (every offset comes from the manifest, which
/// could be stale or hand-edited — never index past the blob).
struct BlobReader<'a> {
    buf: &'a [u8],
}

impl<'a> BlobReader<'a> {
    fn section(&self, off: usize, bytes: usize) -> Result<&'a [u8]> {
        off.checked_add(bytes)
            .and_then(|end| self.buf.get(off..end))
            .ok_or_else(|| {
                anyhow!(
                    "blob section [{off}, +{bytes}) out of range ({} blob bytes): \
                     truncated or mismatched artifact",
                    self.buf.len()
                )
            })
    }

    fn u32s(&self, off: usize, n: usize) -> Result<Vec<u32>> {
        let b = self.section(off, n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn f32s(&self, off: usize, n: usize) -> Result<Vec<f32>> {
        le_bytes_to_f32s(self.section(off, n * 4)?)
    }
}

/// Bounds-checked table reads for method spec deserialization.
impl TableSource for BlobReader<'_> {
    fn f32s(&self, off: usize, n: usize) -> Result<Vec<f32>> {
        BlobReader::f32s(self, off, n)
    }
}

fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

fn code_spec_to_json(code: &CodeSpec, blob: &mut BlobWriter) -> Json {
    // Method-owned serialization: the owning method writes its `method` id
    // and config fields, staging decode tables through the TableSink bridge.
    code.method().spec_to_json(code, blob)
}

fn code_spec_from_json(j: &Json, blob: &BlobReader, trellis: &Trellis) -> Result<CodeSpec> {
    // v2 manifests key the code object by `method`; v1 used `name` with the
    // same per-method fields, so resolving the id is the only version split.
    let id = j
        .get("method")
        .and_then(|m| m.as_str())
        .or_else(|| j.get("name").and_then(|m| m.as_str()))
        .ok_or_else(|| anyhow!("layer code object carries neither 'method' nor 'name'"))?;
    let method = registry::get(id).ok_or_else(|| {
        anyhow!(
            "unknown code '{id}' in quantized artifact (registered methods: {})",
            registry::names().join("|")
        )
    })?;
    let spec = method.spec_from_json(j, blob, trellis)?;
    if spec.v() != trellis.v {
        bail!("code dimension V={} disagrees with trellis V={}", spec.v(), trellis.v);
    }
    Ok(spec)
}

fn dense_entry(
    entries: &mut Vec<Json>,
    blob: &mut BlobWriter,
    name: String,
    rows: usize,
    cols: usize,
    data: &[f32],
) {
    assert_eq!(data.len(), rows * cols, "dense tensor '{name}' shape mismatch");
    let off = blob.put_f32s(data);
    entries.push(Json::obj(vec![
        ("name", Json::Str(name)),
        ("rows", num(rows)),
        ("cols", num(cols)),
        ("off", num(off)),
    ]));
}

/// Quant-method id from a manifest: v2 records it as `quant_method`; v1
/// manifests lead `quant_desc` with the method name ("3inst L=12 ...").
fn manifest_method(j: &Json) -> String {
    j.get("quant_method")
        .and_then(|m| m.as_str())
        .or_else(|| {
            j.get("quant_desc").and_then(|d| d.as_str()).and_then(|d| d.split_whitespace().next())
        })
        .unwrap_or("?")
        .to_string()
}

fn quant_desc(qm: &QuantizedMatrix) -> String {
    format!(
        "{} L={} k={} V={} tiles {}x{}",
        qm.code.name(),
        qm.trellis.l,
        qm.trellis.k,
        qm.trellis.v,
        qm.tx,
        qm.ty
    )
}

/// Serialize a fully quantized model (+ its quantization report) under `name`.
///
/// Every decoder linear must be `Linear::Quantized`; embeddings, norms, and
/// the head travel as dense f32 sections so the load path needs nothing but
/// the artifact pair. Records the ambient serving geometry
/// (`QTIP_KV_BLOCK` / `QTIP_PREFILL_CHUNK` env > defaults) in the manifest;
/// explicit CLI geometry must go through
/// [`save_quantized_model_with_geometry`].
pub fn save_quantized_model(
    dir: &Path,
    name: &str,
    model: &Transformer,
    report: &QuantizeReport,
) -> Result<ArtifactInfo> {
    let kv_block = crate::model::kv::resolve_kv_block(0, 0);
    let prefill_chunk = crate::model::kv::resolve_prefill_chunk(0, 0);
    save_quantized_model_with_geometry(dir, name, model, report, kv_block, prefill_chunk)
}

/// [`save_quantized_model`] with an explicit KV-block geometry; the
/// prefill-chunk geometry stays ambient (env > default). Kept for callers
/// predating the chunked-prefill field.
pub fn save_quantized_model_with_kv_block(
    dir: &Path,
    name: &str,
    model: &Transformer,
    report: &QuantizeReport,
    kv_block: usize,
) -> Result<ArtifactInfo> {
    let prefill_chunk = crate::model::kv::resolve_prefill_chunk(0, 0);
    save_quantized_model_with_geometry(dir, name, model, report, kv_block, prefill_chunk)
}

/// [`save_quantized_model`] with explicit serving geometry to record in the
/// manifest (the `quantize --save --kv-block N --prefill-chunk M` path — CLI
/// flags outrank the env vars, so the caller resolves precedence).
pub fn save_quantized_model_with_geometry(
    dir: &Path,
    name: &str,
    model: &Transformer,
    report: &QuantizeReport,
    kv_block: usize,
    prefill_chunk: usize,
) -> Result<ArtifactInfo> {
    if name.is_empty()
        || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
    {
        bail!("artifact name '{name}' must be non-empty [A-Za-z0-9_-]");
    }
    std::fs::create_dir_all(dir)?;
    let mut blob = BlobWriter { buf: Vec::new() };
    let mut layer_entries = Vec::new();
    let mut desc = String::new();
    let mut method = String::new();
    for (lname, lin) in model.linears() {
        let qm = match lin {
            Linear::Quantized { qm, .. } => qm,
            Linear::Dense(_) => {
                bail!("layer '{lname}' is still dense; quantize the model before saving")
            }
        };
        if desc.is_empty() {
            desc = quant_desc(qm);
            method = qm.code.name().to_string();
        }
        let packed_off = blob.put_u32s(&qm.packed);
        let sign_rows_off = blob.put_u32s(&RhtContext::sign_bits(&qm.rht.sign_rows));
        let sign_cols_off = blob.put_u32s(&RhtContext::sign_bits(&qm.rht.sign_cols));
        let code = code_spec_to_json(&qm.code, &mut blob);
        layer_entries.push(Json::obj(vec![
            ("name", Json::Str(lname.clone())),
            ("rows", num(qm.rows)),
            ("cols", num(qm.cols)),
            ("tx", num(qm.tx)),
            ("ty", num(qm.ty)),
            (
                "trellis",
                Json::obj(vec![
                    ("l", num(qm.trellis.l as usize)),
                    ("k", num(qm.trellis.k as usize)),
                    ("v", num(qm.trellis.v as usize)),
                ]),
            ),
            // Exact bit pattern: the decode path multiplies by this f32, so a
            // decimal round-trip would break bit-identity.
            ("scale_bits", num(qm.scale.to_bits() as usize)),
            ("tile_words", num(qm.tile_words)),
            ("packed_off", num(packed_off)),
            ("packed_words", num(qm.packed.len())),
            ("sign_rows_off", num(sign_rows_off)),
            ("sign_cols_off", num(sign_cols_off)),
            ("metrics", qm.metrics.to_json()),
            ("code", code),
        ]));
    }
    if layer_entries.is_empty() {
        bail!("model has no decoder linears to save");
    }

    let mut dense_entries = Vec::new();
    dense_entry(
        &mut dense_entries,
        &mut blob,
        "tok_emb".into(),
        model.tok_emb.rows,
        model.tok_emb.cols,
        &model.tok_emb.data,
    );
    for (i, layer) in model.layers.iter().enumerate() {
        dense_entry(
            &mut dense_entries,
            &mut blob,
            format!("l{i}.attn_norm"),
            1,
            layer.attn_norm.len(),
            &layer.attn_norm,
        );
        dense_entry(
            &mut dense_entries,
            &mut blob,
            format!("l{i}.mlp_norm"),
            1,
            layer.mlp_norm.len(),
            &layer.mlp_norm,
        );
    }
    dense_entry(
        &mut dense_entries,
        &mut blob,
        "out_norm".into(),
        1,
        model.out_norm.len(),
        &model.out_norm,
    );
    match &model.head {
        Linear::Dense(w) => {
            dense_entry(&mut dense_entries, &mut blob, "head".into(), w.rows, w.cols, &w.data)
        }
        Linear::Quantized { .. } => {
            bail!("quantized output head is not supported by the artifact format")
        }
    }

    let checksum = fnv1a64(&blob.buf);
    let quantized_layers = layer_entries.len();
    // No KV tensors are persisted (they are runtime state), but the manifest
    // records the KV-block geometry in effect at save time so cold-started
    // servers default to the same arena shape.
    let manifest = Json::obj(vec![
        ("kind", Json::Str(ARTIFACT_KIND.into())),
        ("format_version", num(FORMAT_VERSION)),
        ("model_config", model.cfg.to_json()),
        ("quant_desc", Json::Str(desc.clone())),
        ("quant_method", Json::Str(method.clone())),
        ("quantized_layers", num(quantized_layers)),
        ("kv_block", num(kv_block)),
        ("prefill_chunk", num(prefill_chunk)),
        ("blob_file", Json::Str(format!("quant_{name}.bin"))),
        ("blob_bytes", num(blob.buf.len())),
        ("checksum_fnv1a64", Json::Str(format!("{checksum:016x}"))),
        ("report", report.to_json()),
        ("dense_tensors", Json::Arr(dense_entries)),
        ("layers", Json::Arr(layer_entries)),
    ]);
    let manifest_path = quant_manifest_path(dir, name);
    let blob_path = dir.join(format!("quant_{name}.bin"));
    std::fs::write(&blob_path, &blob.buf)
        .with_context(|| format!("writing {blob_path:?}"))?;
    std::fs::write(&manifest_path, manifest.to_string())
        .with_context(|| format!("writing {manifest_path:?}"))?;
    Ok(ArtifactInfo {
        name: name.to_string(),
        manifest_path,
        blob_bytes: blob.buf.len(),
        config: model.cfg.clone(),
        quant_desc: desc,
        method,
        quantized_layers,
        kv_block,
        prefill_chunk,
    })
}

fn take_dense(map: &mut BTreeMap<String, Matrix>, name: &str) -> Result<Matrix> {
    map.remove(name)
        .with_context(|| format!("artifact missing dense tensor '{name}'"))
}

/// Load artifact `name`: verify version + checksum, then reassemble a
/// serving-ready [`Transformer`] (quantized decoder linears, dense
/// embeddings/norms/head) plus the [`QuantizeReport`] of the original run.
pub fn load_quantized_model(
    dir: &Path,
    name: &str,
) -> Result<(Transformer, QuantizeReport, ArtifactInfo)> {
    load_quantized_model_pool(dir, name, &ExecPool::sequential())
}

/// [`load_quantized_model`] with the per-layer blob reassembly (bounds
/// checks, section reads, sign/LUT expansion) fanned out across `pool`:
/// every blob section is offset-addressed, so layers load independently and
/// a cold-started server's load time scales with `--threads`.
pub fn load_quantized_model_pool(
    dir: &Path,
    name: &str,
    pool: &ExecPool,
) -> Result<(Transformer, QuantizeReport, ArtifactInfo)> {
    let manifest_path = quant_manifest_path(dir, name);
    let text = std::fs::read_to_string(&manifest_path).with_context(|| {
        format!(
            "reading quantized-artifact manifest {manifest_path:?} \
             (save one with `qtip quantize --save {name}`)"
        )
    })?;
    let j = Json::parse(&text)
        .map_err(|e| anyhow!("manifest {manifest_path:?} parse: {e}"))?;
    let kind = j.req_str("kind");
    if kind != ARTIFACT_KIND {
        bail!("{manifest_path:?} is a '{kind}' artifact, not '{ARTIFACT_KIND}'");
    }
    let version = j.req_usize("format_version");
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        bail!(
            "quantized artifact '{name}' uses format version {version}; this build reads \
             versions {MIN_FORMAT_VERSION}..={FORMAT_VERSION} — re-save it with \
             `qtip quantize --save {name}`"
        );
    }
    let cfg = ModelConfig::from_json(j.get("model_config").context("manifest.model_config")?);
    let blob_path = dir.join(j.req_str("blob_file"));
    let blob = std::fs::read(&blob_path).with_context(|| format!("reading {blob_path:?}"))?;
    let expect_bytes = j.req_usize("blob_bytes");
    if blob.len() != expect_bytes {
        bail!(
            "quantized blob {blob_path:?} is {} bytes but the manifest expects \
             {expect_bytes}: truncated or mismatched artifact",
            blob.len()
        );
    }
    let expect_sum = j.req_str("checksum_fnv1a64");
    let got_sum = format!("{:016x}", fnv1a64(&blob));
    if got_sum != expect_sum {
        bail!(
            "checksum mismatch for {blob_path:?}: blob {got_sum}, manifest {expect_sum} \
             (corrupted artifact)"
        );
    }
    let reader = BlobReader { buf: &blob };
    // Membership guard before `expected_shape`: that helper panics on names
    // outside the canonical set, and the manifest (unlike the blob) carries no
    // checksum — a damaged tensor name must error, not abort.
    let known_names: std::collections::BTreeSet<String> =
        WeightStore::expected_names(&cfg).into_iter().collect();

    // Dense tensors, shape-checked against the model config.
    let mut dense: BTreeMap<String, Matrix> = BTreeMap::new();
    for t in j
        .get("dense_tensors")
        .and_then(|d| d.as_arr())
        .context("manifest.dense_tensors")?
    {
        let tname = t.req_str("name").to_string();
        if !known_names.contains(&tname) {
            bail!("unknown tensor '{tname}' in artifact for model '{}'", cfg.name);
        }
        let (rows, cols) = (t.req_usize("rows"), t.req_usize("cols"));
        let (er, ec) = WeightStore::expected_shape(&cfg, &tname);
        if (rows, cols) != (er, ec) {
            bail!(
                "dense tensor '{tname}' has shape {rows}x{cols}, model config expects {er}x{ec}"
            );
        }
        let data = reader
            .f32s(t.req_usize("off"), rows * cols)
            .with_context(|| format!("dense tensor '{tname}'"))?;
        dense.insert(tname, Matrix::from_vec(rows, cols, data));
    }

    // Quantized decoder linears: reassembly is independent per layer (every
    // blob section is offset-addressed), so the jobs fan out across the pool.
    let layer_entries = j.get("layers").and_then(|l| l.as_arr()).context("manifest.layers")?;
    let loaded: Vec<Result<(String, QuantizedMatrix)>> =
        pool.map(layer_entries.len(), |idx| {
            let e = &layer_entries[idx];
            load_quantized_layer(e, &reader, &known_names, &cfg)
        });
    let mut qms: BTreeMap<String, QuantizedMatrix> = BTreeMap::new();
    for r in loaded {
        let (lname, qm) = r?;
        qms.insert(lname, qm);
    }

    reassemble_model(j, cfg, dense, qms, manifest_path, blob.len(), name)
}

/// Rebuild one quantized decoder linear from its manifest entry + blob.
fn load_quantized_layer(
    e: &Json,
    reader: &BlobReader<'_>,
    known_names: &std::collections::BTreeSet<String>,
    cfg: &ModelConfig,
) -> Result<(String, QuantizedMatrix)> {
    let lname = e.req_str("name").to_string();
    if !known_names.contains(&lname) {
        bail!("unknown layer '{lname}' in artifact for model '{}'", cfg.name);
    }
    let (rows, cols) = (e.req_usize("rows"), e.req_usize("cols"));
    let (er, ec) = WeightStore::expected_shape(cfg, &lname);
    if (rows, cols) != (er, ec) {
        bail!("layer '{lname}' has shape {rows}x{cols}, model config expects {er}x{ec}");
    }
    let (tx, ty) = (e.req_usize("tx"), e.req_usize("ty"));
    if tx == 0 || ty == 0 || rows % tx != 0 || cols % ty != 0 {
        bail!("layer '{lname}': tile {tx}x{ty} does not divide {rows}x{cols}");
    }
    let tj = e.get("trellis").context("layer.trellis")?;
    let (l, k, v) = (tj.req_usize("l"), tj.req_usize("k"), tj.req_usize("v"));
    // Pre-validate what Trellis::new would otherwise assert on: a damaged
    // manifest must error, not abort the process.
    if !(1..=24).contains(&l) || k == 0 || v == 0 || k * v >= l || k * v > 8 {
        bail!("layer '{lname}': unsupported trellis (L={l}, k={k}, V={v})");
    }
    let trellis = Trellis::new(l as u32, k as u32, v as u32);
    // tile_words must match the packing geometry exactly, or the decode
    // hot loop's rolling-window reads walk past each tile at serve time.
    if (tx * ty) % v != 0 {
        bail!("layer '{lname}': tile {tx}x{ty} not divisible by V={v}");
    }
    let steps = (tx * ty) / v;
    if steps * k * v < l {
        bail!("layer '{lname}': tile too small for tail-biting at (L={l}, k={k}, V={v})");
    }
    let padded_bits = steps * k * v + (l - k * v);
    let expect_tile_words = padded_bits.div_ceil(32) + 1;
    let tile_words = e.req_usize("tile_words");
    if tile_words != expect_tile_words {
        bail!(
            "layer '{lname}': tile_words {tile_words} != {expect_tile_words} required \
             by the (L, k, V, tile) geometry"
        );
    }
    let packed_words = e.req_usize("packed_words");
    if packed_words != (rows / tx) * (cols / ty) * tile_words {
        bail!(
            "layer '{lname}': packed stream is {packed_words} words, geometry needs {}",
            (rows / tx) * (cols / ty) * tile_words
        );
    }
    let packed = reader
        .u32s(e.req_usize("packed_off"), packed_words)
        .with_context(|| format!("layer '{lname}' packed stream"))?;
    let sign_rows = RhtContext::signs_from_bits(
        &reader.u32s(e.req_usize("sign_rows_off"), rows.div_ceil(32))?,
        rows,
    );
    let sign_cols = RhtContext::signs_from_bits(
        &reader.u32s(e.req_usize("sign_cols_off"), cols.div_ceil(32))?,
        cols,
    );
    let code = code_spec_from_json(e.get("code").context("layer.code")?, reader, &trellis)
        .with_context(|| format!("layer '{lname}' code spec"))?;
    let metrics = QuantMetrics::from_json(e.get("metrics").context("layer.metrics")?);
    Ok((
        lname,
        QuantizedMatrix {
            rows,
            cols,
            tx,
            ty,
            trellis,
            code,
            scale: f32::from_bits(e.req_usize("scale_bits") as u32),
            rht: RhtContext { sign_rows, sign_cols },
            tile_words,
            packed,
            metrics,
            // Runtime choice, not artifact state: the manifest stays
            // kernel-agnostic and the load-time selection
            // (`--kernel` > `QTIP_KERNEL` > auto) decides the decode family.
            kernel: crate::quant::kernel::selected_resolved(),
        },
    ))
}

/// Final assembly of a loaded artifact into a serving-ready [`Transformer`].
fn reassemble_model(
    j: Json,
    cfg: ModelConfig,
    mut dense: BTreeMap<String, Matrix>,
    mut qms: BTreeMap<String, QuantizedMatrix>,
    manifest_path: PathBuf,
    blob_bytes: usize,
    name: &str,
) -> Result<(Transformer, QuantizeReport, ArtifactInfo)> {
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let mut lin = |part: &str| -> Result<Linear> {
            let key = format!("l{i}.{part}");
            let qm = qms
                .remove(&key)
                .with_context(|| format!("artifact missing quantized layer '{key}'"))?;
            Ok(Linear::Quantized { qm, cache: None })
        };
        let attn = Attention { q: lin("q")?, k: lin("k")?, v: lin("v")?, o: lin("o")? };
        let mlp = Mlp { gate: lin("gate")?, up: lin("up")?, down: lin("down")? };
        layers.push(Layer {
            attn_norm: take_dense(&mut dense, &format!("l{i}.attn_norm"))?.data,
            attn,
            mlp_norm: take_dense(&mut dense, &format!("l{i}.mlp_norm"))?.data,
            mlp,
        });
    }
    if let Some(extra) = qms.keys().next() {
        bail!(
            "artifact carries layer '{extra}' beyond the model config's {} layers",
            cfg.n_layers
        );
    }
    let model = Transformer {
        cfg: cfg.clone(),
        tok_emb: take_dense(&mut dense, "tok_emb")?,
        layers,
        out_norm: take_dense(&mut dense, "out_norm")?.data,
        head: Linear::Dense(take_dense(&mut dense, "head")?),
    };
    let report = QuantizeReport::from_json(j.get("report").context("manifest.report")?);
    let info = ArtifactInfo {
        name: name.to_string(),
        manifest_path,
        blob_bytes,
        config: cfg,
        quant_desc: j.req_str("quant_desc").to_string(),
        method: manifest_method(&j),
        quantized_layers: j.req_usize("quantized_layers"),
        // Optional: manifests saved before the paged KV arena (or before
        // chunked prefill) carry no geometry; 0 lets the serve path fall
        // through to its default.
        kv_block: j.get("kv_block").and_then(|v| v.as_usize()).unwrap_or(0),
        prefill_chunk: j.get("prefill_chunk").and_then(|v| v.as_usize()).unwrap_or(0),
    };
    Ok((model, report, info))
}

/// Scan `dir` for saved quantized artifacts (manifest summaries only — blobs
/// are not read). Unparsable manifests are skipped; `load_quantized_model`
/// reports their errors precisely when asked for them by name.
pub fn list_quantized_artifacts(dir: &Path) -> Vec<ArtifactInfo> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for path in paths {
        let Some(fname) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let Some(name) = fname.strip_prefix("quant_").and_then(|n| n.strip_suffix(".json"))
        else {
            continue;
        };
        let Ok(text) = std::fs::read_to_string(&path) else { continue };
        let Ok(j) = Json::parse(&text) else { continue };
        let version = j.get("format_version").and_then(|v| v.as_usize());
        if j.get("kind").and_then(|k| k.as_str()) != Some(ARTIFACT_KIND)
            || !version.is_some_and(|v| (MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&v))
        {
            continue;
        }
        // Defensive field extraction: `qtip info` must list the healthy
        // artifacts even when one manifest is damaged, never panic on it.
        let Some(cfg_json) = j.get("model_config") else { continue };
        let cfg_complete = ["vocab", "d_model", "n_layers", "n_heads", "d_ff", "max_seq"]
            .iter()
            .all(|k| cfg_json.get(k).and_then(|v| v.as_f64()).is_some())
            && cfg_json.get("name").and_then(|v| v.as_str()).is_some();
        if !cfg_complete {
            continue;
        }
        let (Some(blob_bytes), Some(desc), Some(nlayers)) = (
            j.get("blob_bytes").and_then(|v| v.as_usize()),
            j.get("quant_desc").and_then(|v| v.as_str()),
            j.get("quantized_layers").and_then(|v| v.as_usize()),
        ) else {
            continue;
        };
        out.push(ArtifactInfo {
            name: name.to_string(),
            manifest_path: path.clone(),
            blob_bytes,
            config: ModelConfig::from_json(cfg_json),
            quant_desc: desc.to_string(),
            method: manifest_method(&j),
            quantized_layers: nlayers,
            kv_block: j.get("kv_block").and_then(|v| v.as_usize()).unwrap_or(0),
            prefill_chunk: j.get("prefill_chunk").and_then(|v| v.as_usize()).unwrap_or(0),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::quantize_model_qtip;
    use crate::hessian::collect_hessians;
    use crate::model::{KvCache, WeightStore};
    use crate::quant::QtipConfig;

    fn tiny_quantized(code: &str, v: u32) -> (Transformer, QuantizeReport) {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 32;
        cfg.name = "tiny".into();
        let mut model = Transformer::from_store(&WeightStore::random(&cfg, 11));
        let seqs = vec![vec![1u16, 5, 9, 13, 17, 21, 25, 29]];
        let hs = collect_hessians(&model, &seqs);
        let qcfg = QtipConfig {
            l: 10,
            k: 2,
            v,
            tx: 8,
            ty: 8,
            code: code.into(),
            seed: 42,
        };
        let report = quantize_model_qtip(
            &mut model,
            &hs,
            &qcfg,
            &crate::util::threadpool::ExecPool::sequential(),
            |_| {},
        )
        .unwrap();
        (model, report)
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("qtip_io_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn save_load_roundtrip_preserves_decode_state() {
        let dir = tmp_dir("roundtrip");
        let (model, report) = tiny_quantized("3inst", 1);
        let info = save_quantized_model(&dir, "rt", &model, &report).unwrap();
        assert_eq!(info.quantized_layers, 7);
        assert!(info.blob_bytes > 0);

        let (loaded, lreport, linfo) = load_quantized_model(&dir, "rt").unwrap();
        assert_eq!(linfo.quantized_layers, 7);
        assert_eq!(lreport.layers.len(), report.layers.len());
        assert_eq!(lreport.bytes_after, report.bytes_after);
        // The manifest records the save-time KV geometry (no KV data itself),
        // and it round-trips through load.
        assert_eq!(info.kv_block, crate::model::kv::resolve_kv_block(0, 0));
        assert_eq!(linfo.kv_block, info.kv_block);

        // Every packed word, sign, and scale bit must round-trip exactly.
        for ((n1, a), (n2, b)) in model.linears().iter().zip(loaded.linears().iter()) {
            assert_eq!(n1, n2);
            let (Linear::Quantized { qm: qa, .. }, Linear::Quantized { qm: qb, .. }) = (a, b)
            else {
                panic!("expected quantized layers");
            };
            assert_eq!(qa.packed, qb.packed, "{n1}: packed stream differs");
            assert_eq!(qa.scale.to_bits(), qb.scale.to_bits(), "{n1}: scale bits differ");
            assert_eq!(qa.rht.sign_rows, qb.rht.sign_rows, "{n1}: row signs differ");
            assert_eq!(qa.rht.sign_cols, qb.rht.sign_cols, "{n1}: col signs differ");
            assert_eq!(qa.tile_words, qb.tile_words);
            assert_eq!(qa.trellis, qb.trellis);
        }
        // And a decode step end-to-end (KV path) must agree bit-for-bit.
        let mut ca = KvCache::new(&model.cfg);
        let mut cb = KvCache::new(&loaded.cfg);
        for &t in &[3u16, 17, 99] {
            let la = model.decode_step(&mut ca, t);
            let lb = loaded.decode_step(&mut cb, t);
            assert_eq!(la, lb, "loaded-artifact logits diverged");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pooled_load_matches_sequential_load() {
        // Per-layer reassembly fans out across the pool; the loaded artifact
        // must be byte-identical to a sequential load at any width.
        let dir = tmp_dir("pooled");
        let (model, report) = tiny_quantized("lut", 2);
        save_quantized_model(&dir, "p", &model, &report).unwrap();
        let (a, _, _) = load_quantized_model(&dir, "p").unwrap();
        let pool = crate::util::threadpool::ExecPool::new(4);
        let (b, _, _) = load_quantized_model_pool(&dir, "p", &pool).unwrap();
        for ((n1, la), (_, lb)) in a.linears().iter().zip(b.linears().iter()) {
            let (Linear::Quantized { qm: qa, .. }, Linear::Quantized { qm: qb, .. }) = (la, lb)
            else {
                panic!("expected quantized layers");
            };
            assert_eq!(qa.packed, qb.packed, "{n1}: pooled load diverged");
            assert_eq!(qa.scale.to_bits(), qb.scale.to_bits(), "{n1}: scale differs");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn listing_reports_saved_artifacts() {
        let dir = tmp_dir("listing");
        assert!(list_quantized_artifacts(&dir).is_empty());
        let (model, report) = tiny_quantized("3inst", 1);
        save_quantized_model(&dir, "alpha", &model, &report).unwrap();
        // Explicit geometry (the `--kv-block` / `--prefill-chunk` path) must
        // be recorded and listed verbatim, outranking env/default.
        save_quantized_model_with_geometry(&dir, "beta", &model, &report, 8, 5).unwrap();
        let infos = list_quantized_artifacts(&dir);
        assert_eq!(infos.len(), 2);
        assert_eq!(infos[0].name, "alpha");
        assert_eq!(infos[1].kv_block, 8, "explicit --kv-block geometry must round-trip");
        assert_eq!(infos[1].prefill_chunk, 5, "explicit --prefill-chunk must round-trip");
        assert_eq!(infos[1].name, "beta");
        assert!(infos[0].quant_desc.contains("3inst"));
        assert_eq!(infos[0].config.name, "tiny");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_blob_fails_with_clear_error() {
        let dir = tmp_dir("trunc");
        let (model, report) = tiny_quantized("3inst", 1);
        save_quantized_model(&dir, "t", &model, &report).unwrap();
        let blob_path = dir.join("quant_t.bin");
        let blob = std::fs::read(&blob_path).unwrap();
        std::fs::write(&blob_path, &blob[..blob.len() / 2]).unwrap();
        let err = load_quantized_model(&dir, "t").unwrap_err().to_string();
        assert!(err.contains("truncated"), "unhelpful truncation error: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_blob_fails_checksum() {
        let dir = tmp_dir("corrupt");
        let (model, report) = tiny_quantized("3inst", 1);
        save_quantized_model(&dir, "c", &model, &report).unwrap();
        let blob_path = dir.join("quant_c.bin");
        let mut blob = std::fs::read(&blob_path).unwrap();
        blob[blob.len() / 3] ^= 0x40; // flip one bit, length unchanged
        std::fs::write(&blob_path, &blob).unwrap();
        let err = load_quantized_model(&dir, "c").unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "unhelpful corruption error: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_mismatch_fails_with_clear_error() {
        let dir = tmp_dir("version");
        let (model, report) = tiny_quantized("3inst", 1);
        save_quantized_model(&dir, "v", &model, &report).unwrap();
        let mpath = quant_manifest_path(&dir, "v");
        let text = std::fs::read_to_string(&mpath).unwrap();
        let bumped = text.replace("\"format_version\":2", "\"format_version\":99");
        assert_ne!(bumped, text, "manifest rewrite failed to find the version field");
        std::fs::write(&mpath, bumped).unwrap();
        let err = load_quantized_model(&dir, "v").unwrap_err().to_string();
        assert!(err.contains("format version 99"), "unhelpful version error: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn v1_manifest_still_loads() {
        // Pre-registry manifests (format_version 1) keyed the per-layer code
        // object by "name"; the same fields under the same keys must keep
        // loading, bit-identically, without a re-save.
        let dir = tmp_dir("v1compat");
        let (model, report) = tiny_quantized("3inst", 1);
        save_quantized_model(&dir, "old", &model, &report).unwrap();
        let mpath = quant_manifest_path(&dir, "old");
        let text = std::fs::read_to_string(&mpath).unwrap();
        let downgraded = text
            .replace("\"format_version\":2", "\"format_version\":1")
            .replace(",\"quant_method\":\"3inst\"", "")
            .replace("\"method\":\"3inst\"", "\"name\":\"3inst\"");
        assert_ne!(downgraded, text, "manifest rewrite failed to downgrade to v1");
        std::fs::write(&mpath, downgraded).unwrap();
        let (loaded, _, linfo) = load_quantized_model(&dir, "old").unwrap();
        // v1 carries no `quant_method`; the id is recovered from quant_desc.
        assert_eq!(linfo.method, "3inst");
        let infos = list_quantized_artifacts(&dir);
        assert_eq!(infos.len(), 1, "v1 manifests must still be listed");
        let mut ca = KvCache::new(&model.cfg);
        let mut cb = KvCache::new(&loaded.cfg);
        for &t in &[3u16, 17, 99] {
            assert_eq!(
                model.decode_step(&mut ca, t),
                loaded.decode_step(&mut cb, t),
                "v1-manifest load diverged from the in-memory model"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn unknown_method_error_lists_registered_names() {
        let dir = tmp_dir("unknown_method");
        let (model, report) = tiny_quantized("3inst", 1);
        save_quantized_model(&dir, "z", &model, &report).unwrap();
        let mpath = quant_manifest_path(&dir, "z");
        let text = std::fs::read_to_string(&mpath).unwrap();
        let bad = text.replace("\"method\":\"3inst\"", "\"method\":\"zeta\"");
        assert_ne!(bad, text, "manifest rewrite failed to find the method id");
        std::fs::write(&mpath, bad).unwrap();
        let err = load_quantized_model(&dir, "z").unwrap_err().to_string();
        assert!(err.contains("unknown code 'zeta'"), "{err}");
        for name in registry::names() {
            assert!(err.contains(name), "error should list registered method '{name}': {err}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn vptq_artifact_roundtrip() {
        // The plug-in method must flow quantize → save → load → decode with
        // no special cases in io: this test would fail on any registry leak.
        let dir = tmp_dir("vptq");
        let (model, report) = tiny_quantized("vptq", 2);
        let info = save_quantized_model(&dir, "vq", &model, &report).unwrap();
        assert_eq!(info.method, "vptq");
        let (loaded, _, linfo) = load_quantized_model(&dir, "vq").unwrap();
        assert_eq!(linfo.method, "vptq");
        assert!(linfo.quant_desc.starts_with("vptq"));
        let mut ca = KvCache::new(&model.cfg);
        let mut cb = KvCache::new(&loaded.cfg);
        for &t in &[3u16, 17, 99] {
            assert_eq!(
                model.decode_step(&mut ca, t),
                loaded.decode_step(&mut cb, t),
                "vptq loaded-artifact logits diverged"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn damaged_trellis_params_error_not_abort() {
        // The manifest carries no checksum, so field damage must surface as a
        // Result error — not an assert abort inside Trellis::new.
        let dir = tmp_dir("trellis");
        let (model, report) = tiny_quantized("3inst", 1);
        save_quantized_model(&dir, "tr", &model, &report).unwrap();
        let mpath = quant_manifest_path(&dir, "tr");
        let text = std::fs::read_to_string(&mpath).unwrap();
        let bad = text.replace("\"l\":10", "\"l\":30");
        assert_ne!(bad, text, "manifest rewrite failed to find the trellis L field");
        std::fs::write(&mpath, bad).unwrap();
        let err = load_quantized_model(&dir, "tr").unwrap_err().to_string();
        assert!(err.contains("unsupported trellis"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_artifact_mentions_how_to_save() {
        let dir = tmp_dir("missing");
        let err = load_quantized_model(&dir, "ghost").unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("quantize --save ghost"), "unhelpful error: {chain}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refuses_to_save_dense_model() {
        let dir = tmp_dir("dense");
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 1;
        cfg.max_seq = 32;
        let model = Transformer::from_store(&WeightStore::random(&cfg, 1));
        let report = QuantizeReport {
            layers: Vec::new(),
            seconds: 0.0,
            bytes_before: 0,
            bytes_after: 0,
        };
        let err = save_quantized_model(&dir, "d", &model, &report).unwrap_err().to_string();
        assert!(err.contains("still dense"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_bad_artifact_names() {
        let dir = tmp_dir("names");
        let (model, report) = tiny_quantized("3inst", 1);
        for bad in ["", "a/b", "x y", "../up"] {
            assert!(
                save_quantized_model(&dir, bad, &model, &report).is_err(),
                "name '{bad}' should be rejected"
            );
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
