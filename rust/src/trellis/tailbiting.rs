//! Tail-biting trellis quantization (paper §3.2, Algorithm 4, Table 2).
//!
//! A tail-biting walk's last state shares its high `L−kV` bits with the first
//! state's low `L−kV` bits, so a length-T sequence costs exactly `kT` bits (no
//! `L−kV`-bit start-state overhead). Exact tail-biting Viterbi is quadratic in the
//! state count; Algorithm 4 approximates it with two Viterbi calls:
//!
//! 1. rotate the sequence by half its length and solve the *free* problem;
//! 2. read off the overlap at the rotation point (which corresponds to the original
//!    sequence's wrap-around boundary);
//! 3. re-solve the original sequence with that overlap pinned at both ends.

use super::viterbi::{Viterbi, ViterbiWorkspace};

/// Result of a tail-biting quantization.
#[derive(Clone, Debug)]
pub struct TailBitingSolution {
    /// One state per trellis step; satisfies the tail-biting constraint.
    pub states: Vec<u32>,
    /// Total squared error of the decoded walk.
    pub cost: f64,
    /// The pinned overlap (low `L-kV` bits of the first state).
    pub overlap: u32,
}

/// Algorithm 4: approximate tail-biting quantization with two Viterbi calls.
pub fn quantize_tail_biting(
    vit: &Viterbi,
    seq: &[f32],
    ws: &mut ViterbiWorkspace,
) -> TailBitingSolution {
    let t = vit.trellis;
    let steps = t.steps_for(seq.len());
    assert!(steps >= 2, "tail-biting needs at least 2 steps");
    assert!(
        steps as u32 * t.step_bits() >= t.l,
        "tail-biting needs steps*kV >= L (stream at least one window long)"
    );

    // Rotate right by half the steps (in weight units: V * steps/2).
    let half = steps / 2;
    let rot = half * t.v as usize;
    let mut rotated = Vec::with_capacity(seq.len());
    rotated.extend_from_slice(&seq[seq.len() - rot..]);
    rotated.extend_from_slice(&seq[..seq.len() - rot]);

    // Free solve on the rotated sequence.
    let (rstates, _) = vit.quantize(&rotated, None, None, ws);

    // The original wrap-around boundary sits between rotated steps (steps-half-1)
    // and (steps-half): rotated step index `steps-half` corresponds to original
    // step 0. The overlap shared by those two states pins the boundary.
    let boundary_state = rstates[steps - half];
    let overlap = boundary_state & t.overlap_mask();

    // Constrained solve of the original sequence.
    let (states, cost) = vit.quantize(seq, Some(overlap), Some(overlap), ws);
    debug_assert!(t.is_valid_walk(&states, true));
    TailBitingSolution { states, cost, overlap }
}

/// Exact tail-biting quantization: constrained Viterbi for every possible overlap.
/// `O(2^(L-kV))` Viterbi calls — tractable only for small L; used by Table 2's
/// "Optimal" column and by differential tests.
pub fn quantize_tail_biting_exact(
    vit: &Viterbi,
    seq: &[f32],
    ws: &mut ViterbiWorkspace,
) -> TailBitingSolution {
    let t = vit.trellis;
    let mut best: Option<TailBitingSolution> = None;
    for o in 0..t.overlaps() as u32 {
        let (states, cost) = vit.quantize(seq, Some(o), Some(o), ws);
        if best.as_ref().map_or(true, |b| cost < b.cost) {
            best = Some(TailBitingSolution { states, cost, overlap: o });
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trellis::Trellis;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    fn random_codebook(trellis: &Trellis, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.gauss_vec(trellis.states() * trellis.v as usize)
    }

    #[test]
    fn solution_is_tail_biting() {
        prop_check("alg4 produces valid tail-biting walks", 20, |g| {
            let l = g.usize_in(3, 8) as u32;
            let k = g.usize_in(1, 2) as u32;
            if k >= l {
                return;
            }
            let trellis = Trellis::new(l, k, 1);
            let values = g.gauss_vec(trellis.states());
            let vit = Viterbi::new(trellis, &values);
            let min_steps = (l as usize).div_ceil(k as usize).max(2);
            let steps = g.usize_in(min_steps, min_steps + 20);
            let seq = g.gauss_vec(steps);
            let mut ws = ViterbiWorkspace::new();
            let sol = quantize_tail_biting(&vit, &seq, &mut ws);
            assert!(trellis.is_valid_walk(&sol.states, true));
            assert_eq!(sol.states[0] & trellis.overlap_mask(), sol.overlap);
        });
    }

    #[test]
    fn approx_close_to_exact() {
        // Table 2's claim at test scale: Alg. 4 is near-optimal on Gaussian input.
        let trellis = Trellis::new(8, 2, 1);
        let values = random_codebook(&trellis, 21);
        let vit = Viterbi::new(trellis, &values);
        let mut rng = Rng::new(22);
        let mut ws = ViterbiWorkspace::new();
        let mut approx_total = 0.0;
        let mut exact_total = 0.0;
        for _ in 0..12 {
            let seq = rng.gauss_vec(64);
            approx_total += quantize_tail_biting(&vit, &seq, &mut ws).cost;
            exact_total += quantize_tail_biting_exact(&vit, &seq, &mut ws).cost;
        }
        assert!(approx_total >= exact_total - 1e-6, "exact must lower-bound approx");
        assert!(
            approx_total <= exact_total * 1.05,
            "approx {approx_total} too far from exact {exact_total}"
        );
    }

    #[test]
    fn exact_beats_or_matches_every_single_overlap() {
        let trellis = Trellis::new(5, 1, 1);
        let values = random_codebook(&trellis, 30);
        let vit = Viterbi::new(trellis, &values);
        let mut rng = Rng::new(31);
        let seq = rng.gauss_vec(12);
        let mut ws = ViterbiWorkspace::new();
        let exact = quantize_tail_biting_exact(&vit, &seq, &mut ws);
        for o in 0..trellis.overlaps() as u32 {
            let (_, cost) = vit.quantize(&seq, Some(o), Some(o), &mut ws);
            assert!(exact.cost <= cost + 1e-6);
        }
    }

    #[test]
    fn free_solution_lower_bounds_tail_biting() {
        let trellis = Trellis::new(6, 2, 1);
        let values = random_codebook(&trellis, 40);
        let vit = Viterbi::new(trellis, &values);
        let mut rng = Rng::new(41);
        let seq = rng.gauss_vec(32);
        let mut ws = ViterbiWorkspace::new();
        let (_, free_cost) = vit.quantize(&seq, None, None, &mut ws);
        let tb = quantize_tail_biting(&vit, &seq, &mut ws);
        assert!(tb.cost >= free_cost - 1e-6);
    }

    #[test]
    fn works_with_v2() {
        let trellis = Trellis::new(6, 1, 2);
        let values = random_codebook(&trellis, 50);
        let vit = Viterbi::new(trellis, &values);
        let mut rng = Rng::new(51);
        let seq = rng.gauss_vec(32); // 16 steps
        let mut ws = ViterbiWorkspace::new();
        let sol = quantize_tail_biting(&vit, &seq, &mut ws);
        assert!(trellis.is_valid_walk(&sol.states, true));
        assert_eq!(sol.states.len(), 16);
    }
}
