//! Viterbi quantization on the bitshift trellis (paper §2.3).
//!
//! Finds the walk whose decoded values minimize squared error against the input
//! sequence. The textbook relaxation is `O(2^L · 2^kV)` per step; the bitshift
//! structure admits a two-pass form that is `O(2^L)` per step:
//!
//! 1. predecessor sets are contiguous: preds(j) = { (j & lowmask)·2^kV + d }, so a
//!    single sweep computes `minv[p] = min_d prev[p·2^kV + d]` for every overlap `p`;
//! 2. then `cur[j] = minv[j & lowmask] + (C[j] − s_t)²` for every state `j`.
//!
//! Both passes stream memory in order. The naive form is kept (`quantize_naive`)
//! for the design-ablation bench and as a differential-testing oracle.

use super::Trellis;

/// Reusable buffers: Viterbi over T=256, L=16 allocates ~0.8 MB of f32 state plus
/// N·2^(L-kV) backpointer bytes; the quantization pipeline calls this hundreds of
/// thousands of times, so buffers are recycled across calls.
pub struct ViterbiWorkspace {
    prev: Vec<f32>,
    cur: Vec<f32>,
    minv: Vec<f32>,
    bp: Vec<u8>,
}

impl ViterbiWorkspace {
    pub fn new() -> Self {
        ViterbiWorkspace { prev: Vec::new(), cur: Vec::new(), minv: Vec::new(), bp: Vec::new() }
    }

    fn prepare(&mut self, states: usize, overlaps: usize, steps: usize) {
        self.prev.clear();
        self.prev.resize(states, 0.0);
        self.cur.clear();
        self.cur.resize(states, 0.0);
        self.minv.clear();
        self.minv.resize(overlaps, 0.0);
        self.bp.clear();
        self.bp.resize(overlaps * steps.saturating_sub(1), 0);
    }
}

impl Default for ViterbiWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

/// A trellis quantizer bound to a materialized codebook
/// (`values[state*V + j]`, length `2^L * V`).
pub struct Viterbi<'a> {
    pub trellis: Trellis,
    pub values: &'a [f32],
}

impl<'a> Viterbi<'a> {
    pub fn new(trellis: Trellis, values: &'a [f32]) -> Self {
        assert_eq!(
            values.len(),
            trellis.states() * trellis.v as usize,
            "codebook length must be 2^L * V"
        );
        Viterbi { trellis, values }
    }

    /// Squared distance between state `j`'s value vector and the step-`t` slice of seq.
    #[inline]
    fn dist(&self, j: usize, step_vals: &[f32]) -> f32 {
        let v = self.trellis.v as usize;
        if v == 1 {
            let d = self.values[j] - step_vals[0];
            d * d
        } else {
            let base = j * v;
            let mut acc = 0.0f32;
            for i in 0..v {
                let d = self.values[base + i] - step_vals[i];
                acc += d * d;
            }
            acc
        }
    }

    /// Quantize `seq` (length divisible by V) to the minimum-squared-error walk.
    ///
    /// `start_overlap` constrains the low `L-kV` bits of the first state;
    /// `end_overlap` constrains the high `L-kV` bits of the last state. Both `None`
    /// gives the unconstrained ("free") Viterbi solution.
    ///
    /// Returns the state path (one state per trellis step) and its total squared error.
    pub fn quantize(
        &self,
        seq: &[f32],
        start_overlap: Option<u32>,
        end_overlap: Option<u32>,
        ws: &mut ViterbiWorkspace,
    ) -> (Vec<u32>, f64) {
        let t = self.trellis;
        let v = t.v as usize;
        let steps = t.steps_for(seq.len());
        assert!(steps >= 1);
        let n_states = t.states();
        let overlaps = t.overlaps();
        let kv = t.step_bits();
        let lomask = t.overlap_mask();
        ws.prepare(n_states, overlaps, steps);

        // Init: cost of starting in each state.
        let s0 = &seq[0..v];
        if let Some(o) = start_overlap {
            debug_assert!(o <= lomask);
            ws.prev.fill(f32::INFINITY);
            // Allowed states: (j & lomask) == o, i.e. j = o + hi << (L-kV).
            let mut j = o as usize;
            while j < n_states {
                ws.prev[j] = self.dist(j, s0);
                j += overlaps;
            }
        } else {
            for j in 0..n_states {
                ws.prev[j] = self.dist(j, s0);
            }
        }

        // Forward passes.
        let fan = 1usize << kv;
        for step in 1..steps {
            let bp_row = &mut ws.bp[(step - 1) * overlaps..step * overlaps];
            // Pass 1: per-overlap min over the contiguous predecessor block.
            if fan == 4 {
                // Specialized branch-light min-tree for the paper's k=2,V=1
                // geometry (§Perf optimization: the generic loop's data-dependent
                // branches mispredict ~50% on random costs).
                for p in 0..overlaps {
                    let b = &ws.prev[p * 4..p * 4 + 4];
                    let (l01, a01) = if b[1] < b[0] { (b[1], 1u8) } else { (b[0], 0) };
                    let (l23, a23) = if b[3] < b[2] { (b[3], 3u8) } else { (b[2], 2) };
                    let (best, arg) = if l23 < l01 { (l23, a23) } else { (l01, a01) };
                    ws.minv[p] = best;
                    bp_row[p] = arg;
                }
            } else {
                for p in 0..overlaps {
                    let block = &ws.prev[p * fan..(p + 1) * fan];
                    let mut best = block[0];
                    let mut arg = 0u8;
                    for (d, &c) in block.iter().enumerate().skip(1) {
                        if c < best {
                            best = c;
                            arg = d as u8;
                        }
                    }
                    ws.minv[p] = best;
                    bp_row[p] = arg;
                }
            }
            // Pass 2: relax into every state.
            let sv = &seq[step * v..(step + 1) * v];
            if v == 1 {
                let s = sv[0];
                for (j, cur) in ws.cur.iter_mut().enumerate() {
                    let d = self.values[j] - s;
                    *cur = ws.minv[j & lomask as usize] + d * d;
                }
            } else {
                for j in 0..n_states {
                    ws.cur[j] = ws.minv[j & lomask as usize] + self.dist(j, sv);
                }
            }
            std::mem::swap(&mut ws.prev, &mut ws.cur);
        }

        // Select final state.
        let (best_state, best_cost) = if let Some(o) = end_overlap {
            // High L-kV bits of final state must equal o: j = lo | (o << kV).
            let base = (o << kv) as usize;
            let mut best = f32::INFINITY;
            let mut arg = base;
            for lo in 0..fan {
                let j = base | lo;
                if ws.prev[j] < best {
                    best = ws.prev[j];
                    arg = j;
                }
            }
            (arg, best)
        } else {
            let mut best = f32::INFINITY;
            let mut arg = 0usize;
            for (j, &c) in ws.prev.iter().enumerate() {
                if c < best {
                    best = c;
                    arg = j;
                }
            }
            (arg, best)
        };
        assert!(
            best_cost.is_finite(),
            "no feasible walk (over-constrained trellis?)"
        );

        // Traceback.
        let mut states = vec![0u32; steps];
        states[steps - 1] = best_state as u32;
        for step in (1..steps).rev() {
            let j = states[step];
            let p = (j & lomask) as usize;
            let d = ws.bp[(step - 1) * overlaps + p] as u32;
            states[step - 1] = ((p as u32) << kv) | d;
        }
        (states, best_cost as f64)
    }

    /// Textbook Viterbi: explicit relaxation over each state's 2^kV predecessors.
    /// Same argmin as [`Self::quantize`]; kept as a differential oracle and for the
    /// `ablations_design` bench.
    pub fn quantize_naive(
        &self,
        seq: &[f32],
        start_overlap: Option<u32>,
        end_overlap: Option<u32>,
    ) -> (Vec<u32>, f64) {
        let t = self.trellis;
        let v = t.v as usize;
        let steps = t.steps_for(seq.len());
        let n_states = t.states();
        let kv = t.step_bits();
        let lomask = t.overlap_mask();
        let fan = 1usize << kv;

        let mut prev = vec![0.0f32; n_states];
        let mut cur = vec![0.0f32; n_states];
        let mut bp = vec![0u32; n_states * steps.saturating_sub(1)];

        let s0 = &seq[0..v];
        for (j, pv) in prev.iter_mut().enumerate() {
            *pv = if start_overlap.map_or(true, |o| (j as u32 & lomask) == o) {
                self.dist(j, s0)
            } else {
                f32::INFINITY
            };
        }
        for step in 1..steps {
            let sv = &seq[step * v..(step + 1) * v];
            for j in 0..n_states {
                let p = j & lomask as usize;
                let mut best = f32::INFINITY;
                let mut argp = 0usize;
                for d in 0..fan {
                    let i = (p << kv) | d;
                    if prev[i] < best {
                        best = prev[i];
                        argp = i;
                    }
                }
                cur[j] = best + self.dist(j, sv);
                bp[(step - 1) * n_states + j] = argp as u32;
            }
            std::mem::swap(&mut prev, &mut cur);
        }
        let mut best = f32::INFINITY;
        let mut arg = 0usize;
        for (j, &c) in prev.iter().enumerate() {
            if end_overlap.map_or(true, |o| (j as u32 >> kv) == o) && c < best {
                best = c;
                arg = j;
            }
        }
        assert!(best.is_finite(), "no feasible walk");
        let mut states = vec![0u32; steps];
        states[steps - 1] = arg as u32;
        for step in (1..steps).rev() {
            states[step - 1] = bp[(step - 1) * n_states + states[step] as usize];
        }
        (states, best as f64)
    }

    /// Decode a state path back to values.
    pub fn decode(&self, states: &[u32]) -> Vec<f32> {
        super::decode_states(&self.trellis, states, self.values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;
    use crate::util::stats::mse;

    /// Exhaustive search over all walks (tiny trellises only).
    fn brute_force(
        trellis: &Trellis,
        values: &[f32],
        seq: &[f32],
        start_overlap: Option<u32>,
        end_overlap: Option<u32>,
    ) -> f64 {
        let steps = trellis.steps_for(seq.len());
        let v = trellis.v as usize;
        let fan = 1u32 << trellis.step_bits();
        let mut best = f64::INFINITY;
        for init in 0..trellis.states() as u32 {
            if let Some(o) = start_overlap {
                if init & trellis.overlap_mask() != o {
                    continue;
                }
            }
            // Enumerate all (steps-1) transition choices.
            let total: u64 = (fan as u64).pow(steps as u32 - 1);
            for code in 0..total {
                let mut state = init;
                let mut cost = 0.0f64;
                for i in 0..v {
                    cost += (values[init as usize * v + i] as f64 - seq[i] as f64).powi(2);
                }
                let mut c = code;
                for step in 1..steps {
                    state = trellis.next_state(state, (c % fan as u64) as u32);
                    c /= fan as u64;
                    for i in 0..v {
                        cost += (values[state as usize * v + i] as f64
                            - seq[step * v + i] as f64)
                            .powi(2);
                    }
                }
                if let Some(o) = end_overlap {
                    if state >> trellis.step_bits() != o {
                        continue;
                    }
                }
                best = best.min(cost);
            }
        }
        best
    }

    fn random_codebook(trellis: &Trellis, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.gauss_vec(trellis.states() * trellis.v as usize)
    }

    #[test]
    fn matches_brute_force_small() {
        let mut ws = ViterbiWorkspace::new();
        for (l, k, v, t_len) in [(3u32, 1u32, 1u32, 4usize), (4, 2, 1, 4), (4, 1, 2, 6), (5, 2, 1, 3)] {
            let trellis = Trellis::new(l, k, v);
            let values = random_codebook(&trellis, 100 + l as u64);
            let mut rng = Rng::new(l as u64);
            let seq = rng.gauss_vec(t_len);
            let vit = Viterbi::new(trellis, &values);
            let (states, cost) = vit.quantize(&seq, None, None, &mut ws);
            let bf = brute_force(&trellis, &values, &seq, None, None);
            assert!(
                (cost - bf).abs() < 1e-4 * (1.0 + bf),
                "L={l} k={k} V={v}: viterbi={cost} brute={bf}"
            );
            assert!(trellis.is_valid_walk(&states, false));
            // Cost must equal recomputed decode error.
            let dec = vit.decode(&states);
            let recomputed: f64 = dec
                .iter()
                .zip(&seq)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum();
            assert!((recomputed - cost).abs() < 1e-4 * (1.0 + cost));
        }
    }

    #[test]
    fn constrained_matches_brute_force() {
        let mut ws = ViterbiWorkspace::new();
        let trellis = Trellis::new(4, 1, 1);
        let values = random_codebook(&trellis, 7);
        let mut rng = Rng::new(3);
        let seq = rng.gauss_vec(5);
        let vit = Viterbi::new(trellis, &values);
        for o in 0..trellis.overlaps() as u32 {
            let (states, cost) = vit.quantize(&seq, Some(o), Some(o), &mut ws);
            let bf = brute_force(&trellis, &values, &seq, Some(o), Some(o));
            assert!((cost - bf).abs() < 1e-4 * (1.0 + bf), "overlap {o}");
            assert_eq!(states[0] & trellis.overlap_mask(), o);
            assert_eq!(states.last().unwrap() >> trellis.step_bits(), o);
            assert!(trellis.is_valid_walk(&states, true));
        }
    }

    #[test]
    fn naive_and_fast_agree() {
        prop_check("viterbi fast == naive", 25, |g| {
            let l = g.usize_in(3, 8) as u32;
            let k = g.usize_in(1, 2) as u32;
            let v = if l > 4 && g.bool() { 2u32 } else { 1 };
            if k * v >= l {
                return;
            }
            let trellis = Trellis::new(l, k, v);
            let values = g.gauss_vec(trellis.states() * v as usize);
            // Dual overlap constraints are only feasible once the stream is at
            // least one window long: steps * kV >= L.
            let min_steps = (l as usize).div_ceil((k * v) as usize);
            let steps = g.usize_in(min_steps.max(2), min_steps.max(2) + 10);
            let seq = g.gauss_vec(steps * v as usize);
            let vit = Viterbi::new(trellis, &values);
            let mut ws = ViterbiWorkspace::new();
            let o = if g.bool() {
                Some(g.usize_in(0, trellis.overlaps() - 1) as u32)
            } else {
                None
            };
            let (sf, cf) = vit.quantize(&seq, o, o, &mut ws);
            let (sn, cn) = vit.quantize_naive(&seq, o, o);
            assert!((cf - cn).abs() < 1e-4 * (1.0 + cn), "fast={cf} naive={cn}");
            // Paths may differ on exact ties; costs must match.
            assert!(trellis.is_valid_walk(&sf, false));
            assert!(trellis.is_valid_walk(&sn, false));
        });
    }

    #[test]
    fn quantizing_gaussian_reduces_error_with_l() {
        // Larger L => more states => lower distortion (Table 10's mechanism).
        let mut rng = Rng::new(42);
        let seq = rng.gauss_vec(256);
        let mut prev_mse = f64::INFINITY;
        let mut ws = ViterbiWorkspace::new();
        for l in [6u32, 8, 10] {
            let trellis = Trellis::new(l, 2, 1);
            let values = random_codebook(&trellis, 1000 + l as u64);
            let vit = Viterbi::new(trellis, &values);
            let (states, _) = vit.quantize(&seq, None, None, &mut ws);
            let dec = vit.decode(&states);
            let e = mse(&dec, &seq);
            assert!(e < prev_mse, "L={l}: {e} !< {prev_mse}");
            prev_mse = e;
        }
    }

    #[test]
    fn single_step_sequence() {
        let trellis = Trellis::new(4, 2, 1);
        let values = random_codebook(&trellis, 5);
        let vit = Viterbi::new(trellis, &values);
        let mut ws = ViterbiWorkspace::new();
        let (states, cost) = vit.quantize(&[0.37], None, None, &mut ws);
        assert_eq!(states.len(), 1);
        // Must pick the globally nearest codeword.
        let best = values
            .iter()
            .map(|&v| ((v - 0.37) as f64).powi(2))
            .fold(f64::INFINITY, f64::min);
        assert!((cost - best).abs() < 1e-6);
    }

    #[test]
    fn workspace_reuse_is_clean() {
        // Two quantizations with the same workspace give identical results.
        let trellis = Trellis::new(8, 2, 1);
        let values = random_codebook(&trellis, 11);
        let vit = Viterbi::new(trellis, &values);
        let mut rng = Rng::new(12);
        let seq = rng.gauss_vec(32);
        let mut ws = ViterbiWorkspace::new();
        let a = vit.quantize(&seq, None, None, &mut ws);
        // Pollute with a different-shaped call.
        let other = rng.gauss_vec(8);
        let _ = vit.quantize(&other, Some(3), Some(3), &mut ws);
        let b = vit.quantize(&seq, None, None, &mut ws);
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
    }
}
