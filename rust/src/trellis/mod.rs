//! The bitshift trellis (paper §3.1) and trellis-coded quantization machinery.
//!
//! An `(L, k, V)` trellis is a directed graph over `2^L` states where each state
//! carries a value in R^V and has `2^kV` outgoing edges. QTIP uses the *bitshift*
//! trellis: states are L-bit sliding windows over the quantized bitstream, so walking
//! one step shifts the window by `kV` bits. We store the stream little-endian, which
//! makes the decoder `word >> (t*kV) & (2^L-1)` — state transitions are
//! `next = (cur >> kV) | (newbits << (L-kV))`.
//!
//! (The paper writes the window big-endian — `j = (i·2^kV mod 2^L) + c` — which is the
//! same trellis up to bit reversal of the state labels; the little-endian orientation
//! makes the predecessor set of state `j` a *contiguous* range `{(j & lowmask)·2^kV + d}`,
//! which is what makes the optimized Viterbi inner loop cache-friendly. See
//! `viterbi.rs`.)

pub mod packing;
pub mod tailbiting;
pub mod viterbi;

pub use tailbiting::{quantize_tail_biting, quantize_tail_biting_exact, TailBitingSolution};
pub use viterbi::{Viterbi, ViterbiWorkspace};

/// Parameters of an (L, k, V) bitshift trellis: `2^L` states, `k` bits per weight,
/// values in R^V (so `kV` bits consumed per trellis step).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Trellis {
    /// log2(number of states). 1..=24 supported.
    pub l: u32,
    /// Bits per weight.
    pub k: u32,
    /// Vector dimension of each node value.
    pub v: u32,
}

impl Trellis {
    pub fn new(l: u32, k: u32, v: u32) -> Self {
        assert!(l >= 1 && l <= 24, "L={l} out of supported range");
        assert!(k >= 1 && v >= 1, "k and V must be positive");
        let kv = k * v;
        assert!(kv < l, "need kV < L (kV={kv}, L={l})");
        assert!(kv <= 8, "kV={kv} > 8 not supported (u8 backpointers)");
        Trellis { l, k, v }
    }

    /// Number of states, 2^L.
    #[inline]
    pub fn states(&self) -> usize {
        1usize << self.l
    }

    /// Bits consumed per trellis step (kV).
    #[inline]
    pub fn step_bits(&self) -> u32 {
        self.k * self.v
    }

    /// Mask of the low `L - kV` bits (the part shared between consecutive states).
    #[inline]
    pub fn overlap_mask(&self) -> u32 {
        (1u32 << (self.l - self.step_bits())) - 1
    }

    /// Number of distinct overlaps, 2^(L-kV).
    #[inline]
    pub fn overlaps(&self) -> usize {
        1usize << (self.l - self.step_bits())
    }

    /// State mask, 2^L - 1.
    #[inline]
    pub fn state_mask(&self) -> u32 {
        (1u32 << self.l) - 1
    }

    /// Walk one step: from `state`, consume `newbits` (kV bits).
    #[inline]
    pub fn next_state(&self, state: u32, newbits: u32) -> u32 {
        debug_assert!(newbits < (1 << self.step_bits()));
        (state >> self.step_bits()) | (newbits << (self.l - self.step_bits()))
    }

    /// Is (a -> b) an edge of the bitshift trellis?
    #[inline]
    pub fn is_edge(&self, a: u32, b: u32) -> bool {
        (b & self.overlap_mask()) == (a >> self.step_bits())
    }

    /// Trellis steps needed to quantize a sequence of `t` weights (requires V | t).
    #[inline]
    pub fn steps_for(&self, t: usize) -> usize {
        assert_eq!(t % self.v as usize, 0, "sequence length {t} not divisible by V");
        t / self.v as usize
    }

    /// Verify a state path is a valid walk (and, if `tail_biting`, cyclic).
    pub fn is_valid_walk(&self, states: &[u32], tail_biting: bool) -> bool {
        if states.is_empty() {
            return false;
        }
        for w in states.windows(2) {
            if !self.is_edge(w[0], w[1]) {
                return false;
            }
        }
        if tail_biting {
            let first = states[0];
            let last = *states.last().unwrap();
            if (last >> self.step_bits()) != (first & self.overlap_mask()) {
                return false;
            }
        }
        true
    }
}

/// Reconstruct the weight sequence from a state path given the materialized
/// codebook (`values[state * V + j]`).
pub fn decode_states(trellis: &Trellis, states: &[u32], values: &[f32]) -> Vec<f32> {
    let v = trellis.v as usize;
    assert_eq!(values.len(), trellis.states() * v);
    let mut out = Vec::with_capacity(states.len() * v);
    for &s in states {
        let base = s as usize * v;
        out.extend_from_slice(&values[base..base + v]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_validates() {
        let t = Trellis::new(16, 2, 1);
        assert_eq!(t.states(), 65536);
        assert_eq!(t.step_bits(), 2);
        assert_eq!(t.overlaps(), 1 << 14);
    }

    #[test]
    #[should_panic(expected = "kV < L")]
    fn rejects_kv_ge_l() {
        Trellis::new(2, 2, 1);
    }

    #[test]
    fn edges_follow_bitshift_rule() {
        let t = Trellis::new(4, 1, 1); // 16 states, 2 edges out
        // next of state 0b1011 with newbit 1 -> 0b1101
        assert_eq!(t.next_state(0b1011, 1), 0b1101);
        assert!(t.is_edge(0b1011, 0b1101));
        assert!(t.is_edge(0b1011, 0b0101));
        assert!(!t.is_edge(0b1011, 0b1110));
        // Out-degree is exactly 2^kV.
        let outs: Vec<u32> = (0..2u32).map(|c| t.next_state(0b1011, c)).collect();
        assert_eq!(outs.len(), 2);
        assert_ne!(outs[0], outs[1]);
    }

    #[test]
    fn paper_figure2_trellis() {
        // Figure 2: L=2, k=1, V=1 — each node transitions to the 2 nodes sharing
        // its (in our orientation, low) overlap bit.
        let t = Trellis::new(2, 1, 1);
        assert_eq!(t.states(), 4);
        for s in 0..4u32 {
            let succs: Vec<u32> = (0..2).map(|c| t.next_state(s, c)).collect();
            for &n in &succs {
                assert!(t.is_edge(s, n));
                // Overlap: low bit of n == high bit of s.
                assert_eq!(n & 1, s >> 1);
            }
        }
    }

    #[test]
    fn valid_walk_detection() {
        let t = Trellis::new(4, 2, 1);
        let mut states = vec![0b1010u32];
        let mut s = states[0];
        for c in [1u32, 3, 0, 2] {
            s = t.next_state(s, c);
            states.push(s);
        }
        assert!(t.is_valid_walk(&states, false));
        let mut broken = states.clone();
        broken[2] ^= 0b1; // flipping a low (overlap) bit breaks the edge
        assert!(!t.is_valid_walk(&broken, false));
    }

    #[test]
    fn decode_states_v2() {
        let t = Trellis::new(4, 1, 2);
        let mut values = vec![0.0f32; 16 * 2];
        for s in 0..16 {
            values[s * 2] = s as f32;
            values[s * 2 + 1] = -(s as f32);
        }
        let out = decode_states(&t, &[3, 7], &values);
        assert_eq!(out, vec![3.0, -3.0, 7.0, -7.0]);
    }
}
