//! Bit packing of tail-biting trellis walks (paper §3.1–3.2, Figure 2).
//!
//! A tail-biting walk over `N` steps of a `(L,k,V)` trellis is exactly `N·kV = kT`
//! bits: the stream is cyclic and state `t` is the L-bit window starting at bit
//! `t·kV`. We store the stream little-endian in `u32` words (bit `p` lives at
//! `words[p/32] >> (p%32) & 1`), which is the layout the decode hot path consumes.
//!
//! For decoding we additionally *pre-duplicate* the first `L−kV` bits after the end
//! of the stream (`pad_for_decode`) so the hot loop never needs a modular wrap: each
//! state is then a plain 64-bit load + shift + mask — the paper's "bitshift decode"
//! (§3.1), adapted from GPU registers to CPU words.

use super::Trellis;

#[inline]
fn get_bit(words: &[u32], p: usize) -> u32 {
    (words[p / 32] >> (p % 32)) & 1
}

#[inline]
fn set_bit(words: &mut [u32], p: usize, b: u32) {
    let w = p / 32;
    let s = p % 32;
    words[w] = (words[w] & !(1 << s)) | ((b & 1) << s);
}

/// Pack a tail-biting state path into `ceil(N·kV/32)` words.
///
/// Panics (debug) if the walk is not a valid tail-biting walk: wrapped positions must
/// re-produce the already-written head bits.
pub fn pack_states(trellis: &Trellis, states: &[u32]) -> Vec<u32> {
    let kv = trellis.step_bits() as usize;
    let l = trellis.l as usize;
    let n = states.len();
    let total_bits = n * kv;
    assert!(total_bits >= l, "stream shorter than one window");
    let mut words = vec![0u32; total_bits.div_ceil(32)];

    // State 0 contributes bits [0, L).
    for i in 0..l {
        set_bit(&mut words, i, states[0] >> i);
    }
    // Each later state contributes its top kV bits at [(t-1)kV + L, t·kV + L),
    // wrapping modulo the cyclic stream length.
    for (t, &s) in states.iter().enumerate().skip(1) {
        let newbits = s >> (l - kv);
        for i in 0..kv {
            let p = ((t - 1) * kv + l + i) % total_bits;
            let b = (newbits >> i) & 1;
            if p < l {
                // Wrapped into the head: must agree with state 0 (tail-biting).
                debug_assert_eq!(
                    get_bit(&words, p),
                    b,
                    "walk is not tail-biting at wrapped bit {p}"
                );
            }
            set_bit(&mut words, p, b);
        }
    }
    words
}

/// Recover the state path from a packed cyclic stream.
pub fn unpack_states(trellis: &Trellis, words: &[u32], steps: usize) -> Vec<u32> {
    let kv = trellis.step_bits() as usize;
    let l = trellis.l as usize;
    let total_bits = steps * kv;
    assert!(words.len() * 32 >= total_bits);
    let mut states = Vec::with_capacity(steps);
    for t in 0..steps {
        let mut s = 0u32;
        for i in 0..l {
            let p = (t * kv + i) % total_bits;
            s |= get_bit(words, p) << i;
        }
        states.push(s);
    }
    states
}

/// Append the first `L−kV` bits after the end of the stream, then one explicit
/// all-zero **guard word**, so every window read is a single unaligned 64-bit
/// load (`decode_window`).
///
/// Guard-word invariant (the decode kernels' bounds contract): the padded
/// stream holds `padded_bits = steps·kV + (L−kV)` content bits in its first
/// `ceil(padded_bits/32)` words, plus one zero guard word. The last window any
/// kernel reads starts at bit `(steps−1)·kV` and ends exactly at
/// `padded_bits`, so its high-word index satisfies
/// `w + 1 ≤ ceil(padded_bits/32) = len − 1` — every unconditional
/// `words[w + 1]` load in `decode_window`, the rolling-window v1 kernels, and
/// the lane-blocked kernels is therefore in-bounds at every valid offset.
/// `tests::guard_word_covers_end_of_stream_reads` pins this at the exact
/// end-of-stream offsets.
pub fn pad_for_decode(trellis: &Trellis, words: &[u32], steps: usize) -> Vec<u32> {
    let kv = trellis.step_bits() as usize;
    let l = trellis.l as usize;
    let total_bits = steps * kv;
    let padded_bits = total_bits + (l - kv);
    // Content words + one explicit guard word (see the invariant above).
    let mut out = vec![0u32; padded_bits.div_ceil(32) + 1];
    out[..words.len()].copy_from_slice(words);
    for i in 0..(l - kv) {
        set_bit(&mut out, total_bits + i, get_bit(words, i));
    }
    out
}

/// Hot-path window extraction from a padded stream: state `t` = `decode_window(padded,
/// t*kV, L)`. One 64-bit load, shift, mask. The unconditional `padded[w + 1]`
/// load relies on the guard word appended by [`pad_for_decode`]; callers must
/// only pass padded streams and in-stream offsets.
#[inline(always)]
pub fn decode_window(padded: &[u32], bit_offset: usize, l: u32) -> u32 {
    let w = bit_offset >> 5;
    let sh = bit_offset & 31;
    debug_assert!(w + 1 < padded.len(), "window read past the guard word");
    let lo = padded[w] as u64;
    let hi = padded[w + 1] as u64;
    let pair = lo | (hi << 32);
    ((pair >> sh) & ((1u64 << l) - 1)) as u32
}

/// Bits per weight actually stored by the tail-biting layout (exactly k).
pub fn bits_per_weight(trellis: &Trellis) -> f64 {
    trellis.k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trellis::viterbi::{Viterbi, ViterbiWorkspace};
    use crate::trellis::{quantize_tail_biting, Trellis};
    use crate::util::prop::prop_check;
    use crate::util::rng::Rng;

    fn tb_walk(trellis: &Trellis, seed: u64, steps: usize) -> Vec<u32> {
        // Build a valid tail-biting walk via the quantizer itself.
        let mut rng = Rng::new(seed);
        let values = rng.gauss_vec(trellis.states() * trellis.v as usize);
        let vit = Viterbi::new(*trellis, &values);
        let seq = rng.gauss_vec(steps * trellis.v as usize);
        let mut ws = ViterbiWorkspace::new();
        quantize_tail_biting(&vit, &seq, &mut ws).states
    }

    #[test]
    fn roundtrip_simple() {
        prop_check("pack/unpack roundtrip", 30, |g| {
            let l = g.usize_in(3, 10) as u32;
            let k = g.usize_in(1, 2) as u32;
            let v = if k * 2 < l && g.bool() { 2 } else { 1 };
            if k * v >= l {
                return;
            }
            let trellis = Trellis::new(l, k, v);
            let steps = g.usize_in(
                (l as usize).div_ceil((k * v) as usize) + 1,
                40,
            );
            let states = tb_walk(&trellis, g.rng.next_u64(), steps);
            let packed = pack_states(&trellis, &states);
            let unpacked = unpack_states(&trellis, &packed, steps);
            assert_eq!(states, unpacked);
        });
    }

    #[test]
    fn exact_bit_budget() {
        // Figure 2 / §3.2: tail-biting stores exactly kT bits.
        let trellis = Trellis::new(12, 2, 1);
        let steps = 256;
        let states = tb_walk(&trellis, 3, steps);
        let packed = pack_states(&trellis, &states);
        assert_eq!(packed.len(), (steps * 2).div_ceil(32)); // 512 bits = 16 words
    }

    #[test]
    fn figure2_scale_example() {
        // The paper's Figure 2 trellis: L=2, k=1, V=1, T=6 -> 6 bits tail-biting.
        let trellis = Trellis::new(2, 1, 1);
        let states = tb_walk(&trellis, 9, 6);
        assert!(trellis.is_valid_walk(&states, true));
        let packed = pack_states(&trellis, &states);
        assert_eq!(packed.len(), 1);
        assert_eq!(packed[0] >> 6, 0, "only 6 bits may be used");
        assert_eq!(unpack_states(&trellis, &packed, 6), states);
    }

    #[test]
    fn padded_decode_matches_unpack() {
        prop_check("padded window decode == unpack", 30, |g| {
            let l = g.usize_in(4, 16) as u32;
            let k = g.usize_in(1, 2) as u32;
            if k >= l {
                return;
            }
            let trellis = Trellis::new(l, k, 1);
            let steps = g.usize_in((l as usize).div_ceil(k as usize) + 1, 64);
            let states = tb_walk(&trellis, g.rng.next_u64(), steps);
            let packed = pack_states(&trellis, &states);
            let padded = pad_for_decode(&trellis, &packed, steps);
            for (t, &s) in states.iter().enumerate() {
                let w = decode_window(&padded, t * k as usize, l);
                assert_eq!(w, s, "step {t}");
            }
        });
    }

    #[test]
    fn guard_word_covers_end_of_stream_reads() {
        // The exact offsets the hot kernels hit at the end of a padded stream:
        // for every step — the final one at bit (steps−1)·kV in particular —
        // the unconditional high-word load `padded[w + 1]` must be in-bounds,
        // and the explicit guard word must exist and stay zero.
        for (l, k, steps) in [(12u32, 2u32, 200usize), (10, 1, 97), (10, 3, 64), (16, 2, 40)] {
            let trellis = Trellis::new(l, k, 1);
            let states = tb_walk(&trellis, ((l as u64) << 8) | steps as u64, steps);
            let packed = pack_states(&trellis, &states);
            let padded = pad_for_decode(&trellis, &packed, steps);
            let padded_bits = steps * k as usize + (l - k) as usize;
            assert_eq!(
                padded.len(),
                padded_bits.div_ceil(32) + 1,
                "L={l} k={k}: guard word missing"
            );
            assert_eq!(*padded.last().unwrap(), 0, "L={l} k={k}: guard word not zero");
            for (t, &s) in states.iter().enumerate() {
                let bit = t * k as usize;
                assert!(
                    (bit >> 5) + 1 < padded.len(),
                    "L={l} k={k} step {t}: high-word load out of bounds"
                );
                assert_eq!(decode_window(&padded, bit, l), s, "L={l} k={k} step {t}");
            }
        }
    }

    #[test]
    fn guard_word_property_adversarial_lengths() {
        // Property form of the guard-word invariant, Miri-friendly: streams
        // are drawn directly from the RNG (no Viterbi walk — any bit pattern
        // is a legal cyclic stream for the padding layout), so the Miri lane
        // can afford it. Lengths are biased toward the adversarial word
        // boundaries (`padded_bits % 32 ∈ {0, 1, 31}`) where an off-by-one in
        // the guard-word arithmetic would first go out of bounds.
        prop_check("pad_for_decode guard word bounds", 40, |g| {
            let l = g.usize_in(4, 16);
            let k = g.usize_in(1, 2);
            let v = if 2 * k < l && g.bool() { 2 } else { 1 };
            let kv = k * v;
            if kv >= l {
                return;
            }
            let mut steps = g.usize_in(l.div_ceil(kv) + 1, 200);
            if g.bool() {
                // Nudge toward a boundary-adjacent padded length. Bounded
                // scan: some (kV, L) residue classes can never land on one.
                for _ in 0..32 {
                    if matches!((steps * kv + (l - kv)) % 32, 0 | 1 | 31) {
                        break;
                    }
                    steps += 1;
                }
            }
            let trellis = Trellis::new(l as u32, k as u32, v as u32);
            let total_bits = steps * kv;
            let mut words: Vec<u32> =
                (0..total_bits.div_ceil(32)).map(|_| g.rng.next_u64() as u32).collect();
            // Zero the stray bits past the stream end, as pack_states would.
            if total_bits % 32 != 0 {
                let last = words.len() - 1;
                words[last] &= (1u32 << (total_bits % 32)) - 1;
            }
            let padded = pad_for_decode(&trellis, &words, steps);
            let padded_bits = total_bits + (l - kv);
            assert_eq!(
                padded.len(),
                padded_bits.div_ceil(32) + 1,
                "L={l} kV={kv} steps={steps}: padded length must be content + guard"
            );
            assert_eq!(
                *padded.last().unwrap(),
                0,
                "L={l} kV={kv} steps={steps}: guard word must be zero"
            );
            for t in 0..steps {
                let bit = t * kv;
                // The decode kernels' unconditional high-word load.
                assert!(
                    (bit >> 5) + 1 < padded.len(),
                    "L={l} kV={kv} steps={steps} step {t}: padded[w+1] out of bounds"
                );
                // Cyclic-stream reference, bit by bit.
                let mut expect = 0u32;
                for i in 0..l {
                    expect |= get_bit(&words, (bit + i) % total_bits) << i;
                }
                assert_eq!(
                    decode_window(&padded, bit, l as u32),
                    expect,
                    "L={l} kV={kv} steps={steps} step {t}: window != cyclic reference"
                );
            }
        });
    }

    #[test]
    fn decode_window_basics() {
        // Stream: bits 0..32 in word0 = 0xDEADBEEF, word1 = 0x12345678.
        let words = vec![0xDEADBEEFu32, 0x12345678, 0];
        assert_eq!(decode_window(&words, 0, 16), 0xBEEF);
        assert_eq!(decode_window(&words, 16, 16), 0xDEAD);
        // Window straddling the word boundary: bits 24..40.
        let expect = ((0x12345678u64 << 32 | 0xDEADBEEF) >> 24) & 0xFFFF;
        assert_eq!(decode_window(&words, 24, 16), expect as u32);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "not tail-biting")]
    fn pack_rejects_non_tail_biting() {
        let trellis = Trellis::new(4, 1, 1);
        // Build a valid walk then break the tail-biting property.
        let mut states = tb_walk(&trellis, 1, 12);
        let last = states.len() - 1;
        // Flip a high bit of the last state; still need a valid edge from prev:
        // easiest reliable break: rotate the walk's first state's low bits.
        states[last] ^= 1 << 3;
        // Ensure it's still a valid (non-tb) walk prefix by recomputing the edge:
        if trellis.is_edge(states[last - 1], states[last]) {
            pack_states(&trellis, &states);
            // If the flip happened to keep tail-biting (unlikely), force failure:
            panic!("walk is not tail-biting at wrapped bit 0");
        } else {
            // The flipped bit broke the edge, not the tail-bite; craft directly:
            // walk of all-zero states is tail-biting; make last state 0b1000.
            let mut zeros = vec![0u32; 12];
            zeros[11] = 0b1000;
            pack_states(&trellis, &zeros);
        }
    }
}
