//! Minimal CLI argument parser (clap substitute): `--key value` / `--flag`
//! options plus positional arguments, with typed getters and usage errors.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    /// Every occurrence of each `--key value` in argv order; `options` keeps
    /// only the last. Repeatable options (`--artifact a --artifact b`) read
    /// from here via [`Args::get_all`].
    pub repeated: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (after the subcommand).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key=value | --key value | --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.repeated.entry(k.to_string()).or_default().push(v.to_string());
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.repeated.entry(name.to_string()).or_default().push(v.clone());
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// All values given for a repeatable option, in argv order (empty if the
    /// option never appeared).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.repeated.get(key).map_or_else(Vec::new, |v| v.iter().map(|s| s.as_str()).collect())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer")))
            .unwrap_or(default)
    }

    pub fn get_u32(&self, key: &str, default: u32) -> u32 {
        self.get_usize(key, default as usize) as u32
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_args() {
        // NB: grammar is greedy — `--name value` binds the following token, so
        // flags must precede another `--option` or end the argv.
        let a = parse("model pos2 --k 2 --code 3inst --l=16 --verbose");
        assert_eq!(a.positional, vec!["model", "pos2"]);
        assert_eq!(a.get("k"), Some("2"));
        assert_eq!(a.get("code"), Some("3inst"));
        assert_eq!(a.get("l"), Some("16"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--k 3 --temp 0.5 --seed 42");
        assert_eq!(a.get_usize("k", 0), 3);
        assert_eq!(a.get_f32("temp", 0.0), 0.5);
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get_usize("missing", 9), 9);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_int_panics() {
        parse("--k abc").get_usize("k", 0);
    }

    #[test]
    fn flag_before_end() {
        let a = parse("--fast --k 2");
        assert!(a.has_flag("fast"));
        assert_eq!(a.get_usize("k", 0), 2);
    }

    #[test]
    fn repeated_options_collect_in_order() {
        let a = parse("--artifact m1 --artifact m2 --k 2");
        assert_eq!(a.get_all("artifact"), vec!["m1", "m2"]);
        // Scalar getter keeps last-wins semantics.
        assert_eq!(a.get("artifact"), Some("m2"));
        assert_eq!(a.get_all("missing"), Vec::<&str>::new());
    }
}
