//! E8-lattice 8-D vector quantizer — the QuIP#-style "E8P" comparator.
//!
//! QuIP# quantizes 8-weight groups to a 2^16-entry codebook built from the E8
//! lattice (the densest 8-D packing). We reproduce the construction from first
//! principles: E8 = D8 ∪ (D8 + ½·1) where D8 = {x ∈ Z^8 : Σx even}; the codebook is
//! the 2^16 lowest-norm lattice points (ball of E8), globally scaled to minimize
//! N(0,1) distortion. Encoding is exact nearest-neighbor search.
//!
//! This is the paper's Table 1 "VQ / QuIP# E8P" column (0.089 MSE at 2 bits) and
//! the proximal VQ baseline inside BlockLDLQ for the perplexity tables. Higher
//! bitrates follow QuIP#'s residual scheme: E8 for the first 2 bits/weight, then
//! Lloyd–Max scalar stages on the residual (`E8Rvq`).

use super::lloydmax::LloydMax;
use crate::util::rng::Rng;

/// An 8-D codebook of E8 lattice points.
#[derive(Clone, Debug)]
pub struct E8Codebook {
    /// `n × 8` row-major entries, *after* global scaling.
    pub entries: Vec<f32>,
    /// Squared norms of each entry (precomputed for NN search).
    norms: Vec<f32>,
    /// The global scale applied to the raw lattice points.
    pub scale: f32,
}

/// Enumerate all points of D8 (+ optional half offset) with squared norm ≤ r2.
fn enumerate_coset(half: bool, r2: f64, out: &mut Vec<([f32; 8], f64)>) {
    // Recursive enumeration with norm budget pruning.
    fn rec(
        dim: usize,
        half: bool,
        point: &mut [f32; 8],
        sum_int: i64,
        norm2: f64,
        r2: f64,
        out: &mut Vec<([f32; 8], f64)>,
    ) {
        if dim == 8 {
            // D8 condition: integer-part sum even. For the half coset the shifted
            // coordinates are c+0.5 with c ∈ Z; E8's half coset requires Σ(2x) ≡ 0
            // (mod 4) ⇔ Σc even as well (all-half vectors with Σx ∈ 2Z + 2).
            if sum_int % 2 == 0 {
                out.push((*point, norm2));
            }
            return;
        }
        let offset = if half { 0.5f64 } else { 0.0 };
        let bound = (r2 - norm2).sqrt();
        let lo = (-bound - offset).ceil() as i64;
        let hi = (bound - offset).floor() as i64;
        for c in lo..=hi {
            let x = c as f64 + offset;
            let n2 = norm2 + x * x;
            if n2 <= r2 + 1e-9 {
                point[dim] = x as f32;
                rec(dim + 1, half, point, sum_int + c, n2, r2, out);
            }
        }
    }
    let mut point = [0.0f32; 8];
    rec(0, half, &mut point, 0, 0.0, r2, out);
}

impl E8Codebook {
    /// Build the `n`-entry E8 ball codebook (n = 2^16 for the paper's setting),
    /// scaled to minimize MSE on an N(0,1) sample.
    pub fn build(n: usize, seed: u64) -> Self {
        // Grow the radius until enough lattice points are enumerated.
        let mut r2 = 4.0;
        let mut pts: Vec<([f32; 8], f64)> = Vec::new();
        loop {
            pts.clear();
            enumerate_coset(false, r2, &mut pts);
            enumerate_coset(true, r2, &mut pts);
            if pts.len() >= n {
                break;
            }
            r2 += 2.0; // E8 shells live at even squared norms
        }
        // Lowest-norm first; deterministic tie-break by coordinates.
        pts.sort_by(|a, b| {
            a.1.partial_cmp(&b.1)
                .unwrap()
                .then_with(|| a.0.partial_cmp(&b.0).unwrap())
        });
        pts.truncate(n);

        let mut raw = Vec::with_capacity(n * 8);
        for (p, _) in &pts {
            raw.extend_from_slice(p);
        }

        // Line-search the global scale on a Gaussian sample.
        let mut rng = Rng::new(seed);
        let sample: Vec<f32> = rng.gauss_vec(8 * 512);
        let mut best = (f64::INFINITY, 1.0f32);
        let mut s = 0.20f32;
        while s <= 1.2 {
            let cb = Self::from_raw(&raw, s);
            let mut err = 0.0;
            for v in sample.chunks(8) {
                let q = cb.quantize_vec(v);
                err += v.iter().zip(&q).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>();
            }
            if err < best.0 {
                best = (err, s);
            }
            s += 0.025;
        }
        Self::from_raw(&raw, best.1)
    }

    fn from_raw(raw: &[f32], scale: f32) -> Self {
        let entries: Vec<f32> = raw.iter().map(|&x| x * scale).collect();
        let norms = entries
            .chunks(8)
            .map(|c| c.iter().map(|&x| x * x).sum::<f32>())
            .collect();
        E8Codebook { entries, norms, scale }
    }

    pub fn len(&self) -> usize {
        self.norms.len()
    }

    pub fn is_empty(&self) -> bool {
        self.norms.is_empty()
    }

    /// Bits per weight of this codebook used alone: log2(n)/8.
    pub fn bits_per_weight(&self) -> f64 {
        (self.len() as f64).log2() / 8.0
    }

    /// Exact nearest neighbor: argmin ||x - c||² = argmin (||c||² − 2⟨x,c⟩).
    pub fn encode(&self, x: &[f32]) -> usize {
        assert_eq!(x.len(), 8);
        let mut best = f32::INFINITY;
        let mut arg = 0usize;
        for (i, c) in self.entries.chunks_exact(8).enumerate() {
            let mut dot = 0.0f32;
            for j in 0..8 {
                dot += x[j] * c[j];
            }
            let score = self.norms[i] - 2.0 * dot;
            if score < best {
                best = score;
                arg = i;
            }
        }
        arg
    }

    /// Quantize one 8-vector (returns the reconstruction).
    pub fn quantize_vec(&self, x: &[f32]) -> Vec<f32> {
        let i = self.encode(x);
        self.entries[i * 8..(i + 1) * 8].to_vec()
    }

    /// Quantize a sequence (length divisible by 8).
    pub fn quantize_all(&self, xs: &[f32]) -> Vec<f32> {
        assert_eq!(xs.len() % 8, 0);
        let mut out = Vec::with_capacity(xs.len());
        for v in xs.chunks_exact(8) {
            out.extend_from_slice(&self.quantize_vec(v));
        }
        out
    }
}

/// Residual VQ: an E8 stage (2 bits/weight) followed by Lloyd–Max scalar stages
/// (1 bit each) on the residual — QuIP#'s recipe for 3- and 4-bit models.
#[derive(Clone)]
pub struct E8Rvq {
    pub e8: E8Codebook,
    pub residual_stages: Vec<LloydMax>,
    /// Residual std per stage (the scalar stage is trained on N(0,1) and scaled).
    residual_scales: Vec<f32>,
}

impl E8Rvq {
    /// `k` total bits per weight (k >= 2): E8 for 2, scalar stages for the rest.
    pub fn build(k: u32, e8_entries: usize, seed: u64) -> Self {
        assert!(k >= 2);
        let e8 = E8Codebook::build(e8_entries, seed);
        let mut rng = Rng::new(seed ^ 0xE8);
        let mut residual_stages = Vec::new();
        let mut residual_scales = Vec::new();
        // Estimate residual scale empirically stage by stage.
        let sample: Vec<f32> = rng.gauss_vec(8 * 256);
        let mut resid: Vec<f32> = {
            let q = e8.quantize_all(&sample);
            sample.iter().zip(&q).map(|(a, b)| a - b).collect()
        };
        for stage in 0..(k - 2) {
            let var =
                resid.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / resid.len() as f64;
            let scale = (var.sqrt() as f32).max(1e-6);
            let lm = LloydMax::train(1, 100_000, seed ^ (stage as u64 + 1));
            resid = resid
                .iter()
                .map(|&r| r - scale * lm.quantize(r / scale))
                .collect();
            residual_stages.push(lm);
            residual_scales.push(scale);
        }
        E8Rvq { e8, residual_stages, residual_scales }
    }

    pub fn bits_per_weight(&self) -> f64 {
        self.e8.bits_per_weight() + self.residual_stages.len() as f64
    }

    /// Quantize a sequence (length divisible by 8).
    pub fn quantize_all(&self, xs: &[f32]) -> Vec<f32> {
        let mut rec = self.e8.quantize_all(xs);
        let mut resid: Vec<f32> = xs.iter().zip(&rec).map(|(a, b)| a - b).collect();
        for (lm, &scale) in self.residual_stages.iter().zip(&self.residual_scales) {
            for (r, out) in resid.iter_mut().zip(rec.iter_mut()) {
                let q = scale * lm.quantize(*r / scale);
                *out += q;
                *r -= q;
            }
        }
        rec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mse;

    #[test]
    fn e8_shell_counts() {
        // The E8 theta series: 240 vectors of norm² 2, 2160 of norm² 4.
        let mut pts = Vec::new();
        enumerate_coset(false, 2.0, &mut pts);
        enumerate_coset(true, 2.0, &mut pts);
        let shell2 = pts.iter().filter(|(_, n)| (n - 2.0).abs() < 1e-6).count();
        assert_eq!(shell2, 240);
        pts.clear();
        enumerate_coset(false, 4.0, &mut pts);
        enumerate_coset(true, 4.0, &mut pts);
        let shell4 = pts.iter().filter(|(_, n)| (n - 4.0).abs() < 1e-6).count();
        assert_eq!(shell4, 2160);
    }

    #[test]
    fn all_points_are_e8() {
        let mut pts = Vec::new();
        enumerate_coset(false, 6.0, &mut pts);
        enumerate_coset(true, 6.0, &mut pts);
        for (p, _) in &pts {
            let doubled: Vec<i64> = p.iter().map(|&x| (2.0 * x).round() as i64).collect();
            // All coords integer or all half-integer.
            let all_even = doubled.iter().all(|&d| d % 2 == 0);
            let all_odd = doubled.iter().all(|&d| d % 2 != 0);
            assert!(all_even || all_odd, "{p:?}");
            // Sum of coordinates even (E8 condition).
            let s: f64 = p.iter().map(|&x| x as f64).sum();
            assert!((s / 2.0 - (s / 2.0).round()).abs() < 1e-9, "{p:?}");
        }
    }

    #[test]
    fn small_codebook_quantizes() {
        let cb = E8Codebook::build(1024, 1);
        assert_eq!(cb.len(), 1024);
        let mut rng = Rng::new(2);
        let xs = rng.gauss_vec(8 * 64);
        let rec = cb.quantize_all(&xs);
        let e = mse(&rec, &xs);
        // 10 bits / 8 weights = 1.25 bpw; must beat nothing fancy but be sane.
        assert!(e < 0.5, "MSE {e}");
    }

    #[test]
    fn encode_is_exact_nn() {
        let cb = E8Codebook::build(512, 3);
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let x = rng.gauss_vec(8);
            let i = cb.encode(&x);
            let mut best = f64::INFINITY;
            let mut arg = 0;
            for (j, c) in cb.entries.chunks_exact(8).enumerate() {
                let d: f64 = x.iter().zip(c).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum();
                if d < best {
                    best = d;
                    arg = j;
                }
            }
            assert_eq!(i, arg);
        }
    }

    #[test]
    fn rvq_bits_accounting() {
        let q3 = E8Rvq::build(3, 1024, 5);
        assert_eq!(q3.residual_stages.len(), 1);
        let q4 = E8Rvq::build(4, 1024, 5);
        assert_eq!(q4.residual_stages.len(), 2);
    }

    #[test]
    fn rvq_improves_with_bits() {
        let mut rng = Rng::new(6);
        let xs = rng.gauss_vec(8 * 128);
        let mut prev = f64::INFINITY;
        for k in 2..=4 {
            let q = E8Rvq::build(k, 2048, 7);
            let e = mse(&q.quantize_all(&xs), &xs);
            assert!(e < prev, "k={k}: {e} !< {prev}");
            prev = e;
        }
    }
}
