//! Lloyd–Max scalar quantizer (Table 1's "SQ" column and the scalar inner rounder
//! for the GPTQ-like baseline).
//!
//! Trained by 1-D k-means on an empirical N(0,1) sample (Lloyd's algorithm in 1-D
//! *is* the Lloyd–Max construction); encode is binary search over the sorted level
//! midpoints.

use crate::codes::kmeans::kmeans;
use crate::util::rng::Rng;

/// A k-bit optimal scalar quantizer for N(0,1).
#[derive(Clone, Debug)]
pub struct LloydMax {
    /// Sorted reconstruction levels, 2^k of them.
    pub levels: Vec<f32>,
    /// Decision boundaries (midpoints), 2^k - 1 of them.
    pub boundaries: Vec<f32>,
}

impl LloydMax {
    /// Train a 2^k-level quantizer on `n` Gaussian samples.
    pub fn train(k: u32, n: usize, seed: u64) -> Self {
        assert!(k >= 1 && k <= 8);
        let mut rng = Rng::new(seed);
        let pts = rng.gauss_vec(n);
        let km = kmeans(&pts, 1, 1 << k, 80, &mut rng);
        let mut levels = km.centroids;
        levels.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let boundaries = levels.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect();
        LloydMax { levels, boundaries }
    }

    /// Index of the nearest level.
    #[inline]
    pub fn encode(&self, x: f32) -> usize {
        // Binary search over boundaries.
        match self
            .boundaries
            .binary_search_by(|b| b.partial_cmp(&x).unwrap())
        {
            Ok(i) => i + 1,
            Err(i) => i,
        }
    }

    /// Quantize-dequantize.
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        self.levels[self.encode(x)]
    }

    /// Quantize a slice, returning the reconstruction.
    pub fn quantize_all(&self, xs: &[f32]) -> Vec<f32> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    pub fn bits(&self) -> u32 {
        (self.levels.len() as f64).log2() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mse;

    #[test]
    fn two_bit_mse_matches_table1() {
        // Paper Table 1: Lloyd–Max at k=2 attains 0.118 MSE on N(0,1).
        let q = LloydMax::train(2, 200_000, 1);
        let mut rng = Rng::new(2);
        let xs = rng.gauss_vec(100_000);
        let rec = q.quantize_all(&xs);
        let e = mse(&rec, &xs);
        assert!((e - 0.118).abs() < 0.004, "MSE {e}");
    }

    #[test]
    fn one_bit_is_sign_times_mean_abs() {
        // Optimal 1-bit quantizer for N(0,1): levels ±sqrt(2/pi) ≈ ±0.7979.
        let q = LloydMax::train(1, 200_000, 3);
        assert!((q.levels[0] + 0.7979).abs() < 0.01, "{:?}", q.levels);
        assert!((q.levels[1] - 0.7979).abs() < 0.01);
    }

    #[test]
    fn encode_picks_nearest() {
        let q = LloydMax::train(3, 50_000, 4);
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            let x = rng.gauss_f32() * 2.0;
            let e = q.encode(x);
            // Exhaustive nearest.
            let best = q
                .levels
                .iter()
                .enumerate()
                .min_by(|a, b| {
                    (a.1 - x).abs().partial_cmp(&(b.1 - x).abs()).unwrap()
                })
                .unwrap()
                .0;
            assert_eq!(e, best, "x={x}");
        }
    }

    #[test]
    fn mse_improves_with_bits() {
        let mut rng = Rng::new(6);
        let xs = rng.gauss_vec(50_000);
        let mut prev = f64::INFINITY;
        for k in 1..=4 {
            let q = LloydMax::train(k, 100_000, 7);
            let e = mse(&q.quantize_all(&xs), &xs);
            assert!(e < prev, "k={k}");
            prev = e;
        }
        // 4-bit scalar Lloyd–Max ~ 0.0095 (vs D_R 0.0039).
        assert!(prev < 0.012);
    }
}
