//! Baseline quantizers the paper compares against (Table 1's SQ/VQ columns and the
//! QuIP#-proxy comparator used in the perplexity tables).
//!
//! These are *in-repo reimplementations*, not wrappers: DESIGN.md §4 documents how
//! each maps onto the published baseline (Lloyd–Max ↔ scalar SQ; `E8Codebook` ↔
//! QuIP# E8P; `E8Rvq` ↔ QuIP#'s residual 3/4-bit recipe; scalar-LDLQ ↔ GPTQ —
//! realized by using [`lloydmax::LloydMax`] as the inner rounder of
//! `quant::ldlq`).

pub mod e8p;
pub mod lloydmax;

pub use e8p::{E8Codebook, E8Rvq};
pub use lloydmax::LloydMax;
