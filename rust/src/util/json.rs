//! Minimal JSON reader/writer (serde is unavailable offline).
//!
//! Used for the artifact manifests written by `python/compile/{train,aot}.py`, the
//! experiment record files, and the TCP serving protocol. Supports the full JSON
//! grammar except for exotic numeric forms; numbers are parsed as f64.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Convenience: fetch `key` as usize or panic with a useful message.
    pub fn req_usize(&self, key: &str) -> usize {
        self.get(key)
            .and_then(|v| v.as_usize())
            .unwrap_or_else(|| panic!("manifest missing numeric field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> &str {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or_else(|| panic!("manifest missing string field '{key}'"))
    }

    pub fn req_f64(&self, key: &str) -> f64 {
        self.get(key)
            .and_then(|v| v.as_f64())
            .unwrap_or_else(|| panic!("manifest missing numeric field '{key}'"))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("d"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn escapes_roundtrip() {
        let orig = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let text = orig.to_string();
        assert_eq!(Json::parse(&text).unwrap(), orig);
    }

    #[test]
    fn unicode_string() {
        let j = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo é"));
    }

    #[test]
    fn display_roundtrip() {
        let j = Json::obj(vec![
            ("name", Json::Str("qtip".into())),
            ("dims", Json::Arr(vec![Json::Num(16.0), Json::Num(256.0)])),
            ("ok", Json::Bool(true)),
        ]);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed, j);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(16.0).to_string(), "16");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }

    #[test]
    fn error_displays_position() {
        let err = Json::parse("[1,]").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("json parse error at byte"), "{msg}");
        // JsonError is a real std error (anyhow interop without thiserror).
        let _: &dyn std::error::Error = &err;
    }

    #[test]
    fn req_f64_reads_numbers() {
        let j = Json::parse(r#"{"x": 2.5}"#).unwrap();
        assert_eq!(j.req_f64("x"), 2.5);
    }

    #[test]
    #[should_panic(expected = "missing numeric field")]
    fn req_f64_panics_on_missing() {
        Json::parse("{}").unwrap().req_f64("nope");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}
