//! Foundational substrates (offline environment: rand/serde/rayon/half/proptest are
//! unavailable, so each role is implemented here — see DESIGN.md §3).

pub mod f16;
pub mod fault;
pub mod hadamard;
pub mod json;
pub mod linalg;
pub mod matrix;
pub mod prop;
pub mod rng;
pub mod shutdown;
pub mod stats;
pub mod sync;
pub mod threadpool;

/// Wall-clock timer for the bench harness.
pub struct Timer(std::time::Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(std::time::Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }
}
