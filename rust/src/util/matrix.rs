//! Dense row-major f32 matrices and the blocked GEMM/GEMV kernels that back the
//! transformer substrate, BlockLDLQ, and the evaluation harness.
//!
//! Single-core CPU: the hot kernels are written so LLVM auto-vectorizes the inner
//! loops (unit-stride FMA chains, fixed-width accumulator blocks). Measured numbers
//! live in `EXPERIMENTS.md` §Perf.

use crate::util::rng::Rng;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// I.i.d. N(0, std^2) entries.
    pub fn gaussian(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.gauss_f32() * std).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.at(i, i) as f64).sum()
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Extract a column-block [c0, c1) as a new matrix.
    pub fn col_block(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut out = Matrix::zeros(self.rows, w);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Write a column-block starting at c0.
    pub fn set_col_block(&mut self, c0: usize, block: &Matrix) {
        assert_eq!(self.rows, block.rows);
        assert!(c0 + block.cols <= self.cols);
        for r in 0..self.rows {
            let dst = r * self.cols + c0;
            self.data[dst..dst + block.cols].copy_from_slice(block.row(r));
        }
    }

    /// C = A @ B (allocating).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(self.rows, b.cols);
        gemm(self, b, &mut c);
        c
    }

    /// y = A @ x (allocating).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.rows];
        gemv(self, x, &mut y);
        y
    }
}

/// C = A @ B, blocked over K with 4-wide row accumulation; C must be zeroed or holds
/// the accumulation base (C += A@B semantics on pre-filled C).
pub fn gemm(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "gemm inner dim");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    // i-k-j loop order: the j-inner loop is unit-stride over both B and C, which LLVM
    // vectorizes. Block over k to keep the C row hot in L1/L2.
    const KB: usize = 256;
    for i in 0..m {
        let crow = &mut c.data[i * n..(i + 1) * n];
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for kk in kb..kend {
                let aik = a.data[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
    }
}

/// y = A @ x. Four-row blocking so the loads of x amortize over four FMA chains.
pub fn gemv(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    let n = a.cols;
    let mut r = 0;
    while r + 4 <= a.rows {
        let r0 = &a.data[r * n..(r + 1) * n];
        let r1 = &a.data[(r + 1) * n..(r + 2) * n];
        let r2 = &a.data[(r + 2) * n..(r + 3) * n];
        let r3 = &a.data[(r + 3) * n..(r + 4) * n];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for i in 0..n {
            let xv = x[i];
            s0 += r0[i] * xv;
            s1 += r1[i] * xv;
            s2 += r2[i] * xv;
            s3 += r3[i] * xv;
        }
        y[r] = s0;
        y[r + 1] = s1;
        y[r + 2] = s2;
        y[r + 3] = s3;
        r += 4;
    }
    while r < a.rows {
        let row = &a.data[r * n..(r + 1) * n];
        y[r] = dot(row, x);
        r += 1;
    }
}

/// Dot product with 4 accumulators (breaks the FP dependence chain for vectorization).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (16, 16, 16), (33, 17, 9), (1, 7, 1)] {
            let a = Matrix::gaussian(m, k, 1.0, &mut rng);
            let b = Matrix::gaussian(k, n, 1.0, &mut rng);
            let c = a.matmul(&b);
            let expected = naive_matmul(&a, &b);
            for (x, y) in c.data.iter().zip(&expected.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(2);
        for (m, n) in [(5, 7), (64, 128), (17, 3), (4, 4)] {
            let a = Matrix::gaussian(m, n, 1.0, &mut rng);
            let x = rng.gauss_vec(n);
            let y = a.matvec(&x);
            let xm = Matrix::from_vec(n, 1, x.clone());
            let ym = a.matmul(&xm);
            for i in 0..m {
                assert!((y[i] - ym.data[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(37, 53, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(4);
        let a = Matrix::gaussian(8, 8, 1.0, &mut rng);
        let i = Matrix::identity(8);
        let ai = a.matmul(&i);
        for (x, y) in ai.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn col_block_roundtrip() {
        let mut rng = Rng::new(5);
        let a = Matrix::gaussian(6, 10, 1.0, &mut rng);
        let blk = a.col_block(3, 7);
        assert_eq!(blk.cols, 4);
        let mut b = Matrix::zeros(6, 10);
        b.set_col_block(3, &blk);
        for r in 0..6 {
            for c in 3..7 {
                assert_eq!(b.at(r, c), a.at(r, c));
            }
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(6);
        for n in [0usize, 1, 7, 8, 9, 31, 32, 100] {
            let a = rng.gauss_vec(n);
            let b = rng.gauss_vec(n);
            let expected: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!((dot(&a, &b) as f64 - expected).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn trace_and_norm() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.trace(), 5.0);
        assert!((m.fro_norm() - (30.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn gemm_accumulates() {
        // gemm on pre-filled C implements C += A@B.
        let a = Matrix::from_vec(1, 1, vec![2.0]);
        let b = Matrix::from_vec(1, 1, vec![3.0]);
        let mut c = Matrix::from_vec(1, 1, vec![10.0]);
        gemm(&a, &b, &mut c);
        assert_eq!(c.data[0], 16.0);
    }
}
