//! Dense row-major f32 matrices and the blocked GEMM/GEMV kernels that back the
//! transformer substrate, BlockLDLQ, and the evaluation harness.
//!
//! Single-core CPU: the hot kernels are written so LLVM auto-vectorizes the inner
//! loops (unit-stride FMA chains, fixed-width accumulator blocks). Measured numbers
//! live in `EXPERIMENTS.md` §Perf.

use crate::util::rng::Rng;
use crate::util::threadpool::SendPtr;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// I.i.d. N(0, std^2) entries.
    pub fn gaussian(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.gauss_f32() * std).collect();
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn transpose(&self) -> Matrix {
        let mut data = Vec::new();
        self.transpose_into(&mut data);
        Matrix { rows: self.cols, cols: self.rows, data }
    }

    /// Transpose into a reusable flat buffer (`cols × rows`, row-major): the
    /// allocation-free form the decode scratch arena uses, where `transpose()`
    /// would churn a fresh `Matrix` per call.
    pub fn transpose_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.rows * self.cols, 0.0);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
    }

    /// Resize in place to `rows × cols`, reusing the backing allocation
    /// (contents unspecified afterwards). Scratch-arena helper: steady-state
    /// serving reshapes batch buffers without reallocating once the high-water
    /// capacity is reached.
    pub fn reshape_scratch(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.at(i, i) as f64).sum()
    }

    /// self += alpha * other
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, alpha: f32) {
        for a in self.data.iter_mut() {
            *a *= alpha;
        }
    }

    /// Extract a column-block [c0, c1) as a new matrix.
    pub fn col_block(&self, c0: usize, c1: usize) -> Matrix {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut out = Matrix::zeros(self.rows, w);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Write a column-block starting at c0.
    pub fn set_col_block(&mut self, c0: usize, block: &Matrix) {
        assert_eq!(self.rows, block.rows);
        assert!(c0 + block.cols <= self.cols);
        for r in 0..self.rows {
            let dst = r * self.cols + c0;
            self.data[dst..dst + block.cols].copy_from_slice(block.row(r));
        }
    }

    /// C = A @ B (allocating).
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(self.rows, b.cols);
        gemm(self, b, &mut c);
        c
    }

    /// y = A @ x (allocating).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.rows];
        gemv(self, x, &mut y);
        y
    }
}

/// C = A @ B, blocked over K with 4-wide row accumulation; C must be zeroed or holds
/// the accumulation base (C += A@B semantics on pre-filled C).
pub fn gemm(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols, b.rows, "gemm inner dim");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    gemm_rows(a, b, 0, a.rows, &mut c.data);
}

/// Tile-parallel GEMM: output rows are striped across the pool in bands.
/// Each C row accumulates independently in the same order as [`gemm`], so the
/// result is bit-identical at any worker count.
pub fn gemm_pool(a: &Matrix, b: &Matrix, c: &mut Matrix, pool: &crate::util::threadpool::ExecPool) {
    assert_eq!(a.cols, b.rows, "gemm inner dim");
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    const BAND: usize = 16;
    if pool.width() <= 1 || a.rows <= BAND || b.cols == 0 {
        return gemm_rows(a, b, 0, a.rows, &mut c.data);
    }
    let n = b.cols;
    pool.run_chunks(&mut c.data, BAND * n, |band, crows| {
        let i0 = band * BAND;
        gemm_rows(a, b, i0, i0 + crows.len() / n, crows);
    });
}

/// GEMM over output rows [i0, i1); `crows` holds exactly those C rows.
fn gemm_rows(a: &Matrix, b: &Matrix, i0: usize, i1: usize, crows: &mut [f32]) {
    let (k, n) = (a.cols, b.cols);
    // i-k-j loop order: the j-inner loop is unit-stride over both B and C, which LLVM
    // vectorizes. Block over k to keep the C row hot in L1/L2.
    const KB: usize = 256;
    for i in i0..i1 {
        let crow = &mut crows[(i - i0) * n..(i - i0 + 1) * n];
        for kb in (0..k).step_by(KB) {
            let kend = (kb + KB).min(k);
            for kk in kb..kend {
                let aik = a.data[i * k + kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
    }
}

/// y = A @ x. Four-row blocking so the loads of x amortize over four FMA chains.
pub fn gemv(a: &Matrix, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    gemv_rows(a, 0, a.rows, x, y);
}

/// Tile-parallel GEMV: output rows striped across the pool in bands whose size
/// is a multiple of the 4-row blocking, so every row falls in the same
/// accumulation group as the sequential kernel — bit-identical at any width.
pub fn gemv_pool(a: &Matrix, x: &[f32], y: &mut [f32], pool: &crate::util::threadpool::ExecPool) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    const BAND: usize = 64;
    if pool.width() <= 1 || a.rows <= BAND {
        return gemv_rows(a, 0, a.rows, x, y);
    }
    pool.run_chunks(y, BAND, |band, yb| {
        let r0 = band * BAND;
        gemv_rows(a, r0, r0 + yb.len(), x, yb);
    });
}

/// Batched per-row GEMV (`y[b] = A @ x.row(b)` for every batch row) with a
/// **single** pool dispatch: jobs are (batch row × row band) pairs, so a B=8
/// round pays one submit/drain instead of eight. Each output row accumulates
/// exactly as in [`gemv`] — bit-identical at any worker count.
pub fn gemv_multi_pool(
    a: &Matrix,
    x: &Matrix,
    y: &mut Matrix,
    pool: &crate::util::threadpool::ExecPool,
) {
    assert_eq!(a.cols, x.cols);
    assert_eq!(y.rows, x.rows);
    assert_eq!(y.cols, a.rows);
    const BAND: usize = 64;
    if pool.width() <= 1 || x.rows * a.rows <= BAND {
        for r in 0..x.rows {
            gemv_rows(a, 0, a.rows, x.row(r), y.row_mut(r));
        }
        return;
    }
    let bands = a.rows.div_ceil(BAND);
    let stride = y.cols;
    let base = SendPtr(y.data.as_mut_ptr());
    pool.run(x.rows * bands, move |job| {
        let br = job / bands;
        let r0 = (job % bands) * BAND;
        let r1 = (r0 + BAND).min(a.rows);
        // SAFETY: job indices map 1:1 onto disjoint `y[br][r0..r1]` ranges,
        // each claimed exactly once; `y` outlives the dispatch.
        let dst =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(br * stride + r0), r1 - r0) };
        gemv_rows(a, r0, r1, x.row(br), dst);
    });
}

/// GEMV over rows [r0, r1) of A; `y` holds exactly those output rows. `r0`
/// must be a multiple of 4 so the blocking matches the full-matrix grouping.
fn gemv_rows(a: &Matrix, r0: usize, r1: usize, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(r0 % 4, 0, "band start must preserve the 4-row grouping");
    let n = a.cols;
    let mut r = r0;
    while r + 4 <= r1 {
        let w0 = &a.data[r * n..(r + 1) * n];
        let w1 = &a.data[(r + 1) * n..(r + 2) * n];
        let w2 = &a.data[(r + 2) * n..(r + 3) * n];
        let w3 = &a.data[(r + 3) * n..(r + 4) * n];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for i in 0..n {
            let xv = x[i];
            s0 += w0[i] * xv;
            s1 += w1[i] * xv;
            s2 += w2[i] * xv;
            s3 += w3[i] * xv;
        }
        y[r - r0] = s0;
        y[r - r0 + 1] = s1;
        y[r - r0 + 2] = s2;
        y[r - r0 + 3] = s3;
        r += 4;
    }
    while r < r1 {
        let row = &a.data[r * n..(r + 1) * n];
        y[r - r0] = dot(row, x);
        r += 1;
    }
}

/// Dot product with 4 accumulators (breaks the FP dependence chain for vectorization).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yv, &xv) in y.iter_mut().zip(x) {
        *yv += alpha * xv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f64;
                for k in 0..a.cols {
                    s += a.at(i, k) as f64 * b.at(k, j) as f64;
                }
                *c.at_mut(i, j) = s as f32;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for (m, k, n) in [(3, 4, 5), (16, 16, 16), (33, 17, 9), (1, 7, 1)] {
            let a = Matrix::gaussian(m, k, 1.0, &mut rng);
            let b = Matrix::gaussian(k, n, 1.0, &mut rng);
            let c = a.matmul(&b);
            let expected = naive_matmul(&a, &b);
            for (x, y) in c.data.iter().zip(&expected.data) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(2);
        for (m, n) in [(5, 7), (64, 128), (17, 3), (4, 4)] {
            let a = Matrix::gaussian(m, n, 1.0, &mut rng);
            let x = rng.gauss_vec(n);
            let y = a.matvec(&x);
            let xm = Matrix::from_vec(n, 1, x.clone());
            let ym = a.matmul(&xm);
            for i in 0..m {
                assert!((y[i] - ym.data[i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Matrix::gaussian(37, 53, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(4);
        let a = Matrix::gaussian(8, 8, 1.0, &mut rng);
        let i = Matrix::identity(8);
        let ai = a.matmul(&i);
        for (x, y) in ai.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn col_block_roundtrip() {
        let mut rng = Rng::new(5);
        let a = Matrix::gaussian(6, 10, 1.0, &mut rng);
        let blk = a.col_block(3, 7);
        assert_eq!(blk.cols, 4);
        let mut b = Matrix::zeros(6, 10);
        b.set_col_block(3, &blk);
        for r in 0..6 {
            for c in 3..7 {
                assert_eq!(b.at(r, c), a.at(r, c));
            }
        }
    }

    #[test]
    fn dot_matches_naive() {
        let mut rng = Rng::new(6);
        for n in [0usize, 1, 7, 8, 9, 31, 32, 100] {
            let a = rng.gauss_vec(n);
            let b = rng.gauss_vec(n);
            let expected: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!((dot(&a, &b) as f64 - expected).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn trace_and_norm() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.trace(), 5.0);
        assert!((m.fro_norm() - (30.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn pooled_kernels_bit_identical_to_sequential() {
        use crate::util::threadpool::ExecPool;
        let mut rng = Rng::new(9);
        // Sizes straddling the band widths, including non-multiples of 4.
        for (m, k, n) in [(7, 16, 5), (64, 32, 16), (130, 20, 33), (257, 8, 3)] {
            let a = Matrix::gaussian(m, k, 1.0, &mut rng);
            let b = Matrix::gaussian(k, n, 1.0, &mut rng);
            let x = rng.gauss_vec(k);
            let mut y_seq = vec![0.0f32; m];
            gemv(&a, &x, &mut y_seq);
            let mut c_seq = Matrix::zeros(m, n);
            gemm(&a, &b, &mut c_seq);
            for width in [1usize, 2, 4] {
                let pool = ExecPool::new(width);
                let mut y_par = vec![0.0f32; m];
                gemv_pool(&a, &x, &mut y_par, &pool);
                assert_eq!(y_seq, y_par, "gemv {m}x{k} width {width}");
                let mut c_par = Matrix::zeros(m, n);
                gemm_pool(&a, &b, &mut c_par, &pool);
                assert_eq!(c_seq.data, c_par.data, "gemm {m}x{k}x{n} width {width}");
            }
            // Batched single-dispatch GEMV: every row must equal plain gemv.
            let bsz = 3usize;
            let mut xs = Matrix::zeros(bsz, k);
            for r in 0..bsz {
                let xr = rng.gauss_vec(k);
                xs.row_mut(r).copy_from_slice(&xr);
            }
            for width in [1usize, 4] {
                let pool = ExecPool::new(width);
                let mut ym = Matrix::zeros(bsz, m);
                gemv_multi_pool(&a, &xs, &mut ym, &pool);
                for r in 0..bsz {
                    let mut yr = vec![0.0f32; m];
                    gemv(&a, xs.row(r), &mut yr);
                    assert_eq!(ym.row(r), &yr[..], "gemv_multi {m}x{k} row {r} width {width}");
                }
            }
        }
    }

    #[test]
    fn transpose_into_matches_transpose() {
        let mut rng = Rng::new(10);
        let a = Matrix::gaussian(37, 53, 1.0, &mut rng);
        let mut buf = vec![7.0f32; 3]; // stale contents + wrong size
        a.transpose_into(&mut buf);
        assert_eq!(buf, a.transpose().data);
    }

    #[test]
    fn gemm_accumulates() {
        // gemm on pre-filled C implements C += A@B.
        let a = Matrix::from_vec(1, 1, vec![2.0]);
        let b = Matrix::from_vec(1, 1, vec![3.0]);
        let mut c = Matrix::from_vec(1, 1, vec![10.0]);
        gemm(&a, &b, &mut c);
        assert_eq!(c.data[0], 16.0);
    }
}
