//! Summary statistics used by the experiment harnesses (Tables 1–2, Figure 3) and the
//! serving metrics (latency percentiles).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|&x| (x as f64 - m).powi(2)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f32]) -> f64 {
    variance(xs).sqrt()
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Pearson correlation coefficient; 0.0 if either side is constant.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    if n == 0 {
        return 0.0;
    }
    let ma = mean(a);
    let mb = mean(b);
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for i in 0..n {
        let da = a[i] as f64 - ma;
        let db = b[i] as f64 - mb;
        cov += da * db;
        va += da * da;
        vb += db * db;
    }
    if va == 0.0 || vb == 0.0 {
        return 0.0;
    }
    cov / (va.sqrt() * vb.sqrt())
}

/// Percentile (nearest-rank on a copy; p in [0,100]).
pub fn percentile(xs: &[f32], p: f64) -> f32 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Histogram with `bins` equal-width buckets over [lo, hi); out-of-range values clamp.
pub fn histogram(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut h = vec![0usize; bins];
    let w = (hi - lo) / bins as f32;
    for &x in xs {
        let mut i = ((x - lo) / w).floor() as isize;
        i = i.clamp(0, bins as isize - 1);
        h[i as usize] += 1;
    }
    h
}

/// Excess-free kurtosis (normal => 3).
pub fn kurtosis(xs: &[f32]) -> f64 {
    let m = mean(xs);
    let v = variance(xs);
    if v == 0.0 {
        return 0.0;
    }
    let m4 = xs
        .iter()
        .map(|&x| (x as f64 - m).powi(4))
        .sum::<f64>()
        / xs.len() as f64;
    m4 / (v * v)
}

/// Shannon distortion-rate bound for a unit Gaussian at `k` bits per sample:
/// `D(R) = 2^(-2k)`. Lower-bounds any k-bit quantizer's MSE (Table 1 "D_R" column).
pub fn gaussian_distortion_rate(k: f64) -> f64 {
    2f64.powf(-2.0 * k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_var() {
        let xs = [1.0f32, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-9);
        assert!((variance(&xs) - 1.25).abs() < 1e-9);
    }

    #[test]
    fn mse_basic() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0, 0.0], &[1.0, 3.0]) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_perfect() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [-2.0f32, -4.0, -6.0, -8.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_constant_is_zero() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn pearson_independent_near_zero() {
        let mut r = crate::util::rng::Rng::new(8);
        let a = r.gauss_vec(20_000);
        let b = r.gauss_vec(20_000);
        assert!(pearson(&a, &b).abs() < 0.03);
    }

    #[test]
    fn percentile_basic() {
        let xs = [5.0f32, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1f32, 0.2, 0.9, -1.0, 2.0];
        let h = histogram(&xs, 0.0, 1.0, 2);
        // -1.0 clamps to bin 0, 2.0 clamps to bin 1.
        assert_eq!(h, vec![3, 2]);
    }

    #[test]
    fn dr_bound() {
        assert!((gaussian_distortion_rate(2.0) - 0.0625).abs() < 1e-12);
        assert!((gaussian_distortion_rate(1.0) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn gauss_kurtosis_near_3() {
        let mut r = crate::util::rng::Rng::new(77);
        let xs = r.gauss_vec(100_000);
        assert!((kurtosis(&xs) - 3.0).abs() < 0.15);
    }
}
