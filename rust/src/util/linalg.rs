//! Dense linear algebra for the quantization pipeline: Cholesky, block-LDL
//! factorization (the feedback matrix of BlockLDLQ), triangular solves, and SPD
//! regularization of empirical Hessians.

use crate::util::matrix::Matrix;

/// Lower Cholesky factor L with H = L L^T. Returns None if H is not positive
/// definite (within a small tolerance).
pub fn cholesky(h: &Matrix) -> Option<Matrix> {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = h.at(i, j) as f64;
            for k in 0..j {
                s -= l.at(i, k) as f64 * l.at(j, k) as f64;
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                *l.at_mut(i, j) = s.sqrt() as f32;
            } else {
                *l.at_mut(i, j) = (s / l.at(j, j) as f64) as f32;
            }
        }
    }
    Some(l)
}

/// Block-LDL decomposition with block size `b` (must divide n):
/// `H = L D L^T` where `L` is unit-lower-*block*-triangular (identity diagonal
/// blocks) and `D` is block diagonal. Returns `(L, D)`.
///
/// This is the decomposition BlockLDLQ (paper Alg. 5) consumes: the feedback matrix
/// is `A = L - I`. Computed from the scalar Cholesky `H = C C^T` via
/// `L = C (blockdiag(C))^{-1}` and `D = blockdiag(C) blockdiag(C)^T`.
pub fn block_ldl(h: &Matrix, b: usize) -> Option<(Matrix, Matrix)> {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    assert!(b > 0 && n % b == 0, "block size {b} must divide {n}");
    let c = cholesky(h)?;
    // Invert each diagonal b x b block of C (lower triangular -> forward substitution).
    let nb = n / b;
    let mut l = Matrix::zeros(n, n);
    let mut d = Matrix::zeros(n, n);
    for bi in 0..nb {
        let o = bi * b;
        // D block = C_bb C_bb^T
        for i in 0..b {
            for j in 0..b {
                let mut s = 0.0f64;
                for k in 0..b {
                    s += c.at(o + i, o + k) as f64 * c.at(o + j, o + k) as f64;
                }
                *d.at_mut(o + i, o + j) = s as f32;
            }
        }
        // Invert C_bb (lower-triangular) into inv.
        let mut inv = Matrix::zeros(b, b);
        for col in 0..b {
            // Solve C_bb x = e_col
            let mut x = vec![0.0f64; b];
            for i in 0..b {
                let mut s = if i == col { 1.0 } else { 0.0 };
                for k in 0..i {
                    s -= c.at(o + i, o + k) as f64 * x[k];
                }
                x[i] = s / c.at(o + i, o + i) as f64;
            }
            for i in 0..b {
                *inv.at_mut(i, col) = x[i] as f32;
            }
        }
        // L block column: rows bi..nb, L_{r,bi} = C_{r,bi} @ inv
        for br in bi..nb
        {
            let ro = br * b;
            for i in 0..b {
                for j in 0..b {
                    let mut s = 0.0f64;
                    for k in 0..b {
                        s += c.at(ro + i, o + k) as f64 * inv.at(k, j) as f64;
                    }
                    *l.at_mut(ro + i, o + j) = s as f32;
                }
            }
        }
    }
    Some((l, d))
}

/// Symmetrize and add `lambda * mean(diag) * I` until Cholesky succeeds.
/// Returns the regularized matrix (standard GPTQ/QuIP# Hessian conditioning).
pub fn regularize_spd(h: &Matrix, base_lambda: f64) -> Matrix {
    assert_eq!(h.rows, h.cols);
    let n = h.rows;
    let mut m = h.clone();
    // Symmetrize.
    for i in 0..n {
        for j in 0..i {
            let v = 0.5 * (m.at(i, j) + m.at(j, i));
            *m.at_mut(i, j) = v;
            *m.at_mut(j, i) = v;
        }
    }
    let mean_diag = (m.trace() / n as f64).max(1e-12);
    let mut lambda = base_lambda;
    loop {
        let mut trial = m.clone();
        let add = (lambda * mean_diag) as f32;
        for i in 0..n {
            *trial.at_mut(i, i) += add;
        }
        if cholesky(&trial).is_some() {
            return trial;
        }
        lambda *= 10.0;
        assert!(lambda < 1e6, "could not regularize Hessian to SPD");
    }
}

/// Solve L x = rhs for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Matrix, rhs: &[f32]) -> Vec<f32> {
    let n = l.rows;
    assert_eq!(rhs.len(), n);
    let mut x = vec![0.0f64; n];
    for i in 0..n {
        let mut s = rhs[i] as f64;
        for k in 0..i {
            s -= l.at(i, k) as f64 * x[k];
        }
        x[i] = s / l.at(i, i) as f64;
    }
    x.into_iter().map(|v| v as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let a = Matrix::gaussian(n, n, 1.0, &mut rng);
        let mut h = a.matmul(&a.transpose());
        for i in 0..n {
            *h.at_mut(i, i) += n as f32 * 0.1;
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let h = random_spd(16, 1);
        let l = cholesky(&h).unwrap();
        let rec = l.matmul(&l.transpose());
        for (a, b) in rec.data.iter().zip(&h.data) {
            assert!((a - b).abs() < 1e-2 * h.fro_norm() as f32, "{a} vs {b}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&m).is_none());
    }

    #[test]
    fn block_ldl_reconstructs() {
        for (n, b) in [(8, 2), (16, 4), (12, 3), (16, 16), (8, 1)] {
            let h = random_spd(n, 7 + n as u64);
            let (l, d) = block_ldl(&h, b).unwrap();
            let rec = l.matmul(&d).matmul(&l.transpose());
            let tol = 1e-2 * h.fro_norm() as f32;
            for (a, bb) in rec.data.iter().zip(&h.data) {
                assert!((a - bb).abs() < tol, "n={n} b={b}: {a} vs {bb}");
            }
        }
    }

    #[test]
    fn block_ldl_unit_diagonal_blocks() {
        let h = random_spd(12, 3);
        let (l, _) = block_ldl(&h, 4).unwrap();
        for bi in 0..3 {
            for i in 0..4 {
                for j in 0..4 {
                    let v = l.at(bi * 4 + i, bi * 4 + j);
                    let expect = if i == j { 1.0 } else { 0.0 };
                    assert!((v - expect).abs() < 1e-4, "block {bi} ({i},{j}) = {v}");
                }
            }
        }
    }

    #[test]
    fn block_ldl_strictly_lower() {
        let h = random_spd(12, 4);
        let (l, d) = block_ldl(&h, 4).unwrap();
        // Everything above the block diagonal must be zero in L; D block-diagonal.
        for i in 0..12 {
            for j in 0..12 {
                if j / 4 > i / 4 {
                    assert_eq!(l.at(i, j), 0.0);
                    assert_eq!(d.at(i, j), 0.0);
                }
                if j / 4 < i / 4 {
                    assert_eq!(d.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn regularize_makes_spd() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        let r = regularize_spd(&m, 0.01);
        assert!(cholesky(&r).is_some());
    }

    #[test]
    fn solve_lower_works() {
        let h = random_spd(8, 5);
        let l = cholesky(&h).unwrap();
        let mut rng = Rng::new(6);
        let x_true = rng.gauss_vec(8);
        let rhs = l.matvec(&x_true);
        let x = solve_lower(&l, &rhs);
        for (a, b) in x.iter().zip(&x_true) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}
