//! Tiny property-based testing harness (proptest substitute for the offline env).
//!
//! `prop_check` runs a predicate over `cases` randomly generated inputs from a seeded
//! generator; on failure it retries with progressively simpler inputs by re-running
//! the generator with a shrinking "size" hint, then panics with the seed and case
//! index so the failure is reproducible.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this offline image;
//! //  the same example executes as `util::prop::tests::passing_property`.)
//! use qtip::util::prop::prop_check;
//! prop_check("addition commutes", 100, |g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::rng::Rng;

/// Input generator handed to each property case. `size` grows with the case index so
/// early cases are small (cheap shrinking-by-construction).
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as usize) as i64
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.uniform_f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn gauss_vec(&mut self, n: usize) -> Vec<f32> {
        self.rng.gauss_vec(n)
    }

    /// A "sized" length: grows with the case index, at least 1, at most `cap`.
    pub fn len(&mut self, cap: usize) -> usize {
        let upper = (self.size + 1).min(cap).max(1);
        1 + self.rng.below(upper)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Seed is derived from the property name so distinct properties explore distinct
/// streams but every run of the same property is identical. Override with
/// `QTIP_PROP_SEED` for exploration.
fn seed_for(name: &str) -> u64 {
    if let Ok(v) = std::env::var("QTIP_PROP_SEED") {
        if let Ok(n) = v.parse::<u64>() {
            return n;
        }
    }
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run `body` for `cases` generated inputs. Panics (with reproduction info) on the
/// first failing case.
pub fn prop_check<F>(name: &str, cases: usize, body: F)
where
    F: Fn(&mut Gen),
{
    let seed = seed_for(name);
    for case in 0..cases {
        let mut g = Gen { rng: Rng::new(seed.wrapping_add(case as u64)), size: case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (seed {seed}, rerun with QTIP_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        prop_check("sort is idempotent", 50, |g| {
            let n = g.len(64);
            let mut v = g.gauss_vec(n);
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let once = v.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(v, once);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        prop_check("always fails", 10, |_| {
            panic!("boom");
        });
    }

    #[test]
    fn gen_ranges() {
        prop_check("gen ranges respected", 100, |g| {
            let u = g.usize_in(3, 9);
            assert!((3..=9).contains(&u));
            let i = g.i64_in(-5, 5);
            assert!((-5..=5).contains(&i));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
            let l = g.len(16);
            assert!((1..=16).contains(&l));
        });
    }

    #[test]
    fn deterministic_given_name() {
        use std::sync::Mutex;
        let first = Mutex::new(Vec::new());
        prop_check("determinism probe", 5, |g| {
            first.lock().unwrap().push(g.rng.next_u64());
        });
        let snapshot = first.lock().unwrap().clone();
        let second = Mutex::new(Vec::new());
        prop_check("determinism probe", 5, |g| {
            second.lock().unwrap().push(g.rng.next_u64());
        });
        assert_eq!(snapshot, *second.lock().unwrap());
    }
}
