//! Cooperative process shutdown: a SIGINT/SIGTERM-driven flag that serving
//! loops poll, so Ctrl-C on `qtip serve --tcp` closes the frontend, drains
//! in-flight requests, and reports `ServerStats` instead of killing the
//! process mid-round.
//!
//! Offline environment: `ctrlc`/`signal-hook` are unavailable, so the handler
//! is registered through libc's `signal` symbol directly (unix only; elsewhere
//! `install` degrades to a flag that can only be tripped programmatically).
//!
//! The flag deliberately comes from [`crate::util::sync::real`] — the
//! always-`std` corner of the sync shim — rather than the loom-switchable
//! types: it must live in a `static` (loom atomics are runtime-constructed)
//! and is written from an async-signal context that no loom model can
//! schedule. `SeqCst` on a single flag is trivially sound; the loom lane
//! covers the protocols that are not (`ExecPool`, `KvArena`).

use crate::util::sync::real::{AtomicBool, Ordering};

static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Handle to the process-wide shutdown flag.
#[derive(Clone, Copy, Debug)]
pub struct ShutdownFlag;

impl ShutdownFlag {
    /// Has a shutdown been requested (signal received or [`Self::request`]ed)?
    pub fn is_set(&self) -> bool {
        SHUTDOWN.load(Ordering::SeqCst)
    }

    /// Trip the flag programmatically (tests; non-signal shutdown paths).
    pub fn request(&self) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
}

#[cfg(unix)]
mod imp {
    use super::SHUTDOWN;
    use crate::util::sync::real::Ordering;

    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;

    extern "C" fn on_signal(_sig: i32) {
        // Async-signal-safe: a single atomic store, nothing else.
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install_handlers() {
        // SAFETY: `signal` is the libc symbol with its documented C ABI;
        // `on_signal` is `extern "C"`, never unwinds, and performs only an
        // async-signal-safe atomic store. Re-registration (idempotent calls)
        // is permitted by POSIX.
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install_handlers() {}
}

/// Install SIGINT/SIGTERM handlers (idempotent) and return the flag handle.
pub fn install() -> ShutdownFlag {
    imp::install_handlers();
    ShutdownFlag
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_trips_on_request() {
        let flag = install();
        flag.request();
        assert!(flag.is_set());
    }

    #[cfg(unix)]
    #[test]
    fn flag_trips_on_real_signal() {
        // Deliver a real SIGINT to this process: with the handler installed the
        // flag must trip (without it, default disposition would kill the test
        // binary — which is exactly the regression this guards against).
        extern "C" {
            fn raise(sig: i32) -> i32;
        }
        let flag = install();
        // SAFETY: `raise` is the libc symbol; delivering SIGINT to ourselves
        // is safe because `install` just registered a handler for it.
        unsafe {
            raise(imp::SIGINT);
        }
        assert!(flag.is_set(), "SIGINT handler did not trip the shutdown flag");
    }
}
