//! Synchronization shim: the single import point for every concurrency
//! primitive the crate uses on its parallel hot paths.
//!
//! Under a normal build this module is a zero-cost re-export of `std::sync`.
//! Under `RUSTFLAGS="--cfg loom"` (the CI loom lane, `tests/loom.rs`) the same
//! names resolve to [loom](https://docs.rs/loom)'s instrumented doubles, so
//! loom can exhaustively model-check every interleaving of the `ExecPool`
//! dispatch/steal/park protocol and the `KvArena` lease/release protocol
//! instead of relying on whatever schedule the test machine happens to
//! produce. Modules that participate in the modeled protocols
//! (`util::threadpool`, `model::kv` call sites, the loom tests) must import
//! `Mutex`/`Condvar`/`Arc`/`atomic::*` and thread spawning from here, never
//! from `std::sync` directly — otherwise loom cannot see (or permute) the
//! operation.
//!
//! ## What is deliberately *not* modeled
//!
//! * [`real`] re-exports the `std` atomics unconditionally. It exists for the
//!   one place loom types cannot go: `util::shutdown`'s process-wide signal
//!   flag, which must be a `static` (loom atomics are runtime-constructed and
//!   only usable inside `loom::model`) and is written from an async-signal
//!   context loom has no concept of. Routing it through `real` keeps the
//!   exclusion explicit and greppable.
//! * `OnceLock` statics (e.g. `ExecPool::shared_sequential`) stay on `std`;
//!   the loom tests construct their pools explicitly inside the model.

#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{
        AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };
}

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard};

#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{
        AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering,
    };
}

/// Always-`std` atomics for state that exists outside any loom model: the
/// async-signal-safe shutdown flag (`util::shutdown`). Everything else should
/// use [`atomic`] so the loom lane can check it.
pub mod real {
    pub use std::sync::atomic::{AtomicBool, Ordering};
}

/// Thread handle type for pool workers (std or loom, matching the build).
#[cfg(not(loom))]
pub type JoinHandle = std::thread::JoinHandle<()>;
#[cfg(loom)]
pub type JoinHandle = loom::thread::JoinHandle<()>;

/// Spawn a named worker thread. Under loom the name is dropped (loom's
/// scheduler identifies threads itself) but the spawn is modeled.
#[cfg(not(loom))]
pub fn spawn_worker<F>(name: String, f: F) -> JoinHandle
where
    F: FnOnce() + Send + 'static,
{
    std::thread::Builder::new().name(name).spawn(f).expect("spawn pool worker")
}

#[cfg(loom)]
pub fn spawn_worker<F>(_name: String, f: F) -> JoinHandle
where
    F: FnOnce() + Send + 'static,
{
    loom::thread::spawn(f)
}

#[cfg(test)]
mod tests {
    use super::atomic::{AtomicUsize, Ordering};
    use super::{Arc, Condvar, Mutex};

    #[test]
    fn shim_primitives_behave_like_std() {
        // Not a concurrency test — just pins that the re-exported surface is
        // the one the pool relies on (lock/wait/notify/fetch_add names).
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let counter = AtomicUsize::new(0);
        {
            let mut ready = pair.0.lock().unwrap();
            *ready = true;
            counter.fetch_add(2, Ordering::AcqRel);
            pair.1.notify_all();
        }
        assert!(*pair.0.lock().unwrap());
        assert_eq!(counter.load(Ordering::Acquire), 2);
    }

    #[test]
    fn worker_spawn_runs_and_joins() {
        let hits = Arc::new(AtomicUsize::new(0));
        let h2 = Arc::clone(&hits);
        let h = super::spawn_worker("qtip-sync-smoke".to_string(), move || {
            h2.fetch_add(1, Ordering::Release);
        });
        h.join().expect("worker must not panic");
        assert_eq!(hits.load(Ordering::Acquire), 1);
    }
}
