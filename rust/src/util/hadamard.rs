//! Fast Walsh–Hadamard transform and the Random Hadamard Transform (RHT) used for
//! incoherence processing (paper §2.1).
//!
//! `hadamard_inplace` applies the orthonormal H_n (scaled by 1/sqrt(n)) for
//! n = m * 2^a where m ∈ {1, 12, 20}: powers of two use the butterfly FWHT, and the
//! 12/20 factors use hard-coded base Hadamard matrices (Paley constructions — the
//! paper sources these from Sloane's tables) combined by the Kronecker identity
//! H_{m·2^a} = H_m ⊗ H_{2^a}.

/// Is n a supported Hadamard size?
pub fn supported(n: usize) -> bool {
    base_factor(n).is_some()
}

/// Decompose n = m * 2^a with m in {1, 12, 20}; returns m.
fn base_factor(n: usize) -> Option<usize> {
    if n == 0 {
        return None;
    }
    let mut v = n;
    while v % 2 == 0 {
        v /= 2;
    }
    match v {
        1 | 3 | 5 => {
            // m=3 -> needs H12 = 3*4 (so n must have >= 2 factors of two), m=5 -> H20.
            let m = match v {
                1 => 1,
                3 => 12,
                5 => 20,
                _ => unreachable!(),
            };
            if n % m == 0 {
                Some(m)
            } else {
                None
            }
        }
        _ => None,
    }
}

/// First rows of a 12x12 Hadamard matrix (Paley I from quadratic residues mod 11).
/// Row 0 is all ones; row i>0 is built by cycling the residue signature.
fn h12() -> Vec<f32> {
    // Quadratic residues mod 11: {1,3,4,5,9}.
    let qr = [1usize, 3, 4, 5, 9];
    let mut m = vec![1.0f32; 12 * 12];
    // Paley: B is 11x11 circulant with b_ij = chi(j - i); border with +1 row/col,
    // diagonal of B set to -1.
    for i in 0..11 {
        for j in 0..11 {
            let v = if i == j {
                -1.0
            } else {
                let d = (11 + j as isize - i as isize) as usize % 11;
                if qr.contains(&d) {
                    1.0
                } else {
                    -1.0
                }
            };
            m[(i + 1) * 12 + (j + 1)] = v;
        }
    }
    m
}

/// 20x20 Hadamard matrix via Paley I over GF(19).
fn h20() -> Vec<f32> {
    // Quadratic residues mod 19.
    let mut qr = Vec::new();
    for x in 1..19usize {
        qr.push(x * x % 19);
    }
    qr.sort();
    qr.dedup();
    let mut m = vec![1.0f32; 20 * 20];
    for i in 0..19 {
        for j in 0..19 {
            let v = if i == j {
                -1.0
            } else {
                let d = (19 + j as isize - i as isize) as usize % 19;
                if qr.contains(&d) {
                    1.0
                } else {
                    -1.0
                }
            };
            m[(i + 1) * 20 + (j + 1)] = v;
        }
    }
    m
}

/// In-place orthonormal Hadamard transform of x (length must be supported).
pub fn hadamard_inplace(x: &mut [f32]) {
    let n = x.len();
    let m = base_factor(n).unwrap_or_else(|| panic!("unsupported Hadamard size {n}"));
    let p2 = n / m; // power-of-two part
    // First: FWHT on each contiguous stride-1 segment of length p2 (H_m (x) H_p2 layout:
    // index = i_m * p2 + i_p2).
    for seg in x.chunks_mut(p2) {
        fwht_pow2(seg);
    }
    if m > 1 {
        let base = if m == 12 { h12() } else { h20() };
        let scale = 1.0 / (m as f32).sqrt();
        let mut tmp = vec![0.0f32; m];
        for col in 0..p2 {
            for (i, t) in tmp.iter_mut().enumerate() {
                let mut s = 0.0f32;
                for j in 0..m {
                    s += base[i * m + j] * x[j * p2 + col];
                }
                *t = s * scale;
            }
            for i in 0..m {
                x[i * p2 + col] = tmp[i];
            }
        }
    }
}

/// Orthonormal FWHT (power-of-two length), butterfly, O(n log n).
fn fwht_pow2(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two() || n == 1, "length {n} not a power of two");
    let mut h = 1;
    while h < n {
        for i in (0..n).step_by(h * 2) {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Apply the signed orthonormal Hadamard: y = H · diag(sign) · x, in place.
/// `sign` entries must be ±1. This is the RHT building block V_n S_n.
pub fn rht_forward(x: &mut [f32], sign: &[f32]) {
    assert_eq!(x.len(), sign.len());
    for (v, &s) in x.iter_mut().zip(sign) {
        *v *= s;
    }
    hadamard_inplace(x);
}

/// Inverse of [`rht_forward`]: x = diag(sign) · H^T · y = diag(sign) · H · y
/// (H is symmetric orthonormal for the FWHT part; for H12/H20 we use H^T = H^-1
/// via applying the transpose explicitly).
pub fn rht_inverse(x: &mut [f32], sign: &[f32]) {
    assert_eq!(x.len(), sign.len());
    hadamard_inverse_inplace(x);
    for (v, &s) in x.iter_mut().zip(sign) {
        *v *= s;
    }
}

/// Inverse orthonormal Hadamard transform. For the pure power-of-two FWHT, H is
/// symmetric so inverse == forward; for the H12/H20 factors, apply the transpose.
pub fn hadamard_inverse_inplace(x: &mut [f32]) {
    let n = x.len();
    let m = base_factor(n).unwrap_or_else(|| panic!("unsupported Hadamard size {n}"));
    let p2 = n / m;
    if m > 1 {
        let base = if m == 12 { h12() } else { h20() };
        let scale = 1.0 / (m as f32).sqrt();
        let mut tmp = vec![0.0f32; m];
        for col in 0..p2 {
            for (i, t) in tmp.iter_mut().enumerate() {
                let mut s = 0.0f32;
                for j in 0..m {
                    // transpose: base[j][i]
                    s += base[j * m + i] * x[j * p2 + col];
                }
                *t = s * scale;
            }
            for i in 0..m {
                x[i * p2 + col] = tmp[i];
            }
        }
    }
    for seg in x.chunks_mut(p2) {
        fwht_pow2(seg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn check_orthonormal(n: usize) {
        // ||Hx|| == ||x|| and H(H^-1 x) == x.
        let mut rng = Rng::new(n as u64);
        let x0 = rng.gauss_vec(n);
        let mut x = x0.clone();
        hadamard_inplace(&mut x);
        let n0: f64 = x0.iter().map(|&v| (v as f64).powi(2)).sum();
        let n1: f64 = x.iter().map(|&v| (v as f64).powi(2)).sum();
        assert!((n0 - n1).abs() / n0.max(1e-9) < 1e-4, "norm not preserved at n={n}");
        hadamard_inverse_inplace(&mut x);
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-4, "roundtrip failed at n={n}");
        }
    }

    #[test]
    fn pow2_sizes() {
        for n in [1usize, 2, 4, 8, 64, 256, 1024] {
            check_orthonormal(n);
        }
    }

    #[test]
    fn h12_h20_sizes() {
        for n in [12usize, 24, 48, 20, 40, 80, 96] {
            check_orthonormal(n);
        }
    }

    #[test]
    fn unsupported_sizes() {
        assert!(!supported(0));
        assert!(!supported(7));
        assert!(!supported(36)); // 9 * 4 — odd part 9 unsupported
        assert!(supported(12));
        assert!(supported(20));
        assert!(supported(4096));
    }

    #[test]
    fn fwht_known_values() {
        // H_2 [1, 0] = [1/sqrt2, 1/sqrt2]
        let mut x = vec![1.0, 0.0];
        hadamard_inplace(&mut x);
        let s = 1.0 / 2f32.sqrt();
        assert!((x[0] - s).abs() < 1e-6 && (x[1] - s).abs() < 1e-6);
    }

    #[test]
    fn h12_rows_orthogonal() {
        let m = h12();
        for i in 0..12 {
            for j in 0..12 {
                let dot: f32 = (0..12).map(|k| m[i * 12 + k] * m[j * 12 + k]).sum();
                let expect = if i == j { 12.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-5, "rows {i},{j}: {dot}");
            }
        }
    }

    #[test]
    fn h20_rows_orthogonal() {
        let m = h20();
        for i in 0..20 {
            for j in 0..20 {
                let dot: f32 = (0..20).map(|k| m[i * 20 + k] * m[j * 20 + k]).sum();
                let expect = if i == j { 20.0 } else { 0.0 };
                assert!((dot - expect).abs() < 1e-5, "rows {i},{j}: {dot}");
            }
        }
    }

    #[test]
    fn rht_roundtrip() {
        let mut rng = Rng::new(9);
        let n = 128;
        let sign: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
        let x0 = rng.gauss_vec(n);
        let mut x = x0.clone();
        rht_forward(&mut x, &sign);
        rht_inverse(&mut x, &sign);
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn rht_flattens_spike() {
        // Incoherence in action: a one-hot vector spreads to magnitude 1/sqrt(n).
        let n = 256;
        let mut rng = Rng::new(10);
        let sign: Vec<f32> = (0..n).map(|_| rng.sign()).collect();
        let mut x = vec![0.0f32; n];
        x[17] = 1.0;
        rht_forward(&mut x, &sign);
        let maxabs = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!((maxabs - 1.0 / (n as f32).sqrt()).abs() < 1e-6);
    }
}
