//! IEEE 754 binary16 (half precision) conversion.
//!
//! The 3INST compute code (paper Alg. 2) builds pseudorandom Gaussians by XOR-ing
//! random bits into the sign/exponent-low/mantissa fields of a magic FP16 constant,
//! so we need exact binary16 semantics. The offline environment has no `half` crate;
//! this is a from-scratch implementation, round-to-nearest-even on the f32->f16 path.
//!
//! Layout: bit 15 = sign, bits 14..10 = exponent (bias 15), bits 9..0 = mantissa.

/// Convert binary16 bits to f32 (exact; covers subnormals, infinities, NaN).
#[inline]
pub fn f16_to_f32(bits: u16) -> f32 {
    let sign = (bits >> 15) as u32;
    let exp = ((bits >> 10) & 0x1F) as u32;
    let man = (bits & 0x3FF) as u32;
    let f32_bits = if exp == 0 {
        if man == 0 {
            sign << 31 // signed zero
        } else {
            // Subnormal: value = man * 2^-24. If the highest set bit of man is bit j,
            // the normalized value is 1.xxx * 2^(j-24), i.e. f32 exponent j + 103.
            let j = 31 - man.leading_zeros();
            let m = (man << (10 - j)) & 0x3FF; // normalized mantissa, implicit bit dropped
            let f32_exp = j + 103;
            (sign << 31) | (f32_exp << 23) | (m << 13)
        }
    } else if exp == 0x1F {
        // Inf / NaN
        (sign << 31) | (0xFF << 23) | (man << 13)
    } else {
        (sign << 31) | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(f32_bits)
}

/// Convert f32 to binary16 bits, round-to-nearest-even.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x7F_FFFF;

    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if man != 0 { 0x200 } else { 0 };
    }
    // Unbiased exponent.
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if e >= -14 {
        // Normal half. 13 bits dropped from mantissa; round to nearest even.
        let half_exp = ((e + 15) as u16) << 10;
        let half_man = (man >> 13) as u16;
        let rest = man & 0x1FFF;
        let mut h = sign | half_exp | half_man;
        if rest > 0x1000 || (rest == 0x1000 && (half_man & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent: correct (rounds up to inf)
        }
        return h;
    }
    if e >= -25 {
        // Subnormal half: value = m * 2^(e) with implicit bit made explicit.
        let m = man | 0x80_0000; // 24-bit significand
        let shift = (-14 - e) as u32 + 13; // bits to drop
        let half_man = (m >> shift) as u16;
        let rest_mask = (1u32 << shift) - 1;
        let rest = m & rest_mask;
        let halfway = 1u32 << (shift - 1);
        let mut h = sign | half_man;
        if rest > halfway || (rest == halfway && (half_man & 1) == 1) {
            h = h.wrapping_add(1);
        }
        return h;
    }
    sign // underflow to signed zero
}

/// Round an f32 through binary16 precision (quantize-dequantize).
#[inline]
pub fn round_f16(x: f32) -> f32 {
    f16_to_f32(f32_to_f16(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(f16_to_f32(0x3C00), 1.0);
        assert_eq!(f16_to_f32(0xBC00), -1.0);
        assert_eq!(f16_to_f32(0x4000), 2.0);
        assert_eq!(f16_to_f32(0x3800), 0.5);
        assert_eq!(f16_to_f32(0x0000), 0.0);
        assert_eq!(f16_to_f32(0x7C00), f32::INFINITY);
        assert_eq!(f16_to_f32(0xFC00), f32::NEG_INFINITY);
        assert!(f16_to_f32(0x7E00).is_nan());
        // Largest normal half: 65504.
        assert_eq!(f16_to_f32(0x7BFF), 65504.0);
        // Smallest positive subnormal: 2^-24.
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        // Smallest positive normal: 2^-14.
        assert_eq!(f16_to_f32(0x0400), 2.0f32.powi(-14));
    }

    #[test]
    fn magic_0922() {
        // The 3INST magic constant. f16(0.922) = 0x3B60 (nearest-even).
        let bits = f32_to_f16(0.922);
        assert_eq!(bits, 0x3B60, "got {bits:#06x}");
        let back = f16_to_f32(bits);
        assert!((back - 0.922).abs() < 5e-4, "back={back}");
    }

    #[test]
    fn roundtrip_all_f16_bit_patterns() {
        // Every non-NaN half must roundtrip exactly through f32.
        for bits in 0u16..=0xFFFF {
            let f = f16_to_f32(bits);
            if f.is_nan() {
                continue;
            }
            let back = f32_to_f16(f);
            assert_eq!(back, bits, "bits={bits:#06x} f={f}");
        }
    }

    #[test]
    fn rounding_nearest_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and 1.0+2^-10 -> rounds to even (1.0).
        let x = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(x), 0x3C00);
        // 1.0 + 3*2^-11 is halfway between odd and even mantissa -> rounds up to even.
        let y = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(y), 0x3C02);
    }

    #[test]
    fn overflow_and_underflow() {
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        assert_eq!(f32_to_f16(1e-9), 0);
        assert_eq!(f32_to_f16(-1e-9), 0x8000);
        assert_eq!(f16_to_f32(f32_to_f16(65504.0)), 65504.0);
        // 65520 is halfway to the next (unrepresentable) value -> inf.
        assert_eq!(f16_to_f32(f32_to_f16(65520.0)), f32::INFINITY);
    }

    #[test]
    fn monotone_on_positive_grid() {
        let mut prev = -1.0f32;
        for bits in 0u16..0x7C00 {
            let f = f16_to_f32(bits);
            assert!(f > prev, "bits={bits:#06x}");
            prev = f;
        }
    }
}
