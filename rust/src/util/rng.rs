//! Deterministic pseudo-random number generation.
//!
//! The offline environment has no `rand` crate, so this module provides the PRNG
//! substrate used throughout the repo: a SplitMix64 seeder, a xoshiro256++ generator,
//! and Gaussian sampling via the Box–Muller transform (with a cached second sample).
//!
//! All experiment entry points take explicit seeds so every table/figure in
//! `EXPERIMENTS.md` is reproducible bit-for-bit.

/// SplitMix64 step; used for seeding and as a cheap stateless hash.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless 64-bit mix of a value (SplitMix64 finalizer). Handy for building
/// deterministic per-index randomness (e.g. the pure-lookup RPTC codebook).
#[inline]
pub fn mix64(x: u64) -> u64 {
    let mut s = x;
    splitmix64(&mut s)
}

/// xoshiro256++ PRNG. Fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller sample.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent stream (for per-worker / per-sequence parallelism).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ mix64(stream.wrapping_mul(0xA24BAED4963EE407)))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our purposes).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    #[inline]
    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// Vector of standard normals.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.gauss_f32()).collect()
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), order randomized.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(1234);
        let n = 200_000;
        let (mut s1, mut s2, mut s4) = (0.0f64, 0.0f64, 0.0f64);
        for _ in 0..n {
            let z = r.gauss();
            s1 += z;
            s2 += z * z;
            s4 += z * z * z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64;
        let kurt = s4 / n as f64 / (var * var);
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!((kurt - 3.0).abs() < 0.1, "kurtosis={kurt}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(9);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(6);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut t = s.clone();
        t.sort();
        t.dedup();
        assert_eq!(t.len(), 30);
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(11);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn mix64_stable_golden() {
        // Frozen golden values: the pure-lookup codebook depends on these.
        assert_eq!(mix64(0), 0xE220A8397B1DCDAF);
        assert_eq!(mix64(1), 0x910A2DEC89025CC1);
    }
}
