//! Scoped data-parallel helpers (rayon substitute).
//!
//! The quantization pipeline fans per-layer and per-sequence jobs across worker
//! threads via `parallel_for_chunks`. On the single-core CI machine this degrades
//! gracefully to sequential execution; the coordinator logic is identical either way.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of workers to use: `QTIP_THREADS` env var, else available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("QTIP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(index)` for every index in 0..n, work-stealing over `workers` threads.
/// `f` must be Sync; per-index outputs should be written through interior
/// mutability or collected via [`parallel_map`].
pub fn parallel_for<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let counter = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = counter.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

/// Parallel map preserving order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let slots: Vec<std::sync::Mutex<&mut T>> =
            out.iter_mut().map(std::sync::Mutex::new).collect();
        parallel_for(n, workers, |i| {
            **slots[i].lock().unwrap() = f(i);
        });
    }
    out
}

/// Process mutable chunks of a slice in parallel: `f(chunk_index, chunk)`.
pub fn parallel_for_chunks<T, F>(data: &mut [T], chunk: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk > 0);
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk).enumerate().collect();
    let n = chunks.len();
    let slots: Vec<std::sync::Mutex<(usize, &mut [T])>> =
        chunks.into_iter().map(std::sync::Mutex::new).collect();
    parallel_for(n, workers, |i| {
        let mut guard = slots[i].lock().unwrap();
        let (idx, ref mut s) = *guard;
        f(idx, s);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_covers_all_indices() {
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(100, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let ran = AtomicUsize::new(0);
        parallel_for(1, 4, |_| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(50, 4, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn parallel_chunks_sum() {
        let mut data = vec![1u64; 1000];
        parallel_for_chunks(&mut data, 64, 4, |idx, chunk| {
            for v in chunk.iter_mut() {
                *v += idx as u64;
            }
        });
        let total: u64 = data.iter().sum();
        // chunk i has min(64, rem) elements incremented by i
        let mut expect = 1000u64;
        let mut off = 0usize;
        let mut idx = 0u64;
        while off < 1000 {
            let len = 64.min(1000 - off) as u64;
            expect += idx * len;
            off += 64;
            idx += 1;
        }
        assert_eq!(total, expect);
    }

    #[test]
    fn workers_env_default() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn parallel_sum_atomic() {
        let sum = AtomicU64::new(0);
        parallel_for(1000, 8, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 999 * 1000 / 2);
    }
}
