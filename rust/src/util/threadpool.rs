//! Persistent work-stealing execution pool (rayon substitute).
//!
//! The seed shipped scoped spawn-per-call helpers: every `parallel_for` paid a
//! full `thread::scope` spawn/join round-trip, which priced parallelism out of
//! the serving hot path (a decode matvec runs in microseconds). [`ExecPool`]
//! replaces them with long-lived workers parked on a condvar: submitting a job
//! is one mutex lock + `notify_all`, cheap enough to invoke per matvec. The
//! same pool is shared by the quantization pipeline (per-layer jobs), the
//! artifact load path (per-layer blob reassembly), and the tile-parallel
//! decode kernels (per-tile-row bands), so `--threads` governs every parallel
//! path in the binary.
//!
//! Scheduling is work-stealing over an atomic index counter: workers (and the
//! submitting thread, which participates) claim indices with `fetch_add`, so
//! uneven per-index cost load-balances automatically. On the single-core CI
//! machine a width-1 pool spawns no threads and degrades to plain sequential
//! execution; all callers are written so results are identical either way.
//!
//! ## Soundness tooling
//!
//! Every primitive below comes from [`crate::util::sync`], the
//! `cfg(loom)`-switchable shim, so the dispatch protocol — the `busy`
//! swap/store re-entrancy gate, the `next` `fetch_add` work-stealing counter,
//! the `remaining` AcqRel countdown, condvar park/wake, panic propagation and
//! nested-use inline degradation — is exhaustively model-checked by the loom
//! lane (`tests/loom.rs`, `RUSTFLAGS="--cfg loom" cargo test --test loom`).
//! The raw-pointer surface (`Job::data`, [`SendPtr`], [`ExecPool::map`]) is
//! additionally exercised under Miri in CI.

use crate::util::fault::{FaultPlan, POOL_PANIC};
use crate::util::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::util::sync::{Arc, Condvar, Mutex};
use std::mem::MaybeUninit;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Number of workers to use: `QTIP_THREADS` env var, else available parallelism.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("QTIP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a requested worker count: an explicit `n > 0` (e.g. a `--threads`
/// CLI flag) wins; `0` means auto (`QTIP_THREADS` env var, else available
/// parallelism). This is the single precedence rule for the whole binary.
pub fn resolve_workers(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        default_workers()
    }
}

/// A snapshot of one submitted job, shared between the submitter and workers.
///
/// `data`/`call` type-erase a `&F` living on the submitter's stack. Safety
/// contract: [`ExecPool::run`] does not return until `remaining == 0`, so the
/// pointer is valid whenever an index is claimed; once the index counter is
/// exhausted a stale `Job` copy can never dereference it again.
struct Job {
    data: *const (),
    call: unsafe fn(*const (), usize),
    n: usize,
    next: Arc<AtomicUsize>,
    remaining: Arc<AtomicUsize>,
    panicked: Arc<AtomicBool>,
    /// Chaos hook: a `pool_panic` rule makes a claimed index panic mid-band
    /// (inside the same `catch_unwind` that contains a real job bug, so the
    /// injected failure takes the production propagation path). `None` in
    /// production — one never-taken branch per claimed index. Deliberately a
    /// `std` Arc, not the loom shim: the plan is plain data and loom tests
    /// never install one.
    fault: Option<std::sync::Arc<FaultPlan>>,
}

impl Clone for Job {
    fn clone(&self) -> Job {
        Job {
            data: self.data,
            call: self.call,
            n: self.n,
            next: Arc::clone(&self.next),
            remaining: Arc::clone(&self.remaining),
            panicked: Arc::clone(&self.panicked),
            fault: self.fault.clone(),
        }
    }
}

// SAFETY: `data` points at an `F: Sync` borrowed for the duration of `run`
// (see `Job` docs), so moving the handle to another thread moves only a
// pointer that stays valid until `remaining` drains; it is dereferenced
// exclusively through `call` under a claimed index.
unsafe impl Send for Job {}
// SAFETY: all shared state is `Arc`-wrapped atomics, and `&Job` exposes
// `data` only as `&F` where `F: Sync` (enforced by the `call_shim::<F>`
// instantiation in `run`), so concurrent shared access is safe.
unsafe impl Sync for Job {}

/// Call the type-erased job closure for index `i`.
///
/// # Safety
/// `data` must point at a live `F` (guaranteed by [`ExecPool::run`], which
/// keeps the closure on its stack until `remaining == 0`), and `i` must be an
/// index claimed exactly once from `Job::next`.
unsafe fn call_shim<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    // SAFETY: caller contract above — `data` was produced from `&F` in `run`
    // and is still borrowed for the duration of this call.
    unsafe { (*(data as *const F))(i) }
}

struct State {
    /// Bumped per submission; workers use it to distinguish fresh jobs.
    epoch: u64,
    /// Latest job. Intentionally never cleared: a worker waking late for an
    /// already-drained job finds the counter exhausted and claims nothing.
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// The submitter parks here until stragglers drain `remaining`.
    done_cv: Condvar,
    /// Guards against re-entrant / concurrent `run` calls: the pool executes
    /// one job at a time, and a nested submission degrades to inline
    /// sequential execution instead of corrupting the active job.
    busy: AtomicBool,
}

/// A persistent pool of `width - 1` worker threads plus the submitting thread.
///
/// `width == 1` spawns nothing and runs jobs inline — sequential execution is
/// the degenerate pool, not a separate code path.
pub struct ExecPool {
    shared: Arc<Shared>,
    handles: Vec<crate::util::sync::JoinHandle>,
    width: usize,
    /// Chaos plan consulted per claimed index at the `pool_panic` site
    /// ([`ExecPool::set_fault_plan`]); `None` in production.
    fault: Option<std::sync::Arc<FaultPlan>>,
}

impl ExecPool {
    /// Build a pool of total width `threads` (including the caller). `0`
    /// resolves via [`resolve_workers`] (env var, else hardware parallelism).
    pub fn new(threads: usize) -> ExecPool {
        let width = resolve_workers(threads).max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { epoch: 0, job: None, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            busy: AtomicBool::new(false),
        });
        let handles = (0..width - 1)
            .map(|i| {
                let sh = Arc::clone(&shared);
                crate::util::sync::spawn_worker(format!("qtip-exec-{i}"), move || {
                    worker_loop(sh)
                })
            })
            .collect();
        ExecPool { shared, handles, width, fault: None }
    }

    /// Arm the `pool_panic` chaos site: every subsequently submitted job
    /// consults `plan` once per claimed index and panics mid-band when a
    /// rule fires. The panic takes the production propagation path — the
    /// worker's `catch_unwind` records it, the submitter re-panics after the
    /// job drains — so chaos tests exercise exactly what a real job bug would.
    pub fn set_fault_plan(&mut self, plan: std::sync::Arc<FaultPlan>) {
        self.fault = Some(plan);
    }

    /// Width-1 pool: no spawned threads, `run` executes inline. Used as the
    /// implicit pool behind the convenience (non-`_with`) model APIs.
    pub fn sequential() -> ExecPool {
        ExecPool::new(1)
    }

    /// Process-wide width-1 pool (no spawned threads; jobs run inline on the
    /// caller). Lets non-pool convenience entry points — e.g.
    /// `QuantizedMatrix::matvec` — route through the scratch-based pool
    /// kernels without constructing a pool per call.
    #[cfg(not(loom))]
    pub fn shared_sequential() -> &'static ExecPool {
        static SEQ: std::sync::OnceLock<ExecPool> = std::sync::OnceLock::new();
        SEQ.get_or_init(ExecPool::sequential)
    }

    /// Loom builds cannot park a loom primitive in a process-wide static
    /// (loom objects only live inside `loom::model`), so the shared handle
    /// degrades to a leaked per-call pool. Only loom tests ever run this.
    #[cfg(loom)]
    pub fn shared_sequential() -> &'static ExecPool {
        Box::leak(Box::new(ExecPool::sequential()))
    }

    /// Total execution width, including the submitting thread.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Number of spawned worker threads (`width - 1`).
    pub fn spawned_workers(&self) -> usize {
        self.handles.len()
    }

    /// Run `f(i)` for every `i in 0..n` across the pool. Blocks until all
    /// indices complete; panics (after the job drains) if any invocation
    /// panicked. Each index is claimed exactly once; claim order is
    /// nondeterministic, so `f` must not depend on cross-index ordering.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        // Inline paths: degenerate pool, single item, or the pool is already
        // executing a job (re-entrant or concurrent submission). Acquire on
        // the winning swap pairs with the Release store below, so a thread
        // that takes ownership of the pool sees the previous job fully drained.
        if self.width <= 1 || n == 1 || self.shared.busy.swap(true, Ordering::Acquire) {
            // The inline path consults the chaos plan too, so a width-1 pool
            // (single-core CI) still exercises the `pool_panic` site — the
            // panic unwinds straight to the caller, same as the re-panic below.
            for i in 0..n {
                if let Some(plan) = &self.fault {
                    if plan.fire(POOL_PANIC) {
                        panic!("injected pool worker panic (inline index {i})");
                    }
                }
                f(i);
            }
            return;
        }
        let job = Job {
            data: &f as *const F as *const (),
            call: call_shim::<F>,
            n,
            next: Arc::new(AtomicUsize::new(0)),
            remaining: Arc::new(AtomicUsize::new(n)),
            panicked: Arc::new(AtomicBool::new(false)),
            fault: self.fault.clone(),
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job.clone());
        }
        self.shared.work_cv.notify_all();
        // The submitter is a worker too — no thread idles while holding work.
        execute(&job, &self.shared);
        {
            let mut st = self.shared.state.lock().unwrap();
            while job.remaining.load(Ordering::Acquire) != 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
        }
        self.shared.busy.store(false, Ordering::Release);
        if job.panicked.load(Ordering::Acquire) {
            panic!("ExecPool job panicked on a worker thread");
        }
    }

    /// Partition `data` into consecutive `chunk`-sized blocks and run
    /// `f(block_index, block)` across the pool. The disjoint `&mut` blocks are
    /// materialized from a shared base pointer — no per-slot locking.
    pub fn run_chunks<T, F>(&self, data: &mut [T], chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(chunk > 0);
        let len = data.len();
        let base = SendPtr(data.as_mut_ptr());
        self.run(len.div_ceil(chunk), move |i| {
            let start = i * chunk;
            let end = (start + chunk).min(len);
            // SAFETY: blocks [start, end) are disjoint across indices, each
            // index is claimed exactly once, and `data` outlives `run`.
            let block =
                unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
            f(i, block);
        });
    }

    /// Partition `0..n_units` into consecutive bands of `per_band` units and
    /// run `f(start, end)` for each band across the pool (the final band may
    /// be short). Band granularity is the caller's alignment lever: the
    /// lane-blocked decode kernels pass `quant::kernel::lane_band_tiles` so
    /// every parallel band covers whole lane blocks.
    pub fn run_bands<F>(&self, n_units: usize, per_band: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        assert!(per_band > 0);
        self.run(n_units.div_ceil(per_band), |i| {
            let start = i * per_band;
            f(start, (start + per_band).min(n_units));
        });
    }

    /// Parallel map preserving order. Results are written straight into their
    /// disjoint output slots (no Mutex per slot, no `T: Default + Clone`
    /// pre-fill — the seed's `parallel_map` needed both).
    ///
    /// The reassembly is a per-slot `assume_init` walk rather than a
    /// `Vec::from_raw_parts` pointer cast of the `MaybeUninit` buffer: the
    /// cast version retagged the allocation through a derived pointer, which
    /// Miri's borrow tracking rejects, and it silently relied on
    /// `Vec<MaybeUninit<T>>`/`Vec<T>` allocation-identity. The element-wise
    /// path is unambiguously defined behavior (and `collect` reuses the
    /// allocation in practice). On a worker panic `run` unwinds first, so the
    /// buffer is dropped as `MaybeUninit` — initialized slots leak rather
    /// than risk dropping a half-written value.
    pub fn map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        let mut out: Vec<MaybeUninit<T>> = Vec::with_capacity(n);
        out.resize_with(n, MaybeUninit::uninit);
        self.run_chunks(&mut out, 1, |i, slot| {
            slot[0].write(f(i));
        });
        out.into_iter()
            .map(|slot| {
                // SAFETY: `run` returned without panicking, so every index
                // executed and wrote its slot exactly once.
                unsafe { slot.assume_init() }
            })
            .collect()
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw-pointer wrapper so closures writing provably disjoint ranges can be
/// `Sync`. Shared by [`ExecPool::run_chunks`] and the pool-striped kernels
/// (`util::matrix`, `quant`); every user must guarantee its claimed ranges
/// are disjoint and that the pointee outlives the dispatch.
///
/// ## Why the bound is `T: Send` (and not `T: Sync`)
///
/// What actually crosses threads here is *exclusive* access: each claimed
/// index materializes `&mut T` (or `&mut [T]`) over a range no other index
/// touches, so the wrapper hands whole values to one thread at a time —
/// exactly the capability `T: Send` certifies. `T: Sync` would be the wrong
/// (and insufficient) bound: it certifies shared `&T` access, which these
/// kernels never perform through the pointer, and demanding it would reject
/// perfectly fine `Send`-only payloads. Conversely, without `T: Send` a
/// `!Send` type (e.g. `Rc`) could have its drop/refcount run on another
/// worker — the exact UB the auto-trait machinery exists to rule out.
pub struct SendPtr<T>(pub *mut T);
// SAFETY: sending the wrapper only moves the address; users take `&mut T`
// over disjoint ranges, so cross-thread transfer of the pointee is exclusive
// access, which `T: Send` certifies (see the bound rationale above).
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: `&SendPtr<T>` exposes nothing but a copy of the address; all
// dereferencing is done by callers under the disjoint-ranges contract, each
// range being exclusively owned by one thread (`T: Send`), never shared.
unsafe impl<T: Send> Sync for SendPtr<T> {}
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}

/// Claim-and-run loop shared by workers and the submitting thread.
///
/// `next` claims may be `Relaxed`: indices are independent and the claim
/// itself carries no payload. The `remaining` countdown is `AcqRel` — each
/// worker's decrement releases its writes, and the submitter's final Acquire
/// load (in `run`) pairs with them, so everything the job wrote
/// happens-before `run` returns.
fn execute(job: &Job, shared: &Shared) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.n {
            break;
        }
        // A panic must still decrement `remaining`, or the submitter (and any
        // borrowed data the job closure captures) would deadlock forever.
        // The injected `pool_panic` fires inside the same catch_unwind so a
        // chaos-injected worker panic is indistinguishable from a job bug.
        //
        // SAFETY: `i` was claimed exactly once from `next` and `i < n`; the
        // closure behind `data` outlives the dispatch (see `Job` docs).
        let ok = catch_unwind(AssertUnwindSafe(|| {
            if let Some(plan) = &job.fault {
                if plan.fire(POOL_PANIC) {
                    panic!("injected pool worker panic (index {i})");
                }
            }
            // SAFETY: see above.
            unsafe { (job.call)(job.data, i) }
        }))
        .is_ok();
        if !ok {
            job.panicked.store(true, Ordering::Release);
        }
        if job.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _st = shared.state.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.clone().expect("epoch advanced without a job");
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        execute(&job, &shared);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_covers_all_indices() {
        let pool = ExecPool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn run_zero_and_one() {
        let pool = ExecPool::new(4);
        pool.run(0, |_| panic!("should not run"));
        let ran = AtomicUsize::new(0);
        pool.run(1, |_| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        // The whole point vs the scoped helpers: one pool, many cheap submits.
        let pool = ExecPool::new(3);
        let sum = AtomicU64::new(0);
        for _ in 0..200 {
            pool.run(17, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
        }
        assert_eq!(sum.load(Ordering::SeqCst), 200 * (16 * 17 / 2));
    }

    #[test]
    fn map_preserves_order_without_default() {
        // The result type is neither Default nor Clone: the seed's
        // Mutex-per-slot parallel_map could not have produced it.
        struct NoDefault(usize);
        let pool = ExecPool::new(4);
        let out = pool.map(50, |i| NoDefault(i * i));
        for (i, v) in out.iter().enumerate() {
            assert_eq!(v.0, i * i);
        }
    }

    #[test]
    fn map_drops_every_result_exactly_once() {
        // Guards the MaybeUninit reassembly in `map`: each produced value must
        // be dropped exactly once by the caller (a double-init, skipped slot,
        // or double-drop in the assume_init walk would show up here — and
        // under the Miri CI lane, as UB).
        static DROPS: AtomicUsize = AtomicUsize::new(0);
        struct CountsDrop(usize);
        impl Drop for CountsDrop {
            fn drop(&mut self) {
                DROPS.fetch_add(1, Ordering::SeqCst);
            }
        }
        let pool = ExecPool::new(4);
        let out = pool.map(37, CountsDrop);
        assert_eq!(out.len(), 37);
        assert_eq!(DROPS.load(Ordering::SeqCst), 0, "no value may drop during map");
        drop(out);
        assert_eq!(DROPS.load(Ordering::SeqCst), 37);
    }

    #[test]
    fn run_chunks_sum() {
        let pool = ExecPool::new(4);
        let mut data = vec![1u64; 1000];
        pool.run_chunks(&mut data, 64, |idx, chunk| {
            for v in chunk.iter_mut() {
                *v += idx as u64;
            }
        });
        let total: u64 = data.iter().sum();
        let mut expect = 1000u64;
        let mut off = 0usize;
        let mut idx = 0u64;
        while off < 1000 {
            let len = 64.min(1000 - off) as u64;
            expect += idx * len;
            off += 64;
            idx += 1;
        }
        assert_eq!(total, expect);
    }

    #[test]
    fn run_bands_covers_all_units_without_overlap() {
        let pool = ExecPool::new(4);
        for (n, per_band) in [(13usize, 2usize), (16, 8), (7, 16), (1, 1), (0, 3)] {
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.run_bands(n, per_band, |start, end| {
                assert!(start < end || n == 0);
                assert!(end <= n);
                assert_eq!(start % per_band, 0, "bands must start on a band boundary");
                for h in &hits[start..end] {
                    h.fetch_add(1, Ordering::SeqCst);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "n={n} per_band={per_band} unit {i}");
            }
        }
    }

    #[test]
    fn shared_sequential_is_width_one() {
        let pool = ExecPool::shared_sequential();
        assert_eq!(pool.width(), 1);
        assert_eq!(pool.spawned_workers(), 0);
        let sum = AtomicUsize::new(0);
        pool.run(9, |i| {
            sum.fetch_add(i, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 36);
    }

    #[test]
    fn sequential_pool_spawns_nothing() {
        let pool = ExecPool::sequential();
        assert_eq!(pool.width(), 1);
        assert_eq!(pool.spawned_workers(), 0);
        let out = pool.map(10, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn nested_run_degrades_to_inline() {
        // run() inside run() must not corrupt the outer job — it executes the
        // inner indices inline on whichever thread submitted them.
        let pool = ExecPool::new(4);
        let sum = AtomicU64::new(0);
        pool.run(8, |_| {
            pool.run(5, |j| {
                sum.fetch_add(j as u64, Ordering::Relaxed);
            });
        });
        assert_eq!(sum.load(Ordering::SeqCst), 8 * (4 * 5 / 2));
    }

    #[test]
    fn worker_panic_propagates_not_deadlocks() {
        let pool = ExecPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(64, |i| {
                if i == 33 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic inside a job must surface to the submitter");
        // And the pool must still be usable afterwards.
        let ran = AtomicUsize::new(0);
        pool.run(16, |_| {
            ran.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ran.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn injected_pool_panic_propagates_like_a_job_bug() {
        // Both the dispatched (width ≥ 2) and inline (width 1) paths must
        // surface an armed pool_panic to the submitter as a plain panic —
        // the same contract as worker_panic_propagates_not_deadlocks.
        for width in [2usize, 1] {
            let mut pool = ExecPool::new(width);
            pool.set_fault_plan(std::sync::Arc::new(
                crate::util::fault::FaultPlan::parse("7:pool_panic=1").unwrap(),
            ));
            let r = catch_unwind(AssertUnwindSafe(|| pool.run(8, |_| {})));
            assert!(r.is_err(), "armed pool_panic must reach the width-{width} submitter");
            // The pool stays usable: the next (un-fired) run would need a
            // fresh plan to panic again, but rate-1 rules always fire, so
            // drop the plan via a new pool and run a clean job.
            let clean = ExecPool::new(width);
            let ran = AtomicUsize::new(0);
            clean.run(4, |_| {
                ran.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(ran.load(Ordering::SeqCst), 4);
        }
    }

    #[test]
    fn workers_env_default() {
        assert!(default_workers() >= 1);
        assert_eq!(resolve_workers(3), 3);
        assert!(resolve_workers(0) >= 1);
    }
}
