//! Deterministic fault injection for chaos testing.
//!
//! A `FaultPlan` is a seeded schedule of failure rules keyed by *site* (a
//! static string naming an injection point) and optionally by a dynamic *key*
//! (e.g. a lane name). Each call to [`FaultPlan::fire`] consumes one step of a
//! per-rule counter and hashes `(seed, site, key, step)` into a uniform value,
//! so a given plan fires the exact same schedule on every run regardless of
//! thread timing — the property `tests/chaos.rs` relies on to replay failures.
//!
//! Sites wired into the serving stack:
//! - [`KV_ALLOC`] — `KvArena::acquire` reports the free list empty.
//! - [`DECODE_PANIC`] — a lane's decode round panics (keyed by lane name).
//! - [`ROUND_STALL`] — a lane's round sleeps `stall_ms` before decoding
//!   (keyed by lane name), exercising the watchdog.
//! - [`IO_ERR`] — a frontend connection fails at accept time.
//! - [`POOL_PANIC`] — an [`crate::util::threadpool::ExecPool`] worker panics
//!   mid-band while executing a claimed index, exercising the
//!   panic-propagation path below the batcher (the submitter re-panics, the
//!   lane's `catch_unwind` poisons that lane only).
//!
//! The process-wide plan is read once from `QTIP_FAULT=<seed>:<spec>` where
//! `<spec>` is a comma-separated list of `site[@key]=rate` rules plus an
//! optional `stall_ms=<n>` parameter, e.g.
//! `QTIP_FAULT=1234:kv_alloc=0.3,decode_panic@beta=1,round_stall=0.05,stall_ms=200`.
//! With the variable unset, [`global`] returns `None` and every injection
//! point is a branch on an `Option` that is always `None`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use super::rng::mix64;

/// Injection site: paged-KV block acquisition fails as if the arena were full.
pub const KV_ALLOC: &str = "kv_alloc";
/// Injection site: a lane's decode round panics (keyed by lane name).
pub const DECODE_PANIC: &str = "decode_panic";
/// Injection site: a lane's round stalls for `stall_ms` (keyed by lane name).
pub const ROUND_STALL: &str = "round_stall";
/// Injection site: a frontend connection is dropped with an IO error.
pub const IO_ERR: &str = "io_err";
/// Injection site: an execution-pool worker panics while running a claimed
/// band of a submitted job.
pub const POOL_PANIC: &str = "pool_panic";

/// FNV-1a over a string; cheap stateless site/key hashing.
fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    h
}

/// One `site[@key]=rate` rule. `hits` counts how many times the rule has been
/// consulted; the counter value is part of the hash so each consultation gets
/// an independent (but reproducible) draw.
#[derive(Debug)]
struct Rule {
    site: String,
    /// `None` matches any key at the site; `Some(k)` matches only that key.
    key: Option<String>,
    rate: f64,
    hits: AtomicU64,
}

/// A seeded, deterministic fault schedule. Shared (`Arc`) between the server,
/// the KV arena, and the frontends; all counters are atomic so concurrent
/// consultation stays well-defined (the *set* of draws is deterministic per
/// consulting site because each site owns its own rule counters).
#[derive(Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<Rule>,
    stall_ms: u64,
    fired: AtomicU64,
}

impl FaultPlan {
    /// Parse `<seed>:<spec>` (the `QTIP_FAULT` grammar, see module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let (seed_str, rules_str) = spec
            .split_once(':')
            .ok_or_else(|| format!("fault spec '{spec}' missing '<seed>:' prefix"))?;
        let seed: u64 = seed_str
            .trim()
            .parse()
            .map_err(|_| format!("fault spec seed '{seed_str}' is not a u64"))?;
        let mut plan = FaultPlan {
            seed,
            rules: Vec::new(),
            stall_ms: 100,
            fired: AtomicU64::new(0),
        };
        for part in rules_str.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (lhs, rhs) = part
                .split_once('=')
                .ok_or_else(|| format!("fault rule '{part}' missing '=rate'"))?;
            if lhs == "stall_ms" {
                plan.stall_ms = rhs
                    .parse()
                    .map_err(|_| format!("stall_ms '{rhs}' is not a u64"))?;
                continue;
            }
            let (site, key) = match lhs.split_once('@') {
                Some((s, k)) => (s.to_string(), Some(k.to_string())),
                None => (lhs.to_string(), None),
            };
            let rate: f64 = rhs
                .parse()
                .map_err(|_| format!("fault rate '{rhs}' is not a number"))?;
            if !(0.0..=1.0).contains(&rate) {
                return Err(format!("fault rate {rate} outside [0, 1]"));
            }
            plan.rules.push(Rule {
                site,
                key,
                rate,
                hits: AtomicU64::new(0),
            });
        }
        Ok(plan)
    }

    /// Consult the plan at `site` with no dynamic key.
    pub fn fire(&self, site: &str) -> bool {
        self.fire_keyed(site, "")
    }

    /// Consult the plan at `site` for `key` (e.g. a lane name). The first rule
    /// whose site matches and whose key is absent or equal decides; its
    /// counter advances exactly once per consultation.
    pub fn fire_keyed(&self, site: &str, key: &str) -> bool {
        for rule in &self.rules {
            let key_ok = match &rule.key {
                Some(k) => k == key,
                None => true,
            };
            if rule.site != site || !key_ok {
                continue;
            }
            let n = rule.hits.fetch_add(1, Ordering::SeqCst);
            let h = mix64(
                self.seed
                    ^ fnv64(site)
                    ^ fnv64(key).rotate_left(31)
                    ^ n.wrapping_mul(0x9E3779B97F4A7C15),
            );
            // 53 mantissa bits -> uniform in [0, 1); rate 0 never fires,
            // rate 1 always fires.
            let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
            if u < rule.rate {
                self.fired.fetch_add(1, Ordering::SeqCst);
                return true;
            }
            return false;
        }
        false
    }

    /// Stall duration for the `round_stall` site.
    pub fn stall_ms(&self) -> u64 {
        self.stall_ms
    }

    /// Total faults fired so far (all sites); chaos tests use this to assert
    /// a schedule actually injected something.
    pub fn fired(&self) -> u64 {
        self.fired.load(Ordering::SeqCst)
    }
}

/// The process-wide plan parsed from `QTIP_FAULT`, or `None` when unset or
/// malformed (a malformed spec logs once and disables injection rather than
/// aborting the server).
pub fn global() -> Option<&'static Arc<FaultPlan>> {
    static GLOBAL: OnceLock<Option<Arc<FaultPlan>>> = OnceLock::new();
    GLOBAL
        .get_or_init(|| match std::env::var("QTIP_FAULT") {
            Ok(spec) if !spec.trim().is_empty() => match FaultPlan::parse(&spec) {
                Ok(plan) => Some(Arc::new(plan)),
                Err(e) => {
                    eprintln!("[fault] ignoring QTIP_FAULT: {e}");
                    None
                }
            },
            _ => None,
        })
        .as_ref()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_grammar() {
        let p = FaultPlan::parse("1234:kv_alloc=0.3,decode_panic@beta=1,stall_ms=200").unwrap();
        assert_eq!(p.seed, 1234);
        assert_eq!(p.stall_ms(), 200);
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].site, "kv_alloc");
        assert!(p.rules[0].key.is_none());
        assert_eq!(p.rules[1].key.as_deref(), Some("beta"));
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(FaultPlan::parse("no-seed-prefix").is_err());
        assert!(FaultPlan::parse("x:kv_alloc=0.5").is_err());
        assert!(FaultPlan::parse("1:kv_alloc").is_err());
        assert!(FaultPlan::parse("1:kv_alloc=1.5").is_err());
        assert!(FaultPlan::parse("1:kv_alloc=nan-ish").is_err());
    }

    #[test]
    fn same_seed_same_schedule() {
        let a = FaultPlan::parse("99:kv_alloc=0.5").unwrap();
        let b = FaultPlan::parse("99:kv_alloc=0.5").unwrap();
        let sa: Vec<bool> = (0..256).map(|_| a.fire(KV_ALLOC)).collect();
        let sb: Vec<bool> = (0..256).map(|_| b.fire(KV_ALLOC)).collect();
        assert_eq!(sa, sb);
        // A 0.5-rate schedule over 256 draws fires some but not all.
        let n = sa.iter().filter(|&&f| f).count();
        assert!(n > 0 && n < 256, "fired {n}/256");
        assert_eq!(a.fired(), n as u64);
    }

    #[test]
    fn different_seeds_differ() {
        let a = FaultPlan::parse("1:kv_alloc=0.5").unwrap();
        let b = FaultPlan::parse("2:kv_alloc=0.5").unwrap();
        let sa: Vec<bool> = (0..128).map(|_| a.fire(KV_ALLOC)).collect();
        let sb: Vec<bool> = (0..128).map(|_| b.fire(KV_ALLOC)).collect();
        assert_ne!(sa, sb);
    }

    #[test]
    fn rate_extremes() {
        let p = FaultPlan::parse("7:decode_panic=1,io_err=0").unwrap();
        for _ in 0..64 {
            assert!(p.fire(DECODE_PANIC));
            assert!(!p.fire(IO_ERR));
        }
        // An unlisted site never fires.
        assert!(!p.fire(KV_ALLOC));
    }

    #[test]
    fn keyed_rule_matches_only_its_key() {
        let p = FaultPlan::parse("5:decode_panic@beta=1").unwrap();
        assert!(!p.fire_keyed(DECODE_PANIC, "alpha"));
        assert!(p.fire_keyed(DECODE_PANIC, "beta"));
        // Unkeyed rules match any key.
        let q = FaultPlan::parse("5:round_stall=1").unwrap();
        assert!(q.fire_keyed(ROUND_STALL, "alpha"));
        assert!(q.fire_keyed(ROUND_STALL, "beta"));
    }
}
