//! Incoherence processing with the Random Hadamard Transform (paper §2.1).
//!
//! `W̃ = V_m S_m W S_n V_nᵀ`, `H̃ = V_n S_n H S_n V_nᵀ` where `V_k` is a (seeded)
//! orthonormal Hadamard matrix and `S_k` a random ±1 diagonal. With probability
//! ≥ 1−δ this makes W̃ μ-incoherent with μ = 2·log(4mn/δ): entries become
//! approximately i.i.d. Gaussian — the input distribution QTIP's trellis codes are
//! designed for.
//!
//! At inference the transform never materializes Ŵ: `Wx = S_m V_mᵀ Ŵ̃ (V_n S_n x)`,
//! i.e. an O(n log n) transform on the activations before the quantized matvec and
//! an O(m log m) one after (`forward_activations` / `restore_outputs`).

use crate::util::hadamard::{rht_forward, rht_inverse, supported};
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// The per-matrix RHT context: two random sign vectors (the Hadamard factors are
/// implicit/deterministic).
#[derive(Clone, Debug)]
pub struct RhtContext {
    pub sign_rows: Vec<f32>,
    pub sign_cols: Vec<f32>,
}

impl RhtContext {
    pub fn new(rows: usize, cols: usize, seed: u64) -> Self {
        assert!(supported(rows), "rows={rows} not a supported Hadamard size");
        assert!(supported(cols), "cols={cols} not a supported Hadamard size");
        let mut rng = Rng::new(seed ^ 0x52_48_54); // "RHT"
        let sign_rows = (0..rows).map(|_| rng.sign()).collect();
        let sign_cols = (0..cols).map(|_| rng.sign()).collect();
        RhtContext { sign_rows, sign_cols }
    }

    /// Serialize the signs as bit flags for the artifact manifest.
    pub fn sign_bits(signs: &[f32]) -> Vec<u32> {
        let mut words = vec![0u32; signs.len().div_ceil(32)];
        for (i, &s) in signs.iter().enumerate() {
            if s < 0.0 {
                words[i / 32] |= 1 << (i % 32);
            }
        }
        words
    }

    pub fn signs_from_bits(words: &[u32], n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| if words[i / 32] >> (i % 32) & 1 == 1 { -1.0 } else { 1.0 })
            .collect()
    }

    /// `W̃ = V_m S_m W S_n V_nᵀ`.
    pub fn transform_weight(&self, w: &Matrix) -> Matrix {
        assert_eq!(w.rows, self.sign_rows.len());
        assert_eq!(w.cols, self.sign_cols.len());
        let mut out = w.clone();
        // Rows: each row r ← V_n S_n r (right factor S_n V_nᵀ acts as RHT on rows).
        for r in 0..out.rows {
            rht_forward(out.row_mut(r), &self.sign_cols);
        }
        // Columns: each col c ← V_m S_m c.
        let mut col = vec![0.0f32; out.rows];
        for c in 0..out.cols {
            for r in 0..out.rows {
                col[r] = out.at(r, c);
            }
            rht_forward(&mut col, &self.sign_rows);
            for r in 0..out.rows {
                *out.at_mut(r, c) = col[r];
            }
        }
        out
    }

    /// Exact inverse of [`Self::transform_weight`].
    pub fn restore_weight(&self, wt: &Matrix) -> Matrix {
        let mut out = wt.clone();
        let mut col = vec![0.0f32; out.rows];
        for c in 0..out.cols {
            for r in 0..out.rows {
                col[r] = out.at(r, c);
            }
            rht_inverse(&mut col, &self.sign_rows);
            for r in 0..out.rows {
                *out.at_mut(r, c) = col[r];
            }
        }
        for r in 0..out.rows {
            rht_inverse(out.row_mut(r), &self.sign_cols);
        }
        out
    }

    /// `H̃ = V_n S_n H S_n V_nᵀ` (input-side conjugation; H is n×n).
    pub fn transform_hessian(&self, h: &Matrix) -> Matrix {
        assert_eq!(h.rows, h.cols);
        assert_eq!(h.rows, self.sign_cols.len());
        let mut out = h.clone();
        for r in 0..out.rows {
            rht_forward(out.row_mut(r), &self.sign_cols);
        }
        let mut col = vec![0.0f32; out.rows];
        for c in 0..out.cols {
            for r in 0..out.rows {
                col[r] = out.at(r, c);
            }
            rht_forward(&mut col, &self.sign_cols);
            for r in 0..out.rows {
                *out.at_mut(r, c) = col[r];
            }
        }
        out
    }

    /// Inference: transform an activation vector x ← V_n S_n x before the quantized
    /// matvec (this matches `transform_weight`'s column conjugation).
    pub fn forward_activations(&self, x: &mut [f32]) {
        rht_forward(x, &self.sign_cols);
    }

    /// Inference: map the quantized matvec output back, y ← S_m V_mᵀ ỹ.
    pub fn restore_outputs(&self, y: &mut [f32]) {
        rht_inverse(y, &self.sign_rows);
    }

    /// Incoherence coefficient μ of a matrix: max |W_ij| · sqrt(mn) / ||W||_F.
    pub fn mu(w: &Matrix) -> f64 {
        let maxabs = w.data.iter().fold(0.0f32, |m, &v| m.max(v.abs())) as f64;
        let fro = w.fro_norm();
        if fro == 0.0 {
            return 0.0;
        }
        maxabs * ((w.rows * w.cols) as f64).sqrt() / fro
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn weight_roundtrip_exact() {
        let mut rng = Rng::new(1);
        let w = Matrix::gaussian(64, 128, 1.0, &mut rng);
        let ctx = RhtContext::new(64, 128, 7);
        let wt = ctx.transform_weight(&w);
        let back = ctx.restore_weight(&wt);
        for (a, b) in back.data.iter().zip(&w.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn transform_preserves_frobenius() {
        let mut rng = Rng::new(2);
        let w = Matrix::gaussian(32, 64, 2.0, &mut rng);
        let ctx = RhtContext::new(32, 64, 8);
        let wt = ctx.transform_weight(&w);
        assert!((wt.fro_norm() - w.fro_norm()).abs() < 1e-3 * w.fro_norm());
    }

    #[test]
    fn reduces_mu_of_spiky_matrix() {
        // A matrix with one huge entry is maximally coherent; RHT must flatten it.
        let mut w = Matrix::zeros(64, 64);
        *w.at_mut(13, 57) = 100.0;
        let before = RhtContext::mu(&w);
        let ctx = RhtContext::new(64, 64, 9);
        let after = RhtContext::mu(&ctx.transform_weight(&w));
        assert!(before == 64.0, "spike mu = sqrt(mn)");
        assert!(after < 3.0, "post-RHT mu {after}");
    }

    #[test]
    fn gaussianizes_sparse_weights() {
        // A sparse, heavy-tailed (outlier-dominated) matrix becomes approximately
        // Gaussian after the RHT: each W̃ entry is a ±-signed average of all
        // entries, so the CLT kicks in (kurtosis → 3).
        let mut rng = Rng::new(3);
        let mut w = Matrix::zeros(128, 128);
        for _ in 0..200 {
            let r = rng.below(128);
            let c = rng.below(128);
            *w.at_mut(r, c) = rng.gauss_f32() * 10.0;
        }
        let ctx = RhtContext::new(128, 128, 10);
        let wt = ctx.transform_weight(&w);
        let kurt_before = stats::kurtosis(&w.data);
        let kurt_after = stats::kurtosis(&wt.data);
        assert!(kurt_before > 20.0, "sparse outliers are heavy tailed: {kurt_before}");
        assert!((kurt_after - 3.0).abs() < 0.8, "post-RHT kurtosis {kurt_after}");
    }

    #[test]
    fn hessian_conjugation_preserves_quadratic_form() {
        // tr(W̃ H̃ W̃ᵀ) == tr(W H Wᵀ): the proxy objective is invariant under RHT.
        let mut rng = Rng::new(4);
        let n = 32;
        let a = Matrix::gaussian(n, n, 1.0, &mut rng);
        let h = a.matmul(&a.transpose());
        let w = Matrix::gaussian(16, n, 1.0, &mut rng);
        let ctx = RhtContext::new(16, n, 11);
        let ht = ctx.transform_hessian(&h);
        let wt = ctx.transform_weight(&w);
        let lhs = wt.matmul(&ht).matmul(&wt.transpose()).trace();
        let rhs = w.matmul(&h).matmul(&w.transpose()).trace();
        assert!((lhs - rhs).abs() < 1e-2 * rhs.abs().max(1.0), "{lhs} vs {rhs}");
    }

    #[test]
    fn inference_path_matches_materialized_product() {
        // y = W x must equal restore_outputs(W̃ @ forward_activations(x)).
        let mut rng = Rng::new(5);
        let w = Matrix::gaussian(32, 64, 1.0, &mut rng);
        let ctx = RhtContext::new(32, 64, 12);
        let wt = ctx.transform_weight(&w);
        let x = rng.gauss_vec(64);
        let direct = w.matvec(&x);
        let mut xt = x.clone();
        ctx.forward_activations(&mut xt);
        let mut y = wt.matvec(&xt);
        ctx.restore_outputs(&mut y);
        for (a, b) in y.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn sign_bits_roundtrip() {
        let mut rng = Rng::new(6);
        let signs: Vec<f32> = (0..100).map(|_| rng.sign()).collect();
        let bits = RhtContext::sign_bits(&signs);
        let back = RhtContext::signs_from_bits(&bits, 100);
        assert_eq!(signs, back);
    }
}
