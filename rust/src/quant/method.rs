//! The pluggable quantization-method surface: every code (1MAD, 3INST, HYB,
//! LUT, VPTQ, …) implements [`QuantMethod`] in its own module and registers a
//! single static in [`crate::quant::registry`]. The trait owns the full
//! method lifecycle:
//!
//! * **build** — construct the encode-side [`Code`] (trellis decode values for
//!   Viterbi) *and* the decode-side [`CodeSpec`] from a [`QtipConfig`], in one
//!   call, so LUT training happens exactly once;
//! * **persistence** — serialize/deserialize the spec's method-owned config
//!   blob in the artifact manifest ([`QuantMethod::spec_to_json`] /
//!   [`QuantMethod::spec_from_json`], bridged to the io layer through
//!   [`TableSink`] / [`TableSource`] so methods never see file formats);
//! * **kernel dispatch** — [`QuantMethod::run_kernel`] receives a
//!   [`KernelCall`] (a band of a single-column or batch-fused decode matvec)
//!   and completes it via [`KernelCall::run_v1`] / [`KernelCall::run_v2`] with
//!   the method's scalar and lane decode closures. The generic kernels
//!   monomorphize *inside each method's module*, so the hot loops compile to
//!   the same per-weight ALU sequences as the pre-registry dispatch macros —
//!   bit-identity with the reference paths is preserved by construction
//!   (`tests/kernel_parity.rs` sweeps every registry entry).
//!
//! Adding a method touches exactly two places: the method's own module and
//! the registration line in `quant/registry.rs`.

use anyhow::Result;

use crate::codes::Code;
use crate::quant::kernel::KernelKind;
use crate::quant::{QtipConfig, QuantizedMatrix, YCells, LANES};
use crate::trellis::Trellis;
use crate::util::json::Json;

/// Static description of a registered method (for `qtip info`).
#[derive(Clone, Copy, Debug)]
pub struct MethodInfo {
    pub name: &'static str,
    /// One-line description of the decode scheme.
    pub summary: &'static str,
    /// Supported code vector dimensions.
    pub v_options: &'static [u32],
    /// Validated bits-per-weight range (trellis `k`; `k·V ≤ 8`, `k·V < L`).
    pub bits_min: u32,
    pub bits_max: u32,
    /// Decoder-table bytes at the method's default configuration
    /// (0 = fully computed code, no table).
    pub default_table_bytes: usize,
}

/// Everything one `build` call produces: the encode-side trellis code (feeds
/// Viterbi via `Code::materialize`) and the decode-side spec carried by the
/// packed artifact. Producing both from one call guarantees any trained
/// tables are trained once and shared bit-exactly by both sides.
pub struct MethodBuild {
    pub code: Box<dyn Code>,
    pub spec: CodeSpec,
}

/// Where a method stores its decode tables when serializing a spec; the io
/// layer's blob writer implements this. Returns the byte offset of the
/// appended section.
pub trait TableSink {
    fn put_f32s(&mut self, vals: &[f32]) -> usize;
}

/// Bounds-checked decode-table reads when deserializing a spec; the io
/// layer's blob reader implements this.
pub trait TableSource {
    fn f32s(&self, off: usize, n: usize) -> Result<Vec<f32>>;
}

/// A quantization method: config parsing, code construction, artifact
/// persistence, and decode-kernel dispatch, owned by one module per method.
/// Implementors are unit structs registered as `&'static dyn QuantMethod` in
/// [`crate::quant::registry`].
pub trait QuantMethod: Send + Sync {
    /// Registry id; also the `--code` CLI spelling and the manifest `method`.
    fn name(&self) -> &'static str;

    /// Static description for `qtip info`.
    fn info(&self) -> MethodInfo;

    /// Preferred code dimension V when the caller does not pin one (parity
    /// sweeps, `--code` defaults).
    fn preferred_v(&self) -> u32 {
        1
    }

    /// Build the encode-side code and decode-side spec for one quantization
    /// run. Errors on configs the method does not support (wrong V, bad L).
    fn build(&'static self, cfg: &QtipConfig) -> Result<MethodBuild>;

    /// Decode one trellis state into `out[..V]` (cold path: tile
    /// reconstruction, debugging; the matvec hot loops go through
    /// [`QuantMethod::run_kernel`] instead).
    fn decode_state(&self, spec: &CodeSpec, state: u32, out: &mut [f32]);

    /// Bytes of decode-time table state (0 for computed codes): the quantity
    /// Table 10 budgets against L1 cache. Tables are fp16 on device.
    fn table_bytes(&self, spec: &CodeSpec) -> usize {
        spec.table().len() * 2
    }

    /// Serialize the spec's method-owned config (tables go to `sink`; the
    /// returned object is embedded in the layer manifest next to a `method`
    /// id written by the io layer).
    fn spec_to_json(&self, spec: &CodeSpec, sink: &mut dyn TableSink) -> Json;

    /// Rebuild a spec from its manifest object + blob sections, validating
    /// everything the decode hot path would otherwise trust blindly.
    fn spec_from_json(
        &'static self,
        j: &Json,
        src: &dyn TableSource,
        trellis: &Trellis,
    ) -> Result<CodeSpec>;

    /// Complete a decode-matvec band with this method's kernels: call
    /// [`KernelCall::run_v1`] (V=1) or [`KernelCall::run_v2`] (V=2) with the
    /// scalar and lane decode closures. Monomorphization happens here, in the
    /// method's own module — one dyn call per band, zero per weight.
    fn run_kernel(&self, spec: &CodeSpec, call: KernelCall<'_>);

    /// A synthetic decode spec (random packed bits are valid tail-biting
    /// walks) for parity sweeps and throughput benches: the trellis geometry
    /// `(l, k, preferred V)` plus a spec with any tables trained from `seed`.
    fn synthetic_entry(&'static self, l: u32, k: u32, seed: u64) -> (Trellis, CodeSpec);

    /// Trellis L the throughput benches should exercise (pure-LUT codes cap
    /// it so the table stays L1-resident, matching the paper's regime).
    fn bench_l(&self) -> u32 {
        16
    }
}

/// Decode-side code specification carried inside the artifact: the owning
/// method plus its parameters and decode tables. LUT-bearing methods own
/// their tables so a `QuantizedMatrix` stays self-contained.
#[derive(Clone)]
pub struct CodeSpec {
    method: &'static dyn QuantMethod,
    v: u32,
    /// Method-owned integer parameters (e.g. HYB's `q`). Meaning is private
    /// to the method; everything else treats them as opaque.
    params: Vec<u32>,
    /// Method-owned decode table (empty for computed codes).
    table: Vec<f32>,
}

impl CodeSpec {
    pub fn new(
        method: &'static dyn QuantMethod,
        v: u32,
        params: Vec<u32>,
        table: Vec<f32>,
    ) -> CodeSpec {
        CodeSpec { method, v, params, table }
    }

    #[inline]
    pub fn method(&self) -> &'static dyn QuantMethod {
        self.method
    }

    #[inline]
    pub fn name(&self) -> &'static str {
        self.method.name()
    }

    #[inline]
    pub fn v(&self) -> u32 {
        self.v
    }

    #[inline]
    pub fn params(&self) -> &[u32] {
        &self.params
    }

    #[inline]
    pub fn table(&self) -> &[f32] {
        &self.table
    }

    /// Decode one state (cold path; the matvec hot loops monomorphize in the
    /// owning method's `run_kernel` instead).
    #[inline]
    pub fn decode(&self, state: u32, out: &mut [f32]) {
        self.method.decode_state(self, state, out);
    }

    /// Bytes of decode-time table state (0 for the pure-computed codes): the
    /// quantity Table 10 budgets against L1 cache.
    pub fn decoder_table_bytes(&self) -> usize {
        self.method.table_bytes(self)
    }
}

impl std::fmt::Debug for CodeSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CodeSpec")
            .field("method", &self.name())
            .field("v", &self.v)
            .field("params", &self.params)
            .field("table_len", &self.table.len())
            .finish()
    }
}

/// One pending decode-matvec band, handed to [`QuantMethod::run_kernel`].
/// The shape (single-column vs batch-fused) is private; the method only
/// chooses the decode closures and the V arity via [`KernelCall::run_v1`] /
/// [`KernelCall::run_v2`] — the call routes itself to the matching scalar or
/// lane-blocked kernel from the matrix's [`KernelKind`] selection.
pub struct KernelCall<'a> {
    inner: CallInner<'a>,
}

enum CallInner<'a> {
    /// Single-column band: `y` holds output rows `[bi0·tx, bi1·tx)`.
    Tilde { qm: &'a QuantizedMatrix, bi0: usize, bi1: usize, xt: &'a [f32], y: &'a mut [f32] },
    /// Batch-fused band over column-major activations (`cols × nb`).
    Multi {
        qm: &'a QuantizedMatrix,
        bi0: usize,
        bi1: usize,
        xcol: &'a [f32],
        nb: usize,
        y: YCells,
    },
}

impl<'a> KernelCall<'a> {
    pub(super) fn tilde(
        qm: &'a QuantizedMatrix,
        bi0: usize,
        bi1: usize,
        xt: &'a [f32],
        y: &'a mut [f32],
    ) -> KernelCall<'a> {
        KernelCall { inner: CallInner::Tilde { qm, bi0, bi1, xt, y } }
    }

    pub(super) fn multi(
        qm: &'a QuantizedMatrix,
        bi0: usize,
        bi1: usize,
        xcol: &'a [f32],
        nb: usize,
        y: YCells,
    ) -> KernelCall<'a> {
        KernelCall { inner: CallInner::Multi { qm, bi0, bi1, xcol, nb, y } }
    }

    /// Run the band with V=1 decode closures. `scalar` and `lanes` must be
    /// the exact same op sequence per lane — that equivalence is what keeps
    /// the two kernel families bit-identical (`tests/kernel_parity.rs`).
    #[inline]
    pub fn run_v1<S, L>(self, scalar: S, lanes: L)
    where
        S: Fn(u32) -> f32,
        L: Fn([u32; LANES]) -> [f32; LANES],
    {
        match self.inner {
            CallInner::Tilde { qm, bi0, bi1, xt, y } => match qm.kernel {
                KernelKind::Scalar => qm.matvec_tilde_v1(bi0, bi1, xt, y, scalar),
                _ => qm.matvec_tilde_lanes_v1(bi0, bi1, xt, y, lanes),
            },
            CallInner::Multi { qm, bi0, bi1, xcol, nb, y } => match qm.kernel {
                KernelKind::Scalar => qm.matvec_tilde_multi_v1(bi0, bi1, xcol, nb, y, scalar),
                _ => qm.matvec_tilde_multi_lanes_v1(bi0, bi1, xcol, nb, y, lanes),
            },
        }
    }

    /// Run the band with V=2 pair-decode closures.
    #[inline]
    pub fn run_v2<S, L>(self, scalar: S, lanes: L)
    where
        S: Fn(u32) -> (f32, f32),
        L: Fn([u32; LANES]) -> ([f32; LANES], [f32; LANES]),
    {
        match self.inner {
            CallInner::Tilde { qm, bi0, bi1, xt, y } => match qm.kernel {
                KernelKind::Scalar => qm.matvec_tilde_v2(bi0, bi1, xt, y, scalar),
                _ => qm.matvec_tilde_lanes_v2(bi0, bi1, xt, y, lanes),
            },
            CallInner::Multi { qm, bi0, bi1, xcol, nb, y } => match qm.kernel {
                KernelKind::Scalar => qm.matvec_tilde_multi_v2(bi0, bi1, xcol, nb, y, scalar),
                _ => qm.matvec_tilde_multi_lanes_v2(bi0, bi1, xcol, nb, y, lanes),
            },
        }
    }
}
