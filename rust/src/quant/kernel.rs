//! Runtime decode-kernel selection for the fused trellis-decode matvecs.
//!
//! Two kernel families implement the hot path (`QuantizedMatrix::matvec_tilde`
//! and friends):
//!
//! * **`Scalar`** — the reference implementation: one rolling-window bit
//!   extraction, one scalar code evaluation, one scalar FMA per weight
//!   (§Perf optimization #1, see `EXPERIMENTS.md`).
//! * **`Lanes`** — the lane-blocked implementation (§Perf optimization #2):
//!   [`LANES`] output rows advance in lockstep, states are gathered into a
//!   `[u32; LANES]` block and decoded by lane-array code evaluators that LLVM
//!   auto-vectorizes. Bit-identical to `Scalar` by construction — lanes are
//!   distinct output rows, so no row's float accumulation order changes.
//!
//! The kernel is chosen **per matrix at quantize/load time** and stored on the
//! [`QuantizedMatrix`](crate::quant::QuantizedMatrix); precedence is
//! `--kernel` CLI flag ([`set_process_kernel`]) > `QTIP_KERNEL` env var >
//! `Auto` (which resolves to `Lanes`). `qtip info` prints the selection.

use std::sync::atomic::{AtomicU8, Ordering};

/// Output rows decoded in lockstep by the lane-blocked kernels. Eight f32
/// lanes = one AVX2 register (two SSE2 registers on the baseline target);
/// shapes whose row count is not a multiple of `LANES` fall back to a padded
/// remainder block, so any tile geometry is supported.
pub const LANES: usize = 8;

/// Which decode-matvec kernel family a [`QuantizedMatrix`] dispatches to.
///
/// [`QuantizedMatrix`]: crate::quant::QuantizedMatrix
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Defer to the build's default (currently [`KernelKind::Lanes`]).
    Auto,
    /// Scalar reference kernels (one weight at a time).
    Scalar,
    /// Lane-blocked kernels ([`LANES`] rows in lockstep).
    Lanes,
}

impl KernelKind {
    /// Parse a CLI/env spelling: `auto` | `scalar` | `lanes`.
    pub fn parse(s: &str) -> Result<KernelKind, String> {
        match s.trim() {
            "auto" => Ok(KernelKind::Auto),
            "scalar" => Ok(KernelKind::Scalar),
            "lanes" => Ok(KernelKind::Lanes),
            other => Err(format!(
                "unknown kernel '{other}' (expected auto | scalar | lanes)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Auto => "auto",
            KernelKind::Scalar => "scalar",
            KernelKind::Lanes => "lanes",
        }
    }

    /// Resolve `Auto` to the concrete kernel the hot path will run. Both
    /// families are bit-identical, so `Auto` simply picks the fast one.
    pub fn resolve(self) -> KernelKind {
        match self {
            KernelKind::Auto => KernelKind::Lanes,
            k => k,
        }
    }
}

/// Process-wide CLI override: 0 = unset, else the 1-based [`encode`] of the
/// kind — `decode(encode(k)) == Some(k)` by construction (roundtrip-tested).
/// Accessed with `Relaxed` (allowlisted in scripts/relaxed_allowlist.txt):
/// a single standalone byte set once at CLI parse time, publishing no other
/// memory.
static PROCESS_OVERRIDE: AtomicU8 = AtomicU8::new(0);

fn encode(k: KernelKind) -> u8 {
    match k {
        KernelKind::Auto => 1,
        KernelKind::Scalar => 2,
        KernelKind::Lanes => 3,
    }
}

fn decode(v: u8) -> Option<KernelKind> {
    match v {
        1 => Some(KernelKind::Auto),
        2 => Some(KernelKind::Scalar),
        3 => Some(KernelKind::Lanes),
        _ => None,
    }
}

/// Install the `--kernel` CLI override for this process (highest precedence).
pub fn set_process_kernel(k: KernelKind) {
    PROCESS_OVERRIDE.store(encode(k), Ordering::Relaxed);
}

/// The active `--kernel` CLI override, if any.
pub fn process_kernel() -> Option<KernelKind> {
    decode(PROCESS_OVERRIDE.load(Ordering::Relaxed))
}

/// Pure precedence rule: CLI override > env var > `Auto`. An unparsable env
/// value is ignored (falls through to `Auto`) rather than aborting a serve.
pub fn select(cli: Option<KernelKind>, env: Option<&str>) -> KernelKind {
    if let Some(k) = cli {
        return k;
    }
    if let Some(k) = env.and_then(|v| KernelKind::parse(v).ok()) {
        return k;
    }
    KernelKind::Auto
}

/// The process-wide kernel selection (`--kernel` > `QTIP_KERNEL` > `Auto`).
pub fn selected() -> KernelKind {
    select(process_kernel(), std::env::var("QTIP_KERNEL").ok().as_deref())
}

/// [`selected`], resolved to the concrete kernel stored on new matrices.
pub fn selected_resolved() -> KernelKind {
    selected().resolve()
}

/// Tile rows per parallel band so every band (except a short tail) covers
/// whole lane blocks: the smallest tile-row count whose row total reaches
/// [`LANES`]. The tile-parallel pool paths stripe bands of
/// `lane_band_tiles(tx) * tx` rows instead of single tile rows.
pub fn lane_band_tiles(tx: usize) -> usize {
    LANES.div_ceil(tx.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in [KernelKind::Auto, KernelKind::Scalar, KernelKind::Lanes] {
            assert_eq!(KernelKind::parse(k.name()), Ok(k));
        }
        assert!(KernelKind::parse("simd").is_err());
        assert_eq!(KernelKind::parse(" lanes "), Ok(KernelKind::Lanes));
    }

    #[test]
    fn precedence_cli_over_env_over_auto() {
        assert_eq!(
            select(Some(KernelKind::Scalar), Some("lanes")),
            KernelKind::Scalar
        );
        assert_eq!(select(None, Some("scalar")), KernelKind::Scalar);
        assert_eq!(select(None, Some("garbage")), KernelKind::Auto);
        assert_eq!(select(None, None), KernelKind::Auto);
    }

    #[test]
    fn override_encoding_roundtrips() {
        for k in [KernelKind::Auto, KernelKind::Scalar, KernelKind::Lanes] {
            assert_eq!(decode(encode(k)), Some(k));
        }
        assert_eq!(decode(0), None, "0 must stay reserved for 'unset'");
    }

    #[test]
    fn auto_resolves_to_lanes() {
        assert_eq!(KernelKind::Auto.resolve(), KernelKind::Lanes);
        assert_eq!(KernelKind::Scalar.resolve(), KernelKind::Scalar);
        assert_eq!(KernelKind::Lanes.resolve(), KernelKind::Lanes);
    }

    #[test]
    fn band_tiles_cover_a_lane_block() {
        assert_eq!(lane_band_tiles(16), 1);
        assert_eq!(lane_band_tiles(8), 1);
        assert_eq!(lane_band_tiles(4), 2);
        assert_eq!(lane_band_tiles(3), 3);
        assert_eq!(lane_band_tiles(1), 8);
        for tx in 1..=32 {
            assert!(lane_band_tiles(tx) * tx >= LANES);
        }
    }
}
