//! The quant-method registry: the single registration point for
//! [`QuantMethod`] implementations. Adding a method = implementing the trait
//! in its own module and appending one entry to [`METHODS`]; everything else
//! (CLI parsing, quantize pipeline, artifact io, serve-path kernel dispatch,
//! parity sweeps, benches, `qtip info`) iterates the registry.

use crate::codes::lut::LutMethod;
use crate::codes::onemad::OneMadMethod;
use crate::codes::threeinst::ThreeInstMethod;
use crate::codes::vptq::VptqMethod;
use crate::codes::HybMethod;
use crate::quant::method::QuantMethod;

/// Every registered quantization method, in presentation order.
pub static METHODS: [&dyn QuantMethod; 5] =
    [&OneMadMethod, &ThreeInstMethod, &HybMethod, &LutMethod, &VptqMethod];

/// All registered methods.
pub fn all() -> &'static [&'static dyn QuantMethod] {
    &METHODS
}

/// Look up a method by registry name.
pub fn get(name: &str) -> Option<&'static dyn QuantMethod> {
    METHODS.iter().copied().find(|m| m.name() == name)
}

/// Registered names, in presentation order (error messages, `qtip info`).
pub fn names() -> Vec<&'static str> {
    METHODS.iter().map(|m| m.name()).collect()
}

/// Look up a method by name or panic with the registered spellings — the
/// CLI-facing counterpart of [`get`] for paths that validated the name
/// earlier (config parsing rejects unknown codes with a proper error).
pub fn require(name: &str) -> &'static dyn QuantMethod {
    get(name).unwrap_or_else(|| {
        panic!("unknown code '{name}' (registered methods: {})", names().join("|"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_stable() {
        let names = names();
        assert!(names.contains(&"1mad"));
        assert!(names.contains(&"3inst"));
        assert!(names.contains(&"hyb"));
        assert!(names.contains(&"lut"));
        assert!(names.contains(&"vptq"));
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len(), "duplicate registry names");
    }

    #[test]
    fn lookup_roundtrips_and_require_panics_with_names() {
        for m in all() {
            assert_eq!(get(m.name()).unwrap().name(), m.name());
            let info = m.info();
            assert_eq!(info.name, m.name());
            assert!(info.v_options.contains(&m.preferred_v()));
            assert!(info.bits_min <= info.bits_max);
        }
        assert!(get("nope").is_none());
        let err = std::panic::catch_unwind(|| require("nope")).unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("unknown code 'nope'") && msg.contains("vptq"), "{msg}");
    }
}
