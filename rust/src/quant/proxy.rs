//! The per-layer proxy loss (paper Eq. 1): `ℓ(Ŵ) = tr((Ŵ−W) H (Ŵ−W)ᵀ)`.

use crate::util::matrix::{gemv, Matrix};

/// `tr((Ŵ−W) H (Ŵ−W)ᵀ)` — the adaptive-rounding objective.
pub fn proxy_loss(w: &Matrix, w_hat: &Matrix, h: &Matrix) -> f64 {
    assert_eq!(w.rows, w_hat.rows);
    assert_eq!(w.cols, w_hat.cols);
    assert_eq!(h.rows, w.cols);
    assert_eq!(h.cols, w.cols);
    let mut total = 0.0f64;
    let mut diff = vec![0.0f32; w.cols];
    let mut hd = vec![0.0f32; w.cols];
    for r in 0..w.rows {
        for c in 0..w.cols {
            diff[c] = w_hat.at(r, c) - w.at(r, c);
        }
        gemv(h, &diff, &mut hd);
        total += diff
            .iter()
            .zip(&hd)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum::<f64>();
    }
    total
}

/// Proxy loss normalized by the weight's own energy under H:
/// `tr((Ŵ−W)H(Ŵ−W)ᵀ) / tr(W H Wᵀ)`. Comparable across layers and scales.
pub fn relative_proxy_loss(w: &Matrix, w_hat: &Matrix, h: &Matrix) -> f64 {
    let denom = proxy_loss(&Matrix::zeros(w.rows, w.cols), w, h);
    if denom == 0.0 {
        return 0.0;
    }
    proxy_loss(w, w_hat, h) / denom
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let a = Matrix::gaussian(n, n, 1.0, &mut rng);
        let mut h = a.matmul(&a.transpose());
        for i in 0..n {
            *h.at_mut(i, i) += 0.5;
        }
        h
    }

    #[test]
    fn zero_for_exact_reconstruction() {
        let mut rng = Rng::new(1);
        let w = Matrix::gaussian(8, 16, 1.0, &mut rng);
        let h = random_spd(16, 2);
        assert_eq!(proxy_loss(&w, &w, &h), 0.0);
    }

    #[test]
    fn positive_for_spd_hessian() {
        let mut rng = Rng::new(3);
        let w = Matrix::gaussian(8, 16, 1.0, &mut rng);
        let mut w_hat = w.clone();
        *w_hat.at_mut(3, 5) += 0.1;
        let h = random_spd(16, 4);
        assert!(proxy_loss(&w, &w_hat, &h) > 0.0);
    }

    #[test]
    fn identity_hessian_reduces_to_frobenius() {
        let mut rng = Rng::new(5);
        let w = Matrix::gaussian(8, 16, 1.0, &mut rng);
        let w_hat = Matrix::gaussian(8, 16, 1.0, &mut rng);
        let h = Matrix::identity(16);
        let loss = proxy_loss(&w, &w_hat, &h);
        let fro: f64 = w
            .data
            .iter()
            .zip(&w_hat.data)
            .map(|(&a, &b)| ((b - a) as f64).powi(2))
            .sum();
        assert!((loss - fro).abs() < 1e-3 * fro.max(1.0));
    }

    #[test]
    fn matches_expectation_form() {
        // tr((D)H(D)ᵀ) == E_x ||D x||² when H = xxᵀ summed over the sample.
        let mut rng = Rng::new(6);
        let n = 12;
        let d = Matrix::gaussian(4, n, 1.0, &mut rng);
        let xs: Vec<Vec<f32>> = (0..50).map(|_| rng.gauss_vec(n)).collect();
        let mut h = Matrix::zeros(n, n);
        for x in &xs {
            for i in 0..n {
                for j in 0..n {
                    *h.at_mut(i, j) += x[i] * x[j];
                }
            }
        }
        let direct: f64 = xs
            .iter()
            .map(|x| d.matvec(x).iter().map(|&v| (v as f64).powi(2)).sum::<f64>())
            .sum();
        let via_trace = proxy_loss(&Matrix::zeros(4, n), &d, &h);
        assert!((direct - via_trace).abs() < 1e-2 * direct);
    }

    #[test]
    fn relative_loss_scale_invariant() {
        let mut rng = Rng::new(7);
        let w = Matrix::gaussian(8, 16, 1.0, &mut rng);
        let mut w_hat = w.clone();
        for v in w_hat.data.iter_mut() {
            *v += 0.01 * rng.gauss_f32();
        }
        let h = random_spd(16, 8);
        let r1 = relative_proxy_loss(&w, &w_hat, &h);
        let mut w2 = w.clone();
        let mut w_hat2 = w_hat.clone();
        w2.scale(10.0);
        w_hat2.scale(10.0);
        let r2 = relative_proxy_loss(&w2, &w_hat2, &h);
        assert!((r1 - r2).abs() < 1e-6 + 1e-3 * r1);
    }
}
