//! BlockLDLQ adaptive rounding (paper Algorithm 5, §4, §A.2).
//!
//! Walks the columns of `W` in `T_y`-wide blocks from right to left; each block is
//! rounded *after* adding the LDL feedback of the error committed on already-rounded
//! blocks: `x = W_j + (W_{>j} − Ŵ_{>j}) · A_{>j,j}` with `A = L − I` from the
//! `T_y`-block LDL decomposition `H = L D Lᵀ`.
//!
//! The inner rounder is pluggable ([`BlockRounder`]): QTIP's trellis quantizer
//! (`quant::QtipRounder`), the E8P VQ proxy, or scalar Lloyd–Max (≈GPTQ). This
//! isolates exactly the variable the paper studies — *what to round with*.

use crate::util::matrix::{gemm, Matrix};

use super::super::util::linalg::block_ldl;

/// A rounding backend for one `m × T_y` column block.
pub trait BlockRounder {
    /// Block width T_y (must divide the Hessian dimension).
    fn ty(&self) -> usize;
    /// Round block `j` (block-column index, counted from the left) of the matrix.
    /// Returns the reconstruction (same shape as `x`).
    fn round_block(&mut self, j: usize, x: &Matrix) -> Matrix;
}

/// Run BlockLDLQ. `h` must already be SPD (see `linalg::regularize_spd`).
/// Returns Ŵ.
pub fn block_ldlq(w: &Matrix, h: &Matrix, rounder: &mut dyn BlockRounder) -> Matrix {
    let (m, n) = (w.rows, w.cols);
    assert_eq!(h.rows, n);
    assert_eq!(h.cols, n);
    let ty = rounder.ty();
    assert!(n % ty == 0, "T_y={ty} must divide n={n}");
    let nb = n / ty;

    let (l, _d) = block_ldl(h, ty).expect("Hessian must be SPD (regularize first)");
    // A = L - I; only the strictly-below-block part of each block column is used.
    let mut w_hat = Matrix::zeros(m, n);
    // Error on already-processed (right-side) columns: E = W - Ŵ, zero elsewhere.
    let mut err = Matrix::zeros(m, n);

    for j in (0..nb).rev() {
        let c0 = j * ty;
        let c1 = c0 + ty;
        // Feedback: x = W_j + E_{:, c1:} @ L[c1:, c0:c1]  (A's diagonal block is 0).
        let mut x = w.col_block(c0, c1);
        if c1 < n {
            let e_right = err.col_block(c1, n); // m × (n - c1)
            let mut a_block = Matrix::zeros(n - c1, ty);
            for r in c1..n {
                for c in c0..c1 {
                    *a_block.at_mut(r - c1, c - c0) = l.at(r, c);
                }
            }
            gemm(&e_right, &a_block, &mut x); // x += E_right @ A_block
        }
        let x_hat = rounder.round_block(j, &x);
        assert_eq!(x_hat.rows, m);
        assert_eq!(x_hat.cols, ty);
        w_hat.set_col_block(c0, &x_hat);
        // Error feedback uses (x - x_hat): the *adjusted* target minus its rounding.
        let mut e_blk = x;
        e_blk.axpy(-1.0, &x_hat);
        err.set_col_block(c0, &e_blk);
    }
    w_hat
}

/// A trivial rounder that applies a scalar quantization function entrywise —
/// used by tests and the GPTQ-like scalar baseline.
pub struct ScalarRounder<F: Fn(f32) -> f32> {
    pub ty: usize,
    pub f: F,
}

impl<F: Fn(f32) -> f32> BlockRounder for ScalarRounder<F> {
    fn ty(&self) -> usize {
        self.ty
    }

    fn round_block(&mut self, _j: usize, x: &Matrix) -> Matrix {
        let mut out = x.clone();
        for v in out.data.iter_mut() {
            *v = (self.f)(*v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::proxy::proxy_loss;
    use crate::util::linalg::regularize_spd;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        // A "realistic" Hessian: correlated activations.
        let a = Matrix::gaussian(n, 2 * n, 1.0, &mut rng);
        let mut h = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..2 * n {
                    s += a.at(i, k) * a.at(j, k) * (1.0 + 0.5 * (k % 7) as f32);
                }
                *h.at_mut(i, j) = s / (2 * n) as f32;
            }
        }
        regularize_spd(&h, 0.01)
    }

    fn round_to_grid(step: f32) -> impl Fn(f32) -> f32 {
        move |x| (x / step).round() * step
    }

    #[test]
    fn exact_rounder_is_identity() {
        let mut rng = Rng::new(1);
        let w = Matrix::gaussian(8, 16, 1.0, &mut rng);
        let h = random_spd(16, 2);
        let mut r = ScalarRounder { ty: 4, f: |x| x };
        let w_hat = block_ldlq(&w, &h, &mut r);
        for (a, b) in w_hat.data.iter().zip(&w.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn beats_round_to_nearest_on_proxy_loss() {
        // The whole point of LDLQ: error feedback lowers tr(ΔHΔᵀ) vs naive RTN.
        let mut rng = Rng::new(3);
        let w = Matrix::gaussian(16, 32, 1.0, &mut rng);
        let h = random_spd(32, 4);
        let step = 0.5f32;

        let mut ldlq = ScalarRounder { ty: 4, f: round_to_grid(step) };
        let w_ldlq = block_ldlq(&w, &h, &mut ldlq);

        let mut w_rtn = w.clone();
        for v in w_rtn.data.iter_mut() {
            *v = (*v / step).round() * step;
        }

        let loss_ldlq = proxy_loss(&w, &w_ldlq, &h);
        let loss_rtn = proxy_loss(&w, &w_rtn, &h);
        assert!(
            loss_ldlq < loss_rtn,
            "LDLQ {loss_ldlq} must beat RTN {loss_rtn}"
        );
    }

    #[test]
    fn ldlq_recursion_invariant() {
        // Ŵ_j == Q(W_j + (W−Ŵ)_{>j} A_{>j,j}) exactly, block by block.
        let mut rng = Rng::new(5);
        let n = 24;
        let w = Matrix::gaussian(6, n, 1.0, &mut rng);
        let h = random_spd(n, 6);
        let ty = 4;
        let step = 0.25f32;
        let mut r = ScalarRounder { ty, f: round_to_grid(step) };
        let w_hat = block_ldlq(&w, &h, &mut r);

        // Recompute the feedback trajectory independently.
        let (l, _) = crate::util::linalg::block_ldl(&h, ty).unwrap();
        let err_full = {
            let mut e = w.clone();
            e.axpy(-1.0, &w_hat);
            e
        };
        // err as produced uses adjusted targets; recompute x_j from scratch:
        let nb = n / ty;
        let mut err_adj = Matrix::zeros(6, n);
        for j in (0..nb).rev() {
            let c0 = j * ty;
            let c1 = c0 + ty;
            let mut x = w.col_block(c0, c1);
            if c1 < n {
                let e_right = err_adj.col_block(c1, n);
                let mut a_block = Matrix::zeros(n - c1, ty);
                for rr in c1..n {
                    for cc in c0..c1 {
                        *a_block.at_mut(rr - c1, cc - c0) = l.at(rr, cc);
                    }
                }
                gemm(&e_right, &a_block, &mut x);
            }
            // Ŵ_j must equal Q(x).
            for rr in 0..6 {
                for cc in 0..ty {
                    let q = (x.at(rr, cc) / step).round() * step;
                    assert!(
                        (q - w_hat.at(rr, c0 + cc)).abs() < 1e-4,
                        "block {j} ({rr},{cc})"
                    );
                }
            }
            let mut e_blk = x;
            e_blk.axpy(-1.0, &w_hat.col_block(c0, c1));
            err_adj.set_col_block(c0, &e_blk);
        }
        let _ = err_full;
    }

    #[test]
    fn ty_must_divide_n() {
        let w = Matrix::zeros(4, 10);
        let h = Matrix::identity(10);
        let mut r = ScalarRounder { ty: 4, f: |x| x };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            block_ldlq(&w, &h, &mut r)
        }));
        assert!(result.is_err());
    }

    #[test]
    fn identity_hessian_equals_rtn() {
        // With H = I there is no correlation to exploit: LDLQ == RTN exactly.
        let mut rng = Rng::new(7);
        let w = Matrix::gaussian(4, 12, 1.0, &mut rng);
        let h = Matrix::identity(12);
        let step = 0.5;
        let mut r = ScalarRounder { ty: 4, f: round_to_grid(step) };
        let w_hat = block_ldlq(&w, &h, &mut r);
        for (a, &b) in w_hat.data.iter().zip(&w.data) {
            assert_eq!(*a, (b / step).round() * step);
        }
    }
}
