//! The QTIP quantization pipeline: incoherence processing → BlockLDLQ → tail-biting
//! trellis coding → packed inference artifact.
//!
//! `quantize_matrix_qtip` is the library entry point used by the coordinator's
//! per-layer jobs; `QuantizedMatrix` is the self-contained inference artifact
//! (packed bits + code spec + RHT signs + scale) whose `matvec` is the serving hot
//! path. Baseline pipelines (`quantize_matrix_baseline`) share the identical RHT +
//! BlockLDLQ wrapper and differ only in the inner rounder, mirroring the paper's
//! experimental control.

pub mod incoherence;
pub mod kernel;
pub mod ldlq;
pub mod method;
pub mod proxy;
pub mod registry;

pub use incoherence::RhtContext;
pub use kernel::{KernelKind, LANES};
pub use ldlq::{block_ldlq, BlockRounder, ScalarRounder};
pub use method::{
    CodeSpec, KernelCall, MethodBuild, MethodInfo, QuantMethod, TableSink, TableSource,
};

use crate::baselines::{E8Rvq, LloydMax};
use crate::codes::Code;
use crate::trellis::packing::{decode_window, pack_states, pad_for_decode};
use crate::trellis::{quantize_tail_biting, Trellis, Viterbi, ViterbiWorkspace};
use crate::util::linalg::regularize_spd;
use crate::util::matrix::Matrix;
use crate::util::threadpool::ExecPool;
use crate::util::Timer;

/// Configuration of a QTIP quantization run.
#[derive(Clone, Debug)]
pub struct QtipConfig {
    /// Trellis: log2 states.
    pub l: u32,
    /// Bits per weight.
    pub k: u32,
    /// Code vector dimension.
    pub v: u32,
    /// Tile rows (output dim); the paper uses 16 to match an MMA tile.
    pub tx: usize,
    /// Tile cols (input dim) = BlockLDLQ group size.
    pub ty: usize,
    /// Registry method name (see `quant::registry::names()`), e.g. "1mad",
    /// "3inst", "hyb", "lut", "vptq".
    pub code: String,
    pub seed: u64,
}

impl QtipConfig {
    /// The paper's headline configuration (§4.1): 3INST, L=16, k bits, 16×16 tiles.
    pub fn paper_default(k: u32) -> Self {
        QtipConfig {
            l: 16,
            k,
            v: 1,
            tx: 16,
            ty: 16,
            code: "3inst".into(),
            seed: 0x51_71_50, // "QTIP"
        }
    }
}

/// Quantization metrics recorded per matrix (rolled up into EXPERIMENTS.md).
#[derive(Clone, Copy, Debug, Default)]
pub struct QuantMetrics {
    /// tr(ΔH̃Δᵀ)/tr(W̃H̃W̃ᵀ) in the incoherent space.
    pub relative_proxy: f64,
    /// Plain MSE between W̃ and its reconstruction (normalized space).
    pub mse: f64,
    /// Achieved bits per weight (excludes the O(m+n) sign/scale side info).
    pub bits_per_weight: f64,
    pub seconds: f64,
}

impl QuantMetrics {
    /// Manifest form (quantized-artifact persistence; see `crate::io`).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("relative_proxy", Json::Num(self.relative_proxy)),
            ("mse", Json::Num(self.mse)),
            ("bits_per_weight", Json::Num(self.bits_per_weight)),
            ("seconds", Json::Num(self.seconds)),
        ])
    }

    pub fn from_json(j: &crate::util::json::Json) -> QuantMetrics {
        QuantMetrics {
            relative_proxy: j.req_f64("relative_proxy"),
            mse: j.req_f64("mse"),
            bits_per_weight: j.req_f64("bits_per_weight"),
            seconds: j.req_f64("seconds"),
        }
    }
}

/// A quantized linear layer: self-contained decode artifact.
#[derive(Clone, Debug)]
pub struct QuantizedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub tx: usize,
    pub ty: usize,
    pub trellis: Trellis,
    pub code: CodeSpec,
    /// Global scale restoring the original weight magnitude.
    pub scale: f32,
    pub rht: RhtContext,
    /// Words per packed tile (padded-for-decode layout).
    pub tile_words: usize,
    /// `(rows/tx) × (cols/ty)` tiles, row-major, `tile_words` u32 each.
    pub packed: Vec<u32>,
    pub metrics: QuantMetrics,
    /// Decode-kernel family the matvec hot path dispatches to (resolved —
    /// never `Auto`). Chosen per matrix at quantize/load time from
    /// `--kernel` > `QTIP_KERNEL` > auto; both families are bit-identical,
    /// so flipping it never changes outputs (`tests/kernel_parity.rs`).
    pub kernel: KernelKind,
}

/// Raw write handle for the batch accumulator (`B × rows`, row-major): the
/// tile-parallel multi kernels write disjoint column ranges of `y` (band
/// `[bi0, bi1)` owns rows `[bi0·tx, bi1·tx)` of Ŵ, i.e. columns of `y`),
/// which are not contiguous in memory, so bands share the matrix through a
/// pointer instead of slice splits.
#[derive(Clone, Copy)]
struct YCells {
    ptr: *mut f32,
    /// Row length of the accumulator = output dim of the layer.
    stride: usize,
}

// SAFETY: the handle is a pointer + stride into an `f32` matrix the caller
// exclusively borrows for the whole dispatch; moving it to a worker moves
// only the address, and `f32: Send`.
unsafe impl Send for YCells {}
// SAFETY: shared `&YCells` access is race-free by the `add` contract — every
// writer touches a distinct (b, row) address, because bands own disjoint
// `row` ranges and each band index is claimed exactly once (`ExecPool::run`).
unsafe impl Sync for YCells {}

impl YCells {
    fn of(y: &mut Matrix) -> YCells {
        YCells { ptr: y.data.as_mut_ptr(), stride: y.cols }
    }

    /// `y[b][row] += v`.
    ///
    /// # Safety
    /// `b` must be in-batch and `row` in-matrix (so the address is inside the
    /// borrowed accumulator), and the calling band must own `row` for the
    /// duration of the dispatch — no other thread may touch `(·, row)` cells.
    #[inline]
    unsafe fn add(&self, b: usize, row: usize, v: f32) {
        // SAFETY: caller contract — in-bounds address, exclusively owned via
        // the band partition while the dispatch runs.
        unsafe { *self.ptr.add(b * self.stride + row) += v };
    }
}

/// Batch-column chunk width of the multi kernels: accumulators live in a
/// fixed stack array (no per-call `vec!` churn); batches wider than this are
/// processed in independent column chunks, which re-reads the packed stream
/// once per chunk but never changes any per-(sequence, row) accumulation
/// order — outputs stay bit-identical at every batch size.
const BCHUNK: usize = 16;

thread_local! {
    /// Per-thread RHT'd-activation scratch behind the convenience
    /// [`QuantizedMatrix::matvec`] wrapper: reused across calls so the
    /// non-pool entry point performs no per-call activation allocation.
    static MATVEC_XT: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

impl QuantizedMatrix {
    #[inline]
    pub fn tiles_r(&self) -> usize {
        self.rows / self.tx
    }

    #[inline]
    pub fn tiles_c(&self) -> usize {
        self.cols / self.ty
    }

    /// Total artifact bytes (packed bits + LUT + signs + scale).
    pub fn size_bytes(&self) -> usize {
        self.packed.len() * 4
            + self.code.decoder_table_bytes()
            + (self.rows + self.cols).div_ceil(8)
            + 4
    }

    #[inline]
    fn tile_offset(&self, bi: usize, bj: usize) -> usize {
        (bi * self.tiles_c() + bj) * self.tile_words
    }

    /// Decode tile (bi, bj) into `out` (tx*ty values, row-major, scaled).
    pub fn decode_tile(&self, bi: usize, bj: usize, out: &mut [f32]) {
        let t = self.tx * self.ty;
        assert_eq!(out.len(), t);
        let words = &self.packed[self.tile_offset(bi, bj)..];
        let kv = self.trellis.step_bits() as usize;
        let l = self.trellis.l;
        let v = self.trellis.v as usize;
        let mut buf = [0.0f32; 8];
        for step in 0..t / v {
            let state = decode_window(words, step * kv, l);
            self.code.decode(state, &mut buf[..v]);
            for i in 0..v {
                out[step * v + i] = buf[i] * self.scale;
            }
        }
    }

    /// Reconstruct the full incoherent-space weight matrix W̃̂ (eval/debug path).
    pub fn reconstruct_wtilde(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        let mut tile = vec![0.0f32; self.tx * self.ty];
        for bi in 0..self.tiles_r() {
            for bj in 0..self.tiles_c() {
                self.decode_tile(bi, bj, &mut tile);
                for r in 0..self.tx {
                    for c in 0..self.ty {
                        *m.at_mut(bi * self.tx + r, bj * self.ty + c) =
                            tile[r * self.ty + c];
                    }
                }
            }
        }
        m
    }

    /// Reconstruct the original-space weights (undoes the RHT) — for parity tests.
    pub fn reconstruct_w(&self) -> Matrix {
        self.rht.restore_weight(&self.reconstruct_wtilde())
    }

    /// Full quantized matvec: y = Ŵ x including the RHT sandwich.
    ///
    /// Convenience wrapper over the scratch-based [`Self::matvec_into`] path
    /// (width-1 shared pool, per-thread activation scratch), so the non-pool
    /// entry point no longer pays a per-call `x.to_vec()` — there is exactly
    /// one RHT-sandwich implementation, and this one allocates only the
    /// returned `y`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0f32; self.rows];
        MATVEC_XT.with(|xt| {
            self.matvec_into(x, &mut y, &mut xt.borrow_mut(), ExecPool::shared_sequential());
        });
        y
    }

    /// The decode-fused matvec hot path in incoherent space: y += Ŵ̃ x̃.
    ///
    /// Monomorphized per code so the per-weight decode inlines to the handful of
    /// ALU ops the paper counts (§3.1.1). See `EXPERIMENTS.md` §Perf.
    pub fn matvec_tilde(&self, xt: &[f32], y: &mut [f32]) {
        assert_eq!(xt.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        self.tilde_band(0, self.tiles_r(), xt, y);
    }

    /// Tile-parallel `matvec_tilde`: disjoint row-tile bands of `y` are striped
    /// across the pool's workers, with bands sized to whole lane blocks
    /// ([`kernel::lane_band_tiles`]) so the lane-blocked kernels never split a
    /// block across workers. Within each output row the accumulation order
    /// over column tiles is unchanged (the band kernel *is* the sequential
    /// kernel), so the result is bit-identical to [`Self::matvec_tilde`] at any
    /// worker count.
    pub fn matvec_tilde_pool(&self, xt: &[f32], y: &mut [f32], pool: &ExecPool) {
        assert_eq!(xt.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let tiles_r = self.tiles_r();
        let band_tiles = kernel::lane_band_tiles(self.tx);
        if pool.width() <= 1 || tiles_r <= band_tiles {
            return self.tilde_band(0, tiles_r, xt, y);
        }
        pool.run_chunks(y, self.tx * band_tiles, |i, band| {
            let bi0 = i * band_tiles;
            self.tilde_band(bi0, (bi0 + band_tiles).min(tiles_r), xt, band)
        });
    }

    /// Single-column kernel over tile-row band `[bi0, bi1)`; `y` holds exactly
    /// the output rows `[bi0·tx, bi1·tx)`. The owning [`QuantMethod`] completes
    /// the call with its decode closures ([`KernelCall::run_v1`] /
    /// [`KernelCall::run_v2`] route to the scalar or lane-blocked family from
    /// [`Self::kernel`] — bit-identical by construction). One dyn call per
    /// band; the hot loops monomorphize inside the method's module.
    fn tilde_band(&self, bi0: usize, bi1: usize, xt: &[f32], y: &mut [f32]) {
        self.code.method().run_kernel(&self.code, KernelCall::tilde(self, bi0, bi1, xt, y));
    }

    #[inline]
    fn matvec_tilde_v1<F: Fn(u32) -> f32>(
        &self,
        bi0: usize,
        bi1: usize,
        xt: &[f32],
        y: &mut [f32],
        decode: F,
    ) {
        let k = self.trellis.k as usize;
        let l = self.trellis.l;
        let (tx, ty) = (self.tx, self.ty);
        let mask = (1u64 << l) - 1;
        for bi in bi0..bi1 {
            for bj in 0..self.tiles_c() {
                let words = &self.packed
                    [self.tile_offset(bi, bj)..self.tile_offset(bi, bj) + self.tile_words];
                let xs = &xt[bj * ty..(bj + 1) * ty];
                let ys = &mut y[(bi - bi0) * tx..(bi - bi0 + 1) * tx];
                // Rolling 64-bit window buffer: one u32 load per 32 bits of
                // stream instead of an unaligned 64-bit assembly per weight
                // (§Perf optimization #1 — see EXPERIMENTS.md).
                let mut bit = 0usize;
                for yr in ys.iter_mut() {
                    let mut acc = 0.0f32;
                    let mut w = bit >> 5;
                    let mut sh = bit & 31;
                    let mut buf = (words[w] as u64) | ((words[w + 1] as u64) << 32);
                    buf >>= sh;
                    let mut avail = 64 - sh;
                    for &xv in xs.iter() {
                        if avail < l as usize {
                            // Refill: re-anchor at the current absolute bit.
                            let abs = bit;
                            w = abs >> 5;
                            sh = abs & 31;
                            buf = (words[w] as u64) | ((words[w + 1] as u64) << 32);
                            buf >>= sh;
                            avail = 64 - sh;
                        }
                        let state = (buf & mask) as u32;
                        acc += decode(state) * xv;
                        buf >>= k;
                        avail -= k;
                        bit += k;
                    }
                    *yr += acc * self.scale;
                }
            }
        }
    }

    /// Batch-fused full matvec: Y = Ŵ X for B activation rows, RHT sandwich
    /// included. `x` is `B × cols` (one activation per row); returns `B × rows`.
    ///
    /// Row `b` of the result is bit-identical to `self.matvec(x.row(b))` — the
    /// fusion only amortizes the packed-weight decode, never reorders the
    /// per-sequence float accumulation.
    pub fn matvec_multi(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols, self.cols);
        let mut xt = x.clone();
        for r in 0..xt.rows {
            self.rht.forward_activations(xt.row_mut(r));
        }
        let mut y = Matrix::zeros(x.rows, self.rows);
        self.matvec_tilde_multi(&xt, &mut y);
        for r in 0..y.rows {
            self.rht.restore_outputs(y.row_mut(r));
        }
        y
    }

    /// Allocation-free full matvec: `y = Ŵ x` including the RHT sandwich, with
    /// the decode striped across `pool` and the activation copy staged in the
    /// caller's scratch buffer. Bit-identical to [`Self::matvec`].
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32], xt: &mut Vec<f32>, pool: &ExecPool) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        xt.clear();
        xt.extend_from_slice(x);
        self.rht.forward_activations(xt);
        y.fill(0.0);
        self.matvec_tilde_pool(xt, y, pool);
        self.rht.restore_outputs(y);
    }

    /// Allocation-free batch-fused matvec: `Y = Ŵ X` with the RHT sandwich,
    /// reusing caller scratch for the RHT'd activations (`bxt`) and their
    /// column-major transpose (`xcol`). `y` is reshaped to `B × rows` in place.
    /// Row `b` is bit-identical to `matvec(x.row(b))` at any worker count.
    pub fn matvec_multi_into(
        &self,
        x: &Matrix,
        y: &mut Matrix,
        bxt: &mut Matrix,
        xcol: &mut Vec<f32>,
        pool: &ExecPool,
    ) {
        assert_eq!(x.cols, self.cols);
        bxt.reshape_scratch(x.rows, x.cols);
        bxt.data.copy_from_slice(&x.data);
        for r in 0..bxt.rows {
            self.rht.forward_activations(bxt.row_mut(r));
        }
        y.reshape_scratch(x.rows, self.rows);
        y.data.fill(0.0);
        self.matvec_tilde_multi_pool(bxt, y, xcol, pool);
        for r in 0..y.rows {
            self.rht.restore_outputs(y.row_mut(r));
        }
    }

    /// Batch-fused decode matvec in incoherent space: Y += Ŵ̃ X̃ for a `B × cols`
    /// activation matrix `xt` into a `B × rows` accumulator `y`.
    ///
    /// The serving-batch amortization (Table 4 batch sweep): each trellis state
    /// is decoded **once** per call and applied to all B activation columns, so
    /// the packed weight stream is read once per decode round instead of once
    /// per sequence. Monomorphized per code like the single-column kernels; the
    /// per-(b, row) accumulation order matches `matvec_tilde` exactly so the
    /// fused path stays bit-identical to B independent matvecs (§Perf
    /// optimization #3 — see EXPERIMENTS.md).
    pub fn matvec_tilde_multi(&self, xt: &Matrix, y: &mut Matrix) {
        assert_eq!(xt.cols, self.cols);
        assert_eq!(y.cols, self.rows);
        assert_eq!(xt.rows, y.rows, "batch dims must agree");
        let mut xcol = Vec::new();
        xt.transpose_into(&mut xcol);
        let cells = YCells::of(y);
        self.multi_band(0, self.tiles_r(), &xcol, xt.rows, cells);
    }

    /// Tile-parallel batch-fused decode: row-tile bands of the accumulator are
    /// striped across `pool` in whole-lane-block bands
    /// ([`kernel::lane_band_tiles`], via [`ExecPool::run_bands`]), the
    /// transposed activations are staged in the caller's `xcol` scratch
    /// (replacing the per-call `transpose()` allocation). Bit-identical to
    /// [`Self::matvec_tilde_multi`] at any worker count — the band kernel is
    /// the sequential kernel.
    pub fn matvec_tilde_multi_pool(
        &self,
        xt: &Matrix,
        y: &mut Matrix,
        xcol: &mut Vec<f32>,
        pool: &ExecPool,
    ) {
        assert_eq!(xt.cols, self.cols);
        assert_eq!(y.cols, self.rows);
        assert_eq!(xt.rows, y.rows, "batch dims must agree");
        xt.transpose_into(xcol);
        let cells = YCells::of(y);
        let b = xt.rows;
        let tiles_r = self.tiles_r();
        let band_tiles = kernel::lane_band_tiles(self.tx);
        if pool.width() <= 1 || tiles_r <= band_tiles {
            return self.multi_band(0, tiles_r, xcol, b, cells);
        }
        let xcol: &[f32] = xcol;
        pool.run_bands(tiles_r, band_tiles, |bi0, bi1| {
            self.multi_band(bi0, bi1, xcol, b, cells)
        });
    }

    /// Batch kernel over tile-row band `[bi0, bi1)` — owns output rows
    /// `[bi0·tx, bi1·tx)` of every batch column of `y`. Dispatched to the
    /// owning [`QuantMethod`] like [`Self::tilde_band`].
    fn multi_band(&self, bi0: usize, bi1: usize, xcol: &[f32], nb: usize, y: YCells) {
        self.code.method().run_kernel(&self.code, KernelCall::multi(self, bi0, bi1, xcol, nb, y));
    }

    #[inline]
    fn matvec_tilde_multi_v1<F: Fn(u32) -> f32>(
        &self,
        bi0: usize,
        bi1: usize,
        xcol: &[f32],
        nb: usize,
        y: YCells,
        decode: F,
    ) {
        let k = self.trellis.k as usize;
        let l = self.trellis.l;
        let (tx, ty) = (self.tx, self.ty);
        let mask = (1u64 << l) - 1;
        // Column-major activations (cols × B) so the per-decoded-weight inner
        // loop over the batch is unit-stride; accumulators live on the stack.
        for b0 in (0..nb).step_by(BCHUNK) {
            let bc = (nb - b0).min(BCHUNK);
            let mut acc = [0.0f32; BCHUNK];
            for bi in bi0..bi1 {
                for bj in 0..self.tiles_c() {
                    let words = &self.packed
                        [self.tile_offset(bi, bj)..self.tile_offset(bi, bj) + self.tile_words];
                    let x0 = bj * ty;
                    // Same rolling 64-bit window as the single-column kernel;
                    // each decoded weight now feeds `bc` accumulators.
                    let mut bit = 0usize;
                    for r in 0..tx {
                        acc[..bc].fill(0.0);
                        let mut w = bit >> 5;
                        let mut sh = bit & 31;
                        let mut buf = (words[w] as u64) | ((words[w + 1] as u64) << 32);
                        buf >>= sh;
                        let mut avail = 64 - sh;
                        for c in 0..ty {
                            if avail < l as usize {
                                let abs = bit;
                                w = abs >> 5;
                                sh = abs & 31;
                                buf = (words[w] as u64) | ((words[w + 1] as u64) << 32);
                                buf >>= sh;
                                avail = 64 - sh;
                            }
                            let state = (buf & mask) as u32;
                            let wv = decode(state);
                            let base = (x0 + c) * nb + b0;
                            let xs = &xcol[base..base + bc];
                            for (a, &xv) in acc[..bc].iter_mut().zip(xs) {
                                *a += wv * xv;
                            }
                            buf >>= k;
                            avail -= k;
                            bit += k;
                        }
                        let row = bi * tx + r;
                        for (bb, &a) in acc[..bc].iter().enumerate() {
                            // SAFETY: this band owns rows [bi0*tx, bi1*tx).
                            unsafe { y.add(b0 + bb, row, a * self.scale) };
                        }
                    }
                }
            }
        }
    }

    #[inline]
    fn matvec_tilde_multi_v2<F: Fn(u32) -> (f32, f32)>(
        &self,
        bi0: usize,
        bi1: usize,
        xcol: &[f32],
        nb: usize,
        y: YCells,
        decode: F,
    ) {
        let kv = (self.trellis.k * 2) as usize;
        let l = self.trellis.l;
        let (tx, ty) = (self.tx, self.ty);
        debug_assert_eq!(ty % 2, 0);
        for b0 in (0..nb).step_by(BCHUNK) {
            let bc = (nb - b0).min(BCHUNK);
            let mut acc = [0.0f32; BCHUNK];
            for bi in bi0..bi1 {
                for bj in 0..self.tiles_c() {
                    let words = &self.packed
                        [self.tile_offset(bi, bj)..self.tile_offset(bi, bj) + self.tile_words];
                    let x0 = bj * ty;
                    let mut bit = 0usize;
                    for r in 0..tx {
                        acc[..bc].fill(0.0);
                        for c in (0..ty).step_by(2) {
                            let state = decode_window(words, bit, l);
                            let (wa, wb) = decode(state);
                            let ba = (x0 + c) * nb + b0;
                            let bb = (x0 + c + 1) * nb + b0;
                            let xa = &xcol[ba..ba + bc];
                            let xb = &xcol[bb..bb + bc];
                            for ((a, &va), &vb) in acc[..bc].iter_mut().zip(xa).zip(xb) {
                                *a += wa * va + wb * vb;
                            }
                            bit += kv;
                        }
                        let row = bi * tx + r;
                        for (bb, &a) in acc[..bc].iter().enumerate() {
                            // SAFETY: this band owns rows [bi0*tx, bi1*tx).
                            unsafe { y.add(b0 + bb, row, a * self.scale) };
                        }
                    }
                }
            }
        }
    }

    #[inline]
    fn matvec_tilde_v2<F: Fn(u32) -> (f32, f32)>(
        &self,
        bi0: usize,
        bi1: usize,
        xt: &[f32],
        y: &mut [f32],
        decode: F,
    ) {
        let kv = (self.trellis.k * 2) as usize;
        let l = self.trellis.l;
        let (tx, ty) = (self.tx, self.ty);
        debug_assert_eq!(ty % 2, 0);
        for bi in bi0..bi1 {
            for bj in 0..self.tiles_c() {
                let words = &self.packed
                    [self.tile_offset(bi, bj)..self.tile_offset(bi, bj) + self.tile_words];
                let xs = &xt[bj * ty..(bj + 1) * ty];
                let ys = &mut y[(bi - bi0) * tx..(bi - bi0 + 1) * tx];
                let mut bit = 0usize;
                for yr in ys.iter_mut() {
                    let mut acc = 0.0f32;
                    for c in (0..ty).step_by(2) {
                        let state = decode_window(words, bit, l);
                        let (a, b) = decode(state);
                        acc += a * xs[c] + b * xs[c + 1];
                        bit += kv;
                    }
                    *yr += acc * self.scale;
                }
            }
        }
    }

    /// Per-lane packed-stream slices and base bit cursors for the lane block
    /// starting at band-local row `r0` (`block` live rows; lanes past the
    /// block repeat its last row so remainder blocks run the same lockstep
    /// code — their outputs are simply discarded). `row_bits` is the stream
    /// distance between consecutive tile rows (`ty·k` bits for every V).
    #[inline]
    fn lane_cursors(
        &self,
        bi0: usize,
        bj: usize,
        r0: usize,
        block: usize,
        row_bits: usize,
    ) -> ([&[u32]; LANES], [usize; LANES]) {
        let mut words: [&[u32]; LANES] = [&[]; LANES];
        let mut base = [0usize; LANES];
        for (j, (w, b)) in words.iter_mut().zip(base.iter_mut()).enumerate() {
            let row = r0 + j.min(block - 1);
            let off = self.tile_offset(bi0 + row / self.tx, bj);
            *w = &self.packed[off..off + self.tile_words];
            *b = (row % self.tx) * row_bits;
        }
        (words, base)
    }

    /// Lane-blocked single-column kernel over tile-row band `[bi0, bi1)`
    /// (§Perf optimization #2 — see EXPERIMENTS.md): [`LANES`] output rows
    /// advance in lockstep, each lane walking its own packed stream slice
    /// with its own bit cursor (cursors advance by `k` per weight for every
    /// lane, so they stay in lockstep by construction). The per-step
    /// `[u32; LANES]` state block is decoded by a lane-array evaluator that
    /// LLVM auto-vectorizes, and the per-lane FMAs against the shared `x`
    /// value vectorize with it. Each lane is a distinct output row, so every
    /// row's float accumulation order is exactly [`Self::matvec_tilde_v1`]'s
    /// — outputs are bit-identical to the scalar reference kernel.
    #[inline]
    fn matvec_tilde_lanes_v1<F: Fn([u32; LANES]) -> [f32; LANES]>(
        &self,
        bi0: usize,
        bi1: usize,
        xt: &[f32],
        y: &mut [f32],
        decode: F,
    ) {
        let k = self.trellis.k as usize;
        let l = self.trellis.l;
        let (tx, ty) = (self.tx, self.ty);
        let nrows = (bi1 - bi0) * tx;
        for bj in 0..self.tiles_c() {
            let xs = &xt[bj * ty..(bj + 1) * ty];
            let mut r0 = 0usize;
            while r0 < nrows {
                let block = LANES.min(nrows - r0);
                let (words, base) = self.lane_cursors(bi0, bj, r0, block, ty * k);
                let mut acc = [0.0f32; LANES];
                for (c, &xv) in xs.iter().enumerate() {
                    let bit = c * k;
                    let mut states = [0u32; LANES];
                    for (s, (w, b)) in states.iter_mut().zip(words.iter().zip(base.iter())) {
                        *s = decode_window(w, b + bit, l);
                    }
                    let wv = decode(states);
                    for (a, &v) in acc.iter_mut().zip(wv.iter()) {
                        *a += v * xv;
                    }
                }
                for (yr, &a) in y[r0..r0 + block].iter_mut().zip(acc.iter()) {
                    *yr += a * self.scale;
                }
                r0 += block;
            }
        }
    }

    /// Lane-blocked pair-decode kernel (V=2 codes): like
    /// [`Self::matvec_tilde_lanes_v1`], but each lockstep step decodes one
    /// state per lane into a weight *pair* applied to two `x` values — the
    /// exact op sequence of [`Self::matvec_tilde_v2`] per lane.
    #[inline]
    fn matvec_tilde_lanes_v2<F: Fn([u32; LANES]) -> ([f32; LANES], [f32; LANES])>(
        &self,
        bi0: usize,
        bi1: usize,
        xt: &[f32],
        y: &mut [f32],
        decode: F,
    ) {
        let kv = (self.trellis.k * 2) as usize;
        let l = self.trellis.l;
        let (tx, ty) = (self.tx, self.ty);
        debug_assert_eq!(ty % 2, 0);
        let nrows = (bi1 - bi0) * tx;
        let row_bits = (ty / 2) * kv;
        for bj in 0..self.tiles_c() {
            let xs = &xt[bj * ty..(bj + 1) * ty];
            let mut r0 = 0usize;
            while r0 < nrows {
                let block = LANES.min(nrows - r0);
                let (words, base) = self.lane_cursors(bi0, bj, r0, block, row_bits);
                let mut acc = [0.0f32; LANES];
                for c in (0..ty).step_by(2) {
                    let bit = (c / 2) * kv;
                    let mut states = [0u32; LANES];
                    for (s, (w, b)) in states.iter_mut().zip(words.iter().zip(base.iter())) {
                        *s = decode_window(w, b + bit, l);
                    }
                    let (wa, wb) = decode(states);
                    let (xa, xb) = (xs[c], xs[c + 1]);
                    for ((a, &va), &vb) in acc.iter_mut().zip(wa.iter()).zip(wb.iter()) {
                        *a += va * xa + vb * xb;
                    }
                }
                for (yr, &a) in y[r0..r0 + block].iter_mut().zip(acc.iter()) {
                    *yr += a * self.scale;
                }
                r0 += block;
            }
        }
    }

    /// Lane-blocked batch kernel: [`LANES`] rows in lockstep *and* the
    /// [`BCHUNK`]-wide batch inner loop of [`Self::matvec_tilde_multi_v1`] —
    /// each decoded `[f32; LANES]` weight block feeds `LANES × bc` stack
    /// accumulators, so both the lane FMAs and the unit-stride batch FMAs
    /// auto-vectorize. Per-(sequence, row) accumulation order matches the
    /// scalar batch kernel exactly.
    #[inline]
    fn matvec_tilde_multi_lanes_v1<F: Fn([u32; LANES]) -> [f32; LANES]>(
        &self,
        bi0: usize,
        bi1: usize,
        xcol: &[f32],
        nb: usize,
        y: YCells,
        decode: F,
    ) {
        let k = self.trellis.k as usize;
        let l = self.trellis.l;
        let (tx, ty) = (self.tx, self.ty);
        let nrows = (bi1 - bi0) * tx;
        for b0 in (0..nb).step_by(BCHUNK) {
            let bc = (nb - b0).min(BCHUNK);
            let mut acc = [[0.0f32; BCHUNK]; LANES];
            for bj in 0..self.tiles_c() {
                let x0 = bj * ty;
                let mut r0 = 0usize;
                while r0 < nrows {
                    let block = LANES.min(nrows - r0);
                    let (words, base) = self.lane_cursors(bi0, bj, r0, block, ty * k);
                    for a in acc.iter_mut() {
                        a[..bc].fill(0.0);
                    }
                    for c in 0..ty {
                        let bit = c * k;
                        let mut states = [0u32; LANES];
                        for (s, (w, b)) in states.iter_mut().zip(words.iter().zip(base.iter())) {
                            *s = decode_window(w, b + bit, l);
                        }
                        let wv = decode(states);
                        let xb = (x0 + c) * nb + b0;
                        let xs = &xcol[xb..xb + bc];
                        for (a, &w) in acc.iter_mut().zip(wv.iter()) {
                            for (av, &xv) in a[..bc].iter_mut().zip(xs) {
                                *av += w * xv;
                            }
                        }
                    }
                    for (j, a) in acc.iter().enumerate().take(block) {
                        let row = bi0 * tx + r0 + j;
                        for (bb, &v) in a[..bc].iter().enumerate() {
                            // SAFETY: this band owns rows [bi0*tx, bi1*tx).
                            unsafe { y.add(b0 + bb, row, v * self.scale) };
                        }
                    }
                    r0 += block;
                }
            }
        }
    }

    /// Lane-blocked batch pair-decode kernel (V=2 codes): the
    /// [`Self::matvec_tilde_multi_v2`] op sequence per lane, lane-blocked
    /// over rows and [`BCHUNK`]-vectorized over batch columns.
    #[inline]
    fn matvec_tilde_multi_lanes_v2<F: Fn([u32; LANES]) -> ([f32; LANES], [f32; LANES])>(
        &self,
        bi0: usize,
        bi1: usize,
        xcol: &[f32],
        nb: usize,
        y: YCells,
        decode: F,
    ) {
        let kv = (self.trellis.k * 2) as usize;
        let l = self.trellis.l;
        let (tx, ty) = (self.tx, self.ty);
        debug_assert_eq!(ty % 2, 0);
        let nrows = (bi1 - bi0) * tx;
        let row_bits = (ty / 2) * kv;
        for b0 in (0..nb).step_by(BCHUNK) {
            let bc = (nb - b0).min(BCHUNK);
            let mut acc = [[0.0f32; BCHUNK]; LANES];
            for bj in 0..self.tiles_c() {
                let x0 = bj * ty;
                let mut r0 = 0usize;
                while r0 < nrows {
                    let block = LANES.min(nrows - r0);
                    let (words, base) = self.lane_cursors(bi0, bj, r0, block, row_bits);
                    for a in acc.iter_mut() {
                        a[..bc].fill(0.0);
                    }
                    for c in (0..ty).step_by(2) {
                        let bit = (c / 2) * kv;
                        let mut states = [0u32; LANES];
                        for (s, (w, b)) in states.iter_mut().zip(words.iter().zip(base.iter())) {
                            *s = decode_window(w, b + bit, l);
                        }
                        let (wa, wb) = decode(states);
                        let xa0 = (x0 + c) * nb + b0;
                        let xb0 = (x0 + c + 1) * nb + b0;
                        let xa = &xcol[xa0..xa0 + bc];
                        let xb = &xcol[xb0..xb0 + bc];
                        for ((a, &va), &vb) in acc.iter_mut().zip(wa.iter()).zip(wb.iter()) {
                            for ((av, &x1), &x2) in a[..bc].iter_mut().zip(xa).zip(xb) {
                                *av += va * x1 + vb * x2;
                            }
                        }
                    }
                    for (j, a) in acc.iter().enumerate().take(block) {
                        let row = bi0 * tx + r0 + j;
                        for (bb, &v) in a[..bc].iter().enumerate() {
                            // SAFETY: this band owns rows [bi0*tx, bi1*tx).
                            unsafe { y.add(b0 + bb, row, v * self.scale) };
                        }
                    }
                    r0 += block;
                }
            }
        }
    }
}

impl QuantizedMatrix {
    /// Build a synthetic quantized matrix with *random* packed bits (any cyclic
    /// bitstring is a valid tail-biting walk) — used by the throughput benches
    /// (Table 4/17), where only decode speed matters, not quality.
    pub fn synthetic(
        rows: usize,
        cols: usize,
        trellis: Trellis,
        code: CodeSpec,
        tx: usize,
        ty: usize,
        seed: u64,
    ) -> QuantizedMatrix {
        assert_eq!(rows % tx, 0);
        assert_eq!(cols % ty, 0);
        let steps = (tx * ty) / trellis.v as usize;
        let total_bits = steps * trellis.step_bits() as usize;
        let padded_bits = total_bits + (trellis.l - trellis.step_bits()) as usize;
        let tile_words = padded_bits.div_ceil(32) + 1;
        let tiles = (rows / tx) * (cols / ty);
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut packed = vec![0u32; tiles * tile_words];
        let packed_words = total_bits.div_ceil(32);
        for t in 0..tiles {
            let base = t * tile_words;
            for w in 0..packed_words {
                packed[base + w] = rng.next_u32();
            }
            let extra = packed_words * 32 - total_bits;
            if extra > 0 {
                packed[base + packed_words - 1] &= (1u32 << (32 - extra)) - 1;
            }
            // Re-create the pad: duplicate head L-kV bits after the stream end.
            let words: Vec<u32> = packed[base..base + packed_words].to_vec();
            let padded =
                crate::trellis::packing::pad_for_decode(&trellis, &words, steps);
            packed[base..base + padded.len()].copy_from_slice(&padded);
        }
        QuantizedMatrix {
            rows,
            cols,
            tx,
            ty,
            trellis,
            code,
            scale: 1.0,
            rht: RhtContext::new(rows, cols, seed),
            tile_words,
            packed,
            metrics: QuantMetrics::default(),
            kernel: kernel::selected_resolved(),
        }
    }
}

/// QTIP's BlockLDLQ inner rounder: tail-biting Viterbi over `T_x × T_y` tiles.
pub struct QtipRounder {
    trellis: Trellis,
    values: Vec<f32>,
    tx: usize,
    ty: usize,
    rows: usize,
    tiles_c: usize,
    tile_words: usize,
    ws: ViterbiWorkspace,
    /// Packed tiles, written as blocks are rounded.
    pub packed: Vec<u32>,
}

impl QtipRounder {
    pub fn new(
        trellis: Trellis,
        code: &dyn Code,
        rows: usize,
        cols: usize,
        tx: usize,
        ty: usize,
    ) -> Self {
        assert_eq!(rows % tx, 0, "tx={tx} must divide rows={rows}");
        assert_eq!(cols % ty, 0, "ty={ty} must divide cols={cols}");
        assert_eq!((tx * ty) % trellis.v as usize, 0);
        let steps = (tx * ty) / trellis.v as usize;
        assert!(
            steps as u32 * trellis.step_bits() >= trellis.l,
            "tile too small for tail-biting at this (L,k,V)"
        );
        let total_bits = steps * trellis.step_bits() as usize;
        let padded_bits = total_bits + (trellis.l - trellis.step_bits()) as usize;
        let tile_words = padded_bits.div_ceil(32) + 1;
        let tiles_r = rows / tx;
        let tiles_c = cols / ty;
        QtipRounder {
            trellis,
            values: code.materialize(),
            tx,
            ty,
            rows,
            tiles_c,
            tile_words,
            ws: ViterbiWorkspace::new(),
            packed: vec![0u32; tiles_r * tiles_c * tile_words],
        }
    }

    pub fn tile_words(&self) -> usize {
        self.tile_words
    }
}

impl BlockRounder for QtipRounder {
    fn ty(&self) -> usize {
        self.ty
    }

    fn round_block(&mut self, j: usize, x: &Matrix) -> Matrix {
        assert_eq!(x.rows, self.rows);
        assert_eq!(x.cols, self.ty);
        let vit = Viterbi::new(self.trellis, &self.values);
        let mut out = Matrix::zeros(x.rows, x.cols);
        let t = self.tx * self.ty;
        let mut seq = vec![0.0f32; t];
        for bi in 0..self.rows / self.tx {
            // Flatten the tile row-major into one trellis sequence.
            for r in 0..self.tx {
                seq[r * self.ty..(r + 1) * self.ty]
                    .copy_from_slice(x.row(bi * self.tx + r));
            }
            let sol = quantize_tail_biting(&vit, &seq, &mut self.ws);
            let dec = vit.decode(&sol.states);
            for r in 0..self.tx {
                out.row_mut(bi * self.tx + r)
                    .copy_from_slice(&dec[r * self.ty..(r + 1) * self.ty]);
            }
            // Pack and stash the tile.
            let words = pack_states(&self.trellis, &sol.states);
            let padded = pad_for_decode(&self.trellis, &words, sol.states.len());
            let off = (bi * self.tiles_c + j) * self.tile_words;
            self.packed[off..off + padded.len()].copy_from_slice(&padded);
        }
        out
    }
}

/// Outcome of quantizing one matrix.
pub struct QuantizeResult {
    pub qm: QuantizedMatrix,
    /// Ŵ̃ in the *normalized* incoherent space (eval convenience).
    pub w_hat_tilde: Matrix,
    pub metrics: QuantMetrics,
}

/// Quantize a weight matrix with QTIP (RHT → BlockLDLQ → tail-biting TCQ → pack).
pub fn quantize_matrix_qtip(w: &Matrix, h: &Matrix, cfg: &QtipConfig) -> QuantizeResult {
    let timer = Timer::start();
    let trellis = Trellis::new(cfg.l, cfg.k, cfg.v);
    let rht = RhtContext::new(w.rows, w.cols, cfg.seed);
    let wt = rht.transform_weight(w);
    let ht = regularize_spd(&rht.transform_hessian(h), 1e-2);

    let sigma = (wt.fro_norm() / ((w.rows * w.cols) as f64).sqrt()) as f32;
    let sigma = if sigma > 0.0 { sigma } else { 1.0 };
    let mut wn = wt.clone();
    wn.scale(1.0 / sigma);

    // One registry build produces both the encode-side code (Viterbi search)
    // and the decode-side spec, sharing any trained tables bit-exactly.
    let MethodBuild { code, spec } =
        registry::require(&cfg.code).build(cfg).expect("code rejected this QtipConfig");
    let mut rounder = QtipRounder::new(trellis, code.as_ref(), w.rows, w.cols, cfg.tx, cfg.ty);
    let w_hat_n = block_ldlq(&wn, &ht, &mut rounder);

    let relative_proxy = proxy::relative_proxy_loss(&wn, &w_hat_n, &ht);
    let mse = crate::util::stats::mse(&w_hat_n.data, &wn.data);

    let metrics = QuantMetrics {
        relative_proxy,
        mse,
        bits_per_weight: cfg.k as f64,
        seconds: timer.secs(),
    };
    let tile_words = rounder.tile_words();
    let qm = QuantizedMatrix {
        rows: w.rows,
        cols: w.cols,
        tx: cfg.tx,
        ty: cfg.ty,
        trellis,
        code: spec,
        scale: sigma,
        rht,
        tile_words,
        packed: rounder.packed,
        metrics,
        kernel: kernel::selected_resolved(),
    };
    QuantizeResult { qm, w_hat_tilde: w_hat_n, metrics }
}

/// Baseline inner rounders sharing the same RHT + BlockLDLQ wrapper.
pub enum BaselineKind {
    /// QuIP#-proxy: E8 ball VQ (+ residual Lloyd–Max stages above 2 bits).
    E8Rvq { k: u32, entries: usize },
    /// GPTQ-proxy: Lloyd–Max scalar.
    Scalar { k: u32 },
}

struct VqRounder {
    rvq: E8Rvq,
}

impl BlockRounder for VqRounder {
    fn ty(&self) -> usize {
        8
    }

    fn round_block(&mut self, _j: usize, x: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(x.rows, x.cols);
        for r in 0..x.rows {
            let q = self.rvq.quantize_all(x.row(r));
            out.row_mut(r).copy_from_slice(&q);
        }
        out
    }
}

/// Result of a baseline quantization: the reconstruction (no packed artifact —
/// baselines are quality comparators, not serving paths).
pub struct BaselineResult {
    pub w_hat_tilde: Matrix,
    pub rht: RhtContext,
    pub scale: f32,
    pub metrics: QuantMetrics,
}

impl BaselineResult {
    /// Reconstruct original-space Ŵ for downstream evaluation.
    pub fn reconstruct_w(&self) -> Matrix {
        let mut wt = self.w_hat_tilde.clone();
        wt.scale(self.scale);
        self.rht.restore_weight(&wt)
    }
}

/// Quantize with a baseline inner rounder under the identical RHT+LDLQ wrapper.
pub fn quantize_matrix_baseline(
    w: &Matrix,
    h: &Matrix,
    kind: &BaselineKind,
    seed: u64,
) -> BaselineResult {
    let timer = Timer::start();
    let rht = RhtContext::new(w.rows, w.cols, seed);
    let wt = rht.transform_weight(w);
    let ht = regularize_spd(&rht.transform_hessian(h), 1e-2);
    let sigma = (wt.fro_norm() / ((w.rows * w.cols) as f64).sqrt()) as f32;
    let sigma = if sigma > 0.0 { sigma } else { 1.0 };
    let mut wn = wt.clone();
    wn.scale(1.0 / sigma);

    let (w_hat_n, bits) = match kind {
        BaselineKind::E8Rvq { k, entries } => {
            let rvq = E8Rvq::build(*k, *entries, seed);
            let bits = rvq.bits_per_weight();
            let mut r = VqRounder { rvq };
            (block_ldlq(&wn, &ht, &mut r), bits)
        }
        BaselineKind::Scalar { k } => {
            let lm = LloydMax::train(*k, 200_000, seed);
            let bits = *k as f64;
            let mut r = ScalarRounder { ty: 8, f: move |x| lm.quantize(x) };
            (block_ldlq(&wn, &ht, &mut r), bits)
        }
    };

    let metrics = QuantMetrics {
        relative_proxy: proxy::relative_proxy_loss(&wn, &w_hat_n, &ht),
        mse: crate::util::stats::mse(&w_hat_n.data, &wn.data),
        bits_per_weight: bits,
        seconds: timer.secs(),
    };
    BaselineResult { w_hat_tilde: w_hat_n, rht, scale: sigma, metrics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let a = Matrix::gaussian(n, 2 * n, 1.0, &mut rng);
        let mut h = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..2 * n {
                    s += a.at(i, k) * a.at(j, k);
                }
                *h.at_mut(i, j) = s / (2 * n) as f32;
            }
        }
        h
    }

    fn small_cfg(k: u32) -> QtipConfig {
        QtipConfig {
            l: 10,
            k,
            v: 1,
            tx: 8,
            ty: 8,
            code: "3inst".into(),
            seed: 99,
        }
    }

    #[test]
    fn quantize_roundtrip_consistency() {
        // decode_tile/reconstruct must exactly match the LDLQ-time reconstruction.
        let mut rng = Rng::new(1);
        let w = Matrix::gaussian(16, 32, 0.3, &mut rng);
        let h = random_spd(32, 2);
        let res = quantize_matrix_qtip(&w, &h, &small_cfg(2));
        let rec = res.qm.reconstruct_wtilde();
        for (a, b) in rec.data.iter().zip(&res.w_hat_tilde.data) {
            assert!(
                (a - b * res.qm.scale).abs() < 1e-4,
                "packed decode disagrees with LDLQ reconstruction: {a} vs {b}"
            );
        }
    }

    #[test]
    fn matvec_matches_reconstructed_product() {
        let mut rng = Rng::new(3);
        let w = Matrix::gaussian(16, 16, 0.5, &mut rng);
        let h = random_spd(16, 4);
        let res = quantize_matrix_qtip(&w, &h, &small_cfg(2));
        let w_rec = res.qm.reconstruct_w();
        let x = rng.gauss_vec(16);
        let direct = w_rec.matvec(&x);
        let fused = res.qm.matvec(&x);
        for (a, b) in fused.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn quantization_error_reasonable() {
        // 2-bit QTIP on Gaussian weights: MSE in the normalized space should land
        // near the trellis distortion (~0.07-0.12 with small L), way below 1.0.
        let mut rng = Rng::new(5);
        let w = Matrix::gaussian(16, 32, 1.0, &mut rng);
        let h = random_spd(32, 6);
        let res = quantize_matrix_qtip(&w, &h, &small_cfg(2));
        assert!(res.metrics.mse < 0.2, "mse {}", res.metrics.mse);
        assert!(res.metrics.relative_proxy < 0.2);
    }

    #[test]
    fn higher_k_lowers_error() {
        let mut rng = Rng::new(7);
        let w = Matrix::gaussian(16, 16, 1.0, &mut rng);
        let h = random_spd(16, 8);
        let m2 = quantize_matrix_qtip(&w, &h, &small_cfg(2)).metrics;
        let m3 = quantize_matrix_qtip(&w, &h, &small_cfg(3)).metrics;
        assert!(m3.mse < m2.mse);
    }

    #[test]
    fn all_codes_run_end_to_end() {
        // Iterates the registry: any newly registered method is automatically
        // held to the same end-to-end quantize + matvec agreement bar.
        let mut rng = Rng::new(9);
        let w = Matrix::gaussian(16, 16, 1.0, &mut rng);
        let h = random_spd(16, 10);
        for m in registry::all() {
            let code = m.name();
            let mut cfg = small_cfg(2);
            cfg.code = code.into();
            cfg.v = m.preferred_v();
            let res = quantize_matrix_qtip(&w, &h, &cfg);
            assert!(res.metrics.mse < 0.35, "{code}: {}", res.metrics.mse);
            // Fused matvec must agree with reconstruction for every code.
            let x = rng.gauss_vec(16);
            let direct = res.qm.reconstruct_w().matvec(&x);
            let fused = res.qm.matvec(&x);
            for (a, b) in fused.iter().zip(&direct) {
                assert!((a - b).abs() < 1e-3, "{code}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn matvec_multi_matches_reconstructed_gemm_all_codes() {
        // The batch-fused kernel must agree with Ŵ X for every CodeSpec variant
        // (both the v1 scalar and v2 paired decode paths), and each batch row
        // must be *bit-identical* to the single-column fused matvec.
        let mut rng = Rng::new(21);
        let w = Matrix::gaussian(16, 16, 0.5, &mut rng);
        let h = random_spd(16, 22);
        let b = 3usize;
        // Registry preferred geometries plus the V=2 pure-LUT path, which no
        // method prefers but the kernels must keep supporting.
        let cases = registry::all()
            .iter()
            .map(|m| (m.name(), m.preferred_v()))
            .chain(std::iter::once(("lut", 2)));
        for (code, v) in cases {
            let mut cfg = small_cfg(2);
            cfg.code = code.into();
            cfg.v = v;
            let res = quantize_matrix_qtip(&w, &h, &cfg);
            let w_rec = res.qm.reconstruct_w();
            let mut x = Matrix::zeros(b, 16);
            for r in 0..b {
                let xr = rng.gauss_vec(16);
                x.row_mut(r).copy_from_slice(&xr);
            }
            let fused = res.qm.matvec_multi(&x);
            assert_eq!(fused.rows, b);
            assert_eq!(fused.cols, 16);
            for r in 0..b {
                let direct = w_rec.matvec(x.row(r));
                for (a, bb) in fused.row(r).iter().zip(&direct) {
                    assert!((a - bb).abs() < 1e-3, "{code} v={v} row {r}: {a} vs {bb}");
                }
                let single = res.qm.matvec(x.row(r));
                assert_eq!(
                    fused.row(r),
                    &single[..],
                    "{code} v={v}: fused batch row {r} not bit-identical to matvec"
                );
            }
        }
    }

    #[test]
    fn matvec_tilde_multi_matches_singles_on_synthetic() {
        // Synthetic packed bits exercise the rolling-window decode at full tile
        // size (16×16, L=16) for both scalar-code kernels.
        for name in ["1mad", "3inst"] {
            let (trellis, code) = registry::require(name).synthetic_entry(16, 2, 9);
            let qm = QuantizedMatrix::synthetic(32, 32, trellis, code, 16, 16, 9);
            let mut rng = Rng::new(31);
            let b = 4usize;
            let mut x = Matrix::zeros(b, 32);
            for r in 0..b {
                let xr = rng.gauss_vec(32);
                x.row_mut(r).copy_from_slice(&xr);
            }
            let mut fused = Matrix::zeros(b, 32);
            qm.matvec_tilde_multi(&x, &mut fused);
            for r in 0..b {
                let mut single = vec![0.0f32; 32];
                qm.matvec_tilde(x.row(r), &mut single);
                assert_eq!(fused.row(r), &single[..], "row {r} diverged");
            }
        }
    }

    #[test]
    fn matvec_into_matches_matvec_all_codes() {
        // The allocation-free RHT-sandwich paths (scratch staging + pool
        // striping) must be bit-identical to the allocating ones for every
        // CodeSpec variant and pool width.
        let mut rng = Rng::new(41);
        let w = Matrix::gaussian(16, 16, 0.5, &mut rng);
        let h = random_spd(16, 42);
        let cases = registry::all()
            .iter()
            .map(|m| (m.name(), m.preferred_v()))
            .chain(std::iter::once(("lut", 2)));
        for (code, v) in cases {
            let mut cfg = small_cfg(2);
            cfg.code = code.into();
            cfg.v = v;
            let qm = quantize_matrix_qtip(&w, &h, &cfg).qm;
            let x = rng.gauss_vec(16);
            let reference = qm.matvec(&x);
            for width in [1usize, 2, 4] {
                let pool = ExecPool::new(width);
                let mut y = vec![0.0f32; 16];
                let mut xt = Vec::new();
                qm.matvec_into(&x, &mut y, &mut xt, &pool);
                assert_eq!(y, reference, "{code} width {width}: matvec_into diverged");
            }
            // Batch form, including a batch wider than one accumulator chunk.
            for b in [3usize, BCHUNK + 2] {
                let mut xm = Matrix::zeros(b, 16);
                for r in 0..b {
                    let xr = rng.gauss_vec(16);
                    xm.row_mut(r).copy_from_slice(&xr);
                }
                let reference = qm.matvec_multi(&xm);
                // Batch-chunked accumulation must stay bit-identical to the
                // single-column kernel even past one chunk width.
                for r in 0..b {
                    assert_eq!(
                        reference.row(r),
                        &qm.matvec(xm.row(r))[..],
                        "{code} b {b}: fused row {r} != single matvec"
                    );
                }
                for width in [1usize, 2, 4] {
                    let pool = ExecPool::new(width);
                    let mut y = Matrix::zeros(0, 0);
                    let mut bxt = Matrix::zeros(0, 0);
                    let mut xcol = Vec::new();
                    qm.matvec_multi_into(&xm, &mut y, &mut bxt, &mut xcol, &pool);
                    assert_eq!(
                        y.data, reference.data,
                        "{code} width {width} b {b}: matvec_multi_into diverged"
                    );
                }
            }
        }
    }

    #[test]
    fn lane_kernels_match_scalar_smoke() {
        // Full lane-boundary coverage lives in tests/kernel_parity.rs; this
        // pins the in-module dispatch: flipping `kernel` never changes bits.
        for name in ["1mad", "3inst"] {
            let (trellis, code) = registry::require(name).synthetic_entry(16, 2, 77);
            let mut qm = QuantizedMatrix::synthetic(32, 32, trellis, code, 16, 16, 77);
            let mut rng = Rng::new(78);
            let x = rng.gauss_vec(32);
            qm.kernel = KernelKind::Scalar;
            let mut ys = vec![0.0f32; 32];
            qm.matvec_tilde(&x, &mut ys);
            qm.kernel = KernelKind::Lanes;
            let mut yl = vec![0.0f32; 32];
            qm.matvec_tilde(&x, &mut yl);
            assert_eq!(ys, yl, "{} lane kernel diverged", qm.code.name());
        }
    }

    #[test]
    fn baseline_pipelines_run() {
        let mut rng = Rng::new(11);
        let w = Matrix::gaussian(8, 16, 1.0, &mut rng);
        let h = random_spd(16, 12);
        // 1024-entry E8 = 1.25 bits/weight (full 2^16 E8P is exercised in the
        // release-mode benches); scalar at 2 bits.
        let vq = quantize_matrix_baseline(
            &w,
            &h,
            &BaselineKind::E8Rvq { k: 2, entries: 1024 },
            1,
        );
        let sc = quantize_matrix_baseline(&w, &h, &BaselineKind::Scalar { k: 2 }, 1);
        assert!((vq.metrics.bits_per_weight - 1.25).abs() < 1e-9);
        assert!(vq.metrics.mse < 0.5, "1.25-bpw E8 mse {}", vq.metrics.mse);
        assert!(sc.metrics.mse < 0.2, "2-bit scalar LDLQ mse {}", sc.metrics.mse);
        // Reconstruction shape.
        assert_eq!(vq.reconstruct_w().rows, 8);
    }

    #[test]
    fn quant_metrics_json_roundtrip() {
        let m = QuantMetrics {
            relative_proxy: 0.03125,
            mse: 0.0625,
            bits_per_weight: 2.0,
            seconds: 1.5,
        };
        let text = m.to_json().to_string();
        let back = QuantMetrics::from_json(&crate::util::json::Json::parse(&text).unwrap());
        assert_eq!(back.relative_proxy, m.relative_proxy);
        assert_eq!(back.mse, m.mse);
        assert_eq!(back.bits_per_weight, m.bits_per_weight);
        assert_eq!(back.seconds, m.seconds);
    }

    #[test]
    fn ycells_pool_disjoint_bands_accumulate_exactly_once() {
        // Focused Miri/TSan target for the raw-pointer accumulator: stripe
        // row bands of a B×rows matrix across a real multi-worker pool and
        // accumulate through `YCells::add` exactly as the multi kernels do.
        // Any aliasing between bands, batch columns, or a retagging bug in
        // the pointer arithmetic is UB Miri rejects; the value check catches
        // lost or doubled updates.
        let (b, rows, band) = (3usize, 32usize, 4usize);
        let mut y = Matrix::zeros(b, rows);
        let cells = YCells::of(&mut y);
        let pool = crate::util::threadpool::ExecPool::new(3);
        pool.run_bands(rows, band, |r0, r1| {
            for row in r0..r1 {
                for bb in 0..b {
                    // Two adds per cell proves accumulation, not overwrite.
                    // SAFETY: this band owns rows [r0, r1); `bb < b` and
                    // `row < rows` are in-bounds; `y` outlives the dispatch.
                    unsafe { cells.add(bb, row, (bb * rows + row) as f32) };
                    // SAFETY: same disjoint-band ownership as the line above.
                    unsafe { cells.add(bb, row, 1.0) };
                }
            }
        });
        for bb in 0..b {
            for row in 0..rows {
                assert_eq!(y.at(bb, row), (bb * rows + row) as f32 + 1.0, "({bb},{row})");
            }
        }
    }

    #[test]
    fn artifact_size_accounting() {
        let mut rng = Rng::new(13);
        let w = Matrix::gaussian(16, 16, 1.0, &mut rng);
        let h = random_spd(16, 14);
        let res = quantize_matrix_qtip(&w, &h, &small_cfg(2));
        // 2-bit: 256 weights -> 512 bits padded to tile_words; plus side info.
        let bytes = res.qm.size_bytes();
        assert!(bytes < 16 * 16 * 4 / 8, "2-bit artifact must be ≪ fp32: {bytes}");
    }
}
