//! Proxy-Hessian generation (paper §2, §A.3.2): `H = E_x[x xᵀ]` accumulated from
//! calibration activations, one Hessian per linear-layer *input* site.
//!
//! The paper calibrates on RedPajama sequences; we calibrate on the offline source
//! corpus (DESIGN.md §4). Activations are captured by running the dense model's
//! batch forward and hooking the inputs of each linear layer.

use std::collections::BTreeMap;

use crate::model::transformer::{rmsnorm_row, rope_rotate, softmax_inplace, Transformer};
use crate::model::ModelConfig;
use crate::util::matrix::Matrix;

/// Accumulates `Σ x xᵀ` and a sample count for one layer input site.
#[derive(Clone, Debug)]
pub struct HessianAccumulator {
    pub sum: Matrix,
    pub count: usize,
}

impl HessianAccumulator {
    pub fn new(dim: usize) -> Self {
        HessianAccumulator { sum: Matrix::zeros(dim, dim), count: 0 }
    }

    /// Rank-1 update with one activation vector.
    pub fn update(&mut self, x: &[f32]) {
        let n = self.sum.rows;
        assert_eq!(x.len(), n);
        for i in 0..n {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = &mut self.sum.data[i * n..(i + 1) * n];
            for (r, &xj) in row.iter_mut().zip(x) {
                *r += xi * xj;
            }
        }
        self.count += 1;
    }

    /// Batched update: each row of `xs` is one activation.
    pub fn update_batch(&mut self, xs: &Matrix) {
        assert_eq!(xs.cols, self.sum.rows);
        // H += Xᵀ X via gemm (much faster than per-row rank-1 updates).
        let xt = xs.transpose();
        crate::util::matrix::gemm(&xt, xs, &mut self.sum);
        self.count += xs.rows;
    }

    /// The mean-normalized Hessian `E[x xᵀ]`.
    pub fn finalize(&self) -> Matrix {
        let mut h = self.sum.clone();
        if self.count > 0 {
            h.scale(1.0 / self.count as f32);
        }
        h
    }
}

/// The input sites that share a Hessian. In a pre-norm block, q/k/v share their
/// input, and gate/up share theirs; o and down have their own.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Site {
    Qkv(usize),
    AttnOut(usize),
    GateUp(usize),
    MlpDown(usize),
}

impl Site {
    pub fn dim(&self, cfg: &ModelConfig) -> usize {
        match self {
            Site::Qkv(_) | Site::AttnOut(_) | Site::GateUp(_) => cfg.d_model,
            Site::MlpDown(_) => cfg.d_ff,
        }
    }

    /// Which linear layer names consume this site's Hessian.
    pub fn layer_names(&self) -> Vec<String> {
        match self {
            Site::Qkv(i) => vec![format!("l{i}.q"), format!("l{i}.k"), format!("l{i}.v")],
            Site::AttnOut(i) => vec![format!("l{i}.o")],
            Site::GateUp(i) => vec![format!("l{i}.gate"), format!("l{i}.up")],
            Site::MlpDown(i) => vec![format!("l{i}.down")],
        }
    }
}

/// Collected Hessians for every linear layer of a model.
pub struct HessianSet {
    pub by_layer: BTreeMap<String, Matrix>,
}

/// Run the dense model over calibration sequences and accumulate per-site
/// Hessians. This duplicates the forward-pass structure of
/// `Transformer::forward_batch` with activation taps (kept in sync by the
/// `hessians_match_forward` test).
pub fn collect_hessians(model: &Transformer, sequences: &[Vec<u16>]) -> HessianSet {
    let cfg = &model.cfg;
    let mut accs: BTreeMap<String, HessianAccumulator> = BTreeMap::new();
    for i in 0..cfg.n_layers {
        for site in [Site::Qkv(i), Site::AttnOut(i), Site::GateUp(i), Site::MlpDown(i)] {
            accs.insert(format!("{site:?}"), HessianAccumulator::new(site.dim(cfg)));
        }
    }

    for tokens in sequences {
        let taps = forward_with_taps(model, tokens);
        for (i, tap) in taps.into_iter().enumerate() {
            accs.get_mut(&format!("{:?}", Site::Qkv(i)))
                .unwrap()
                .update_batch(&tap.attn_in);
            accs.get_mut(&format!("{:?}", Site::AttnOut(i)))
                .unwrap()
                .update_batch(&tap.attn_mid);
            accs.get_mut(&format!("{:?}", Site::GateUp(i)))
                .unwrap()
                .update_batch(&tap.mlp_in);
            accs.get_mut(&format!("{:?}", Site::MlpDown(i)))
                .unwrap()
                .update_batch(&tap.mlp_mid);
        }
    }

    let mut by_layer = BTreeMap::new();
    for i in 0..cfg.n_layers {
        for site in [Site::Qkv(i), Site::AttnOut(i), Site::GateUp(i), Site::MlpDown(i)] {
            let h = accs[&format!("{site:?}")].finalize();
            for name in site.layer_names() {
                by_layer.insert(name, h.clone());
            }
        }
    }
    HessianSet { by_layer }
}

/// Per-layer activation taps from one forward pass.
struct LayerTaps {
    /// Input to q/k/v (post attn_norm).
    attn_in: Matrix,
    /// Input to o (attention mix output).
    attn_mid: Matrix,
    /// Input to gate/up (post mlp_norm).
    mlp_in: Matrix,
    /// Input to down (activated hidden).
    mlp_mid: Matrix,
}

fn forward_with_taps(model: &Transformer, tokens: &[u16]) -> Vec<LayerTaps> {
    // Mirror of Transformer::forward_batch with taps; see that function for the
    // canonical semantics (the parity test enforces agreement).
    use crate::util::matrix::dot;
    let cfg = &model.cfg;
    let t_len = tokens.len();
    let d = cfg.d_model;
    let h = cfg.n_heads;
    let dh = cfg.head_dim();
    let mut taps = Vec::new();

    let mut x = Matrix::zeros(t_len, d);
    for (t, &tok) in tokens.iter().enumerate() {
        x.row_mut(t).copy_from_slice(model.tok_emb.row(tok as usize));
    }

    for layer in &model.layers {
        let mut xn = x.clone();
        for r in 0..t_len {
            rmsnorm_row(xn.row_mut(r), &layer.attn_norm, cfg.rms_eps);
        }
        let attn_in = xn.clone();
        let mut q = layer.attn.q.forward_batch(&xn);
        let mut k = layer.attn.k.forward_batch(&xn);
        let v = layer.attn.v.forward_batch(&xn);
        for t in 0..t_len {
            for head in 0..h {
                rope_rotate(&mut q.row_mut(t)[head * dh..(head + 1) * dh], t, cfg.rope_theta);
                rope_rotate(&mut k.row_mut(t)[head * dh..(head + 1) * dh], t, cfg.rope_theta);
            }
        }
        let scale = 1.0 / (dh as f32).sqrt();
        let mut attn_out = Matrix::zeros(t_len, d);
        let mut scores = vec![0.0f32; t_len];
        for head in 0..h {
            let hs = head * dh;
            for tq in 0..t_len {
                for tk in 0..=tq {
                    scores[tk] = dot(&q.row(tq)[hs..hs + dh], &k.row(tk)[hs..hs + dh]) * scale;
                }
                softmax_inplace(&mut scores[..=tq]);
                let out = &mut attn_out.row_mut(tq)[hs..hs + dh];
                for tk in 0..=tq {
                    let w = scores[tk];
                    let vrow = &v.row(tk)[hs..hs + dh];
                    for i in 0..dh {
                        out[i] += w * vrow[i];
                    }
                }
            }
        }
        let attn_mid = attn_out.clone();
        let proj = layer.attn.o.forward_batch(&attn_out);
        x.axpy(1.0, &proj);

        let mut xn = x.clone();
        for r in 0..t_len {
            rmsnorm_row(xn.row_mut(r), &layer.mlp_norm, cfg.rms_eps);
        }
        let mlp_in = xn.clone();
        let gate = layer.mlp.gate.forward_batch(&xn);
        let up = layer.mlp.up.forward_batch(&xn);
        let mut act = gate;
        for (a, &u) in act.data.iter_mut().zip(&up.data) {
            let g = *a;
            *a = g / (1.0 + (-g).exp()) * u;
        }
        let mlp_mid = act.clone();
        let down = layer.mlp.down.forward_batch(&act);
        x.axpy(1.0, &down);

        taps.push(LayerTaps { attn_in, attn_mid, mlp_in, mlp_mid });
    }
    taps
}



#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, Transformer, WeightStore};
    use crate::util::rng::Rng;

    fn tiny() -> Transformer {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 2;
        cfg.max_seq = 16;
        Transformer::from_store(&WeightStore::random(&cfg, 3))
    }

    #[test]
    fn accumulator_rank1() {
        let mut acc = HessianAccumulator::new(3);
        acc.update(&[1.0, 2.0, 0.0]);
        acc.update(&[0.0, 1.0, -1.0]);
        let h = acc.finalize();
        // E[xxT] over two samples.
        assert!((h.at(0, 0) - 0.5).abs() < 1e-6);
        assert!((h.at(1, 1) - 2.5).abs() < 1e-6);
        assert!((h.at(1, 2) + 0.5).abs() < 1e-6);
        assert_eq!(h.at(0, 2), 0.0);
    }

    #[test]
    fn batch_matches_rank1() {
        let mut rng = Rng::new(1);
        let xs = Matrix::gaussian(10, 8, 1.0, &mut rng);
        let mut a = HessianAccumulator::new(8);
        let mut b = HessianAccumulator::new(8);
        for r in 0..10 {
            a.update(xs.row(r));
        }
        b.update_batch(&xs);
        let (ha, hb) = (a.finalize(), b.finalize());
        for (x, y) in ha.data.iter().zip(&hb.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn hessians_are_symmetric_psd_ish() {
        let model = tiny();
        let seqs: Vec<Vec<u16>> = vec![vec![1, 2, 3, 4, 5, 6, 7, 8], vec![100, 90, 80, 70]];
        let hs = collect_hessians(&model, &seqs);
        assert_eq!(hs.by_layer.len(), 2 * 7);
        for (name, h) in &hs.by_layer {
            assert_eq!(h.rows, h.cols);
            for i in 0..h.rows {
                assert!(h.at(i, i) >= -1e-6, "{name}: negative diagonal");
                for j in 0..i {
                    assert!(
                        (h.at(i, j) - h.at(j, i)).abs() < 1e-3,
                        "{name}: asymmetric"
                    );
                }
            }
            // Regularized Hessian must be Choleskyable.
            let reg = crate::util::linalg::regularize_spd(h, 1e-2);
            assert!(crate::util::linalg::cholesky(&reg).is_some(), "{name}");
        }
    }

    #[test]
    fn qkv_share_hessian() {
        let model = tiny();
        let seqs = vec![vec![5u16, 6, 7, 8, 9, 10]];
        let hs = collect_hessians(&model, &seqs);
        assert_eq!(hs.by_layer["l0.q"].data, hs.by_layer["l0.k"].data);
        assert_eq!(hs.by_layer["l0.q"].data, hs.by_layer["l0.v"].data);
        assert_ne!(hs.by_layer["l0.q"].data, hs.by_layer["l0.o"].data);
    }

    #[test]
    fn hessian_dims_match_layer_inputs() {
        let model = tiny();
        let seqs = vec![vec![1u16, 2, 3, 4]];
        let hs = collect_hessians(&model, &seqs);
        assert_eq!(hs.by_layer["l0.q"].rows, 32);
        assert_eq!(hs.by_layer["l0.down"].rows, 64);
    }
}
