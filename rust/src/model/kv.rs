//! KV-cache storage for the serving path.
//!
//! Two layouts back the decode attention:
//!
//! * [`KvCache`] — the historical per-sequence contiguous cache
//!   (`n_layers × max_seq × d_model` K and V, eagerly allocated). It remains
//!   the **reference implementation**: simple, provably correct, and the
//!   baseline every paged result is parity-tested against.
//! * [`KvArena`] + [`KvSeq`] — the paged layout. One shared block pool per
//!   server; sequences lease fixed-size blocks (default
//!   [`DEFAULT_KV_BLOCK`] = 32 positions, all layers' K and V together) on
//!   demand through a per-sequence block table, so KV memory scales with the
//!   tokens actually resident instead of `max_seq` per admitted sequence.
//!   With QTIP weights trellis-compressed to 2–4 bits, the KV cache is the
//!   dominant serving allocation — block-granular accounting is what lets the
//!   continuous batcher admit mixed-length traffic far beyond the
//!   sequence-granular budget.
//!
//! Both layouts store bit-identical rows in the same order, so attention over
//! a block table reproduces the contiguous path's logits exactly (see the
//! parity tests in `transformer.rs` and `tests/paging_parity.rs`).
//!
//! ## Soundness tooling
//!
//! The arena is externally synchronized (`&mut self` everywhere — the serve
//! loop owns it), so its correctness story is protocol-level, not `unsafe`:
//! every block is either on the free list or on exactly one sequence's table.
//! Three layers machine-check that claim before refcounted block aliasing
//! (prefix sharing / copy-on-write) lands on top of it:
//!
//! * debug builds keep a per-block occupancy bitmap and catch double-lease /
//!   double-release at the faulting call;
//! * [`KvArena::assert_partition`] checks the full `free ⊎ leased = pool`
//!   partition; the continuous batcher asserts it at every round boundary
//!   (debug builds) and the paging-parity tests assert it explicitly;
//! * the loom lane (`tests/loom.rs`) exhaustively interleaves lease/release
//!   from concurrent threads through a `util::sync` Mutex and re-checks the
//!   partition at every join point.

use crate::model::config::ModelConfig;
use crate::util::matrix::Matrix;

/// Default positions per KV block (tokens per lease).
pub const DEFAULT_KV_BLOCK: usize = 32;

/// Resolve the block geometry: `cli` (`--kv-block`, 0 = unset) >
/// `QTIP_KV_BLOCK` env > `fallback` (e.g. the artifact manifest's recorded
/// geometry, 0 = unset) > [`DEFAULT_KV_BLOCK`]. An unparsable env value is
/// ignored rather than aborting a serve.
pub fn resolve_kv_block(cli: usize, fallback: usize) -> usize {
    resolve_kv_block_from(cli, std::env::var("QTIP_KV_BLOCK").ok().as_deref(), fallback)
}

/// Pure precedence rule behind [`resolve_kv_block`] (testable without
/// touching process env).
pub fn resolve_kv_block_from(cli: usize, env: Option<&str>, fallback: usize) -> usize {
    if cli > 0 {
        return cli;
    }
    if let Some(v) = env.and_then(|v| v.trim().parse::<usize>().ok()) {
        if v > 0 {
            return v;
        }
    }
    if fallback > 0 {
        return fallback;
    }
    DEFAULT_KV_BLOCK
}

/// Which KV layout the server schedules over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLayout {
    /// Defer to the build's default (currently [`KvLayout::Paged`]).
    Auto,
    /// Per-sequence contiguous caches with sequence-granular admission — the
    /// reference scheduler (static round batching).
    Contig,
    /// Shared block arena with token-granular continuous batching.
    Paged,
}

impl KvLayout {
    /// Parse a CLI/env spelling: `auto` | `contig` | `paged`.
    pub fn parse(s: &str) -> Result<KvLayout, String> {
        match s.trim() {
            "auto" => Ok(KvLayout::Auto),
            "contig" => Ok(KvLayout::Contig),
            "paged" => Ok(KvLayout::Paged),
            other => Err(format!("unknown kv layout '{other}' (expected auto | contig | paged)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvLayout::Auto => "auto",
            KvLayout::Contig => "contig",
            KvLayout::Paged => "paged",
        }
    }

    /// Resolve `Auto` to the concrete layout the server will schedule over.
    /// Both layouts are bit-identical per sequence, so `Auto` simply picks
    /// the one that admits more traffic.
    pub fn resolve(self) -> KvLayout {
        match self {
            KvLayout::Auto => KvLayout::Paged,
            k => k,
        }
    }
}

/// Per-sequence contiguous KV cache (reference layout).
pub struct KvCache {
    /// Per layer: (keys, values), each `max_seq × d_model` with `len` rows valid.
    pub k: Vec<Matrix>,
    pub v: Vec<Matrix>,
    pub len: usize,
    pub capacity: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        KvCache {
            k: (0..cfg.n_layers).map(|_| Matrix::zeros(cfg.max_seq, cfg.d_model)).collect(),
            v: (0..cfg.n_layers).map(|_| Matrix::zeros(cfg.max_seq, cfg.d_model)).collect(),
            len: 0,
            capacity: cfg.max_seq,
        }
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Bytes held (for the server's cache manager accounting).
    pub fn size_bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|m| m.data.len() * 4).sum()
    }

    /// Bytes a cache built from `cfg` will hold, without allocating one — the
    /// server's per-round admission check must not allocate full K/V buffers
    /// just to read their size.
    pub fn size_bytes_for(cfg: &ModelConfig) -> usize {
        2 * cfg.n_layers * cfg.max_seq * cfg.d_model * 4
    }
}

/// A sequence's lease on arena blocks: the block table plus the number of
/// valid positions. Created empty; the scheduler grows it via
/// [`KvArena::ensure`] and returns it via [`KvArena::release`].
#[derive(Debug, Default)]
pub struct KvSeq {
    blocks: Vec<u32>,
    /// Positions written so far (same meaning as `KvCache::len`).
    pub len: usize,
}

impl KvSeq {
    pub fn new() -> KvSeq {
        KvSeq::default()
    }

    /// Blocks currently leased by this sequence.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }
}

/// The shared paged KV arena: one flat f32 pool carved into fixed-size
/// blocks, a free list, and per-block addressing for every layer's K and V
/// rows. A block holds `block_positions` positions for **all** layers
/// (`[layer][K rows | V rows]` inside the block), so one lease advances a
/// sequence by `block_positions` tokens everywhere at once.
pub struct KvArena {
    n_layers: usize,
    d_model: usize,
    block_positions: usize,
    n_blocks: usize,
    data: Vec<f32>,
    /// Free block ids (stack: release pushes, lease pops).
    free: Vec<u32>,
    /// Most blocks simultaneously leased over the arena's lifetime.
    high_water: usize,
    /// Debug-only occupancy bitmap: `leased[b]` iff block `b` is currently on
    /// some sequence's table. Catches double-lease/double-release at the
    /// faulting call instead of as downstream KV corruption.
    #[cfg(debug_assertions)]
    leased: Vec<bool>,
}

impl KvArena {
    /// Build an arena of `n_blocks` blocks of `block_positions` positions
    /// each, shaped for `cfg`'s layer count and width.
    pub fn new(cfg: &ModelConfig, block_positions: usize, n_blocks: usize) -> KvArena {
        assert!(block_positions > 0, "KV block must hold at least one position");
        let stride = Self::block_floats(cfg, block_positions);
        KvArena {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            block_positions,
            n_blocks,
            data: vec![0.0; n_blocks * stride],
            free: (0..n_blocks as u32).rev().collect(),
            high_water: 0,
            #[cfg(debug_assertions)]
            leased: vec![false; n_blocks],
        }
    }

    fn block_floats(cfg: &ModelConfig, block_positions: usize) -> usize {
        2 * cfg.n_layers * block_positions * cfg.d_model
    }

    /// Bytes one block occupies for `cfg` — the unit of the server's KV
    /// budget arithmetic (must not require allocating an arena to compute).
    pub fn block_bytes(cfg: &ModelConfig, block_positions: usize) -> usize {
        Self::block_floats(cfg, block_positions) * 4
    }

    /// Blocks needed to hold `positions` positions at `block_positions`
    /// granularity.
    pub fn blocks_for_positions(positions: usize, block_positions: usize) -> usize {
        positions.div_ceil(block_positions)
    }

    pub fn block_positions(&self) -> usize {
        self.block_positions
    }

    pub fn blocks_total(&self) -> usize {
        self.n_blocks
    }

    pub fn blocks_free(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// Most blocks simultaneously leased since construction.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Blocks this arena needs to hold `positions` positions of one sequence.
    pub fn blocks_for(&self, positions: usize) -> usize {
        Self::blocks_for_positions(positions, self.block_positions)
    }

    /// Positions `seq` can hold with its current leases.
    pub fn seq_capacity(&self, seq: &KvSeq) -> usize {
        seq.blocks.len() * self.block_positions
    }

    /// Lease one more block onto `seq`'s table. Returns false when the free
    /// list is empty (the scheduler then evicts or waits).
    pub fn lease(&mut self, seq: &mut KvSeq) -> bool {
        match self.free.pop() {
            Some(b) => {
                #[cfg(debug_assertions)]
                {
                    let slot = &mut self.leased[b as usize];
                    debug_assert!(!*slot, "block {b} double-leased (still marked in use)");
                    *slot = true;
                }
                seq.blocks.push(b);
                self.high_water = self.high_water.max(self.blocks_in_use());
                true
            }
            None => false,
        }
    }

    /// Lease blocks until `seq` can hold `positions` positions. On failure
    /// the blocks already leased stay on the table (the scheduler either
    /// evicts another sequence and retries, or releases this one).
    pub fn ensure(&mut self, seq: &mut KvSeq, positions: usize) -> bool {
        while self.seq_capacity(seq) < positions {
            if !self.lease(seq) {
                return false;
            }
        }
        true
    }

    /// Return every block `seq` holds to the free list and reset it.
    pub fn release(&mut self, seq: &mut KvSeq) {
        #[cfg(debug_assertions)]
        for &b in &seq.blocks {
            let slot = &mut self.leased[b as usize];
            debug_assert!(
                *slot,
                "block {b} double-released (returned while already on the free list)"
            );
            *slot = false;
        }
        self.free.extend(seq.blocks.drain(..));
        seq.len = 0;
    }

    /// Invariant checker: given **every** live block table, assert that the
    /// free list and the leased blocks form an exact partition of the pool —
    /// no block leaked, none double-leased, none both free and leased, and no
    /// sequence claiming more positions than its leases hold. O(blocks); the
    /// continuous batcher calls it at round boundaries in debug builds, and
    /// the paging-parity tests call it unconditionally. Panics on violation.
    ///
    /// Pre-refcounting contract: once copy-on-write prefix sharing lands,
    /// "exactly one table" relaxes to "refcount many tables" and this checker
    /// is the place that relaxation must be encoded.
    pub fn assert_partition<'a, I>(&self, tables: I)
    where
        I: IntoIterator<Item = &'a KvSeq>,
    {
        let mut seen = vec![false; self.n_blocks];
        let mut free_ct = 0usize;
        for &b in &self.free {
            let b = b as usize;
            assert!(b < self.n_blocks, "free list holds out-of-range block {b}");
            assert!(!seen[b], "block {b} appears twice in the free list");
            seen[b] = true;
            free_ct += 1;
            #[cfg(debug_assertions)]
            debug_assert!(!self.leased[b], "block {b} is free but marked leased");
        }
        let mut leased_ct = 0usize;
        for seq in tables {
            assert!(
                seq.len <= self.seq_capacity(seq),
                "sequence claims {} positions but its {} blocks hold only {}",
                seq.len,
                seq.blocks.len(),
                self.seq_capacity(seq)
            );
            for &b in &seq.blocks {
                let b = b as usize;
                assert!(b < self.n_blocks, "table holds out-of-range block {b}");
                assert!(!seen[b], "block {b} is on two tables (or both free and leased)");
                seen[b] = true;
                leased_ct += 1;
                #[cfg(debug_assertions)]
                debug_assert!(self.leased[b], "block {b} is on a table but marked free");
            }
        }
        assert_eq!(
            free_ct + leased_ct,
            self.n_blocks,
            "free ⊎ leased must cover the pool exactly (a block table is missing \
             from the checked set, or a block leaked)"
        );
    }

    #[inline]
    fn row_offset(&self, seq: &KvSeq, layer: usize, pos: usize, is_v: bool) -> usize {
        debug_assert!(pos < self.seq_capacity(seq), "position beyond leased blocks");
        debug_assert!(layer < self.n_layers);
        let blk = seq.blocks[pos / self.block_positions] as usize;
        let row = pos % self.block_positions;
        let stride = 2 * self.n_layers * self.block_positions * self.d_model;
        blk * stride
            + layer * (2 * self.block_positions * self.d_model)
            + if is_v { self.block_positions * self.d_model } else { 0 }
            + row * self.d_model
    }

    #[inline]
    pub fn k_row(&self, seq: &KvSeq, layer: usize, pos: usize) -> &[f32] {
        let off = self.row_offset(seq, layer, pos, false);
        &self.data[off..off + self.d_model]
    }

    #[inline]
    pub fn v_row(&self, seq: &KvSeq, layer: usize, pos: usize) -> &[f32] {
        let off = self.row_offset(seq, layer, pos, true);
        &self.data[off..off + self.d_model]
    }

    #[inline]
    pub fn k_row_mut(&mut self, seq: &KvSeq, layer: usize, pos: usize) -> &mut [f32] {
        let off = self.row_offset(seq, layer, pos, false);
        &mut self.data[off..off + self.d_model]
    }

    #[inline]
    pub fn v_row_mut(&mut self, seq: &KvSeq, layer: usize, pos: usize) -> &mut [f32] {
        let off = self.row_offset(seq, layer, pos, true);
        &mut self.data[off..off + self.d_model]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 16;
        cfg.n_layers = 2;
        cfg.max_seq = 64;
        cfg
    }

    #[test]
    fn lease_release_accounting() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        assert_eq!(arena.blocks_total(), 4);
        assert_eq!(arena.blocks_free(), 4);
        let mut a = KvSeq::new();
        let mut b = KvSeq::new();
        assert!(arena.ensure(&mut a, 20)); // 3 blocks of 8
        assert_eq!(a.n_blocks(), 3);
        assert_eq!(arena.blocks_free(), 1);
        assert!(arena.ensure(&mut b, 8));
        assert_eq!(arena.blocks_free(), 0);
        assert_eq!(arena.high_water(), 4);
        // Pool exhausted: the next lease must fail, not panic.
        assert!(!arena.ensure(&mut b, 16));
        arena.release(&mut a);
        assert_eq!(a.n_blocks(), 0);
        assert_eq!(a.len, 0);
        assert_eq!(arena.blocks_free(), 3);
        // Freed blocks are reusable.
        assert!(arena.ensure(&mut b, 16));
        arena.release(&mut b);
        assert_eq!(arena.blocks_free(), 4);
        assert_eq!(arena.high_water(), 4, "high water survives release");
    }

    #[test]
    fn row_addressing_is_disjoint_and_stable() {
        // Write a unique pattern into every (seq, layer, pos, k/v) row via the
        // mut accessors, then read everything back — any overlap between rows,
        // layers, K/V halves, or sequences would corrupt the pattern.
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 4, 8);
        let mut seqs: Vec<KvSeq> = (0..2).map(|_| KvSeq::new()).collect();
        let positions = 10usize; // crosses block boundaries (4-position blocks)
        for s in seqs.iter_mut() {
            assert!(arena.ensure(s, positions));
        }
        let tag = |si: usize, li: usize, pos: usize, v: bool, d: usize| {
            (si * 100_000 + li * 10_000 + pos * 100 + (v as usize) * 10 + d) as f32
        };
        for (si, s) in seqs.iter().enumerate() {
            for li in 0..cfg.n_layers {
                for pos in 0..positions {
                    for d in 0..cfg.d_model {
                        arena.k_row_mut(s, li, pos)[d] = tag(si, li, pos, false, d);
                        arena.v_row_mut(s, li, pos)[d] = tag(si, li, pos, true, d);
                    }
                }
            }
        }
        for (si, s) in seqs.iter().enumerate() {
            for li in 0..cfg.n_layers {
                for pos in 0..positions {
                    for d in 0..cfg.d_model {
                        assert_eq!(arena.k_row(s, li, pos)[d], tag(si, li, pos, false, d));
                        assert_eq!(arena.v_row(s, li, pos)[d], tag(si, li, pos, true, d));
                    }
                }
            }
        }
    }

    #[test]
    fn partition_checker_accepts_every_lease_release_state() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        let mut a = KvSeq::new();
        let mut b = KvSeq::new();
        arena.assert_partition(std::iter::empty()); // all free
        assert!(arena.ensure(&mut a, 20));
        assert!(arena.ensure(&mut b, 8));
        arena.assert_partition([&a, &b]);
        arena.release(&mut a);
        arena.assert_partition([&b]);
        arena.release(&mut b);
        arena.assert_partition(std::iter::empty());
    }

    #[test]
    #[should_panic(expected = "free ⊎ leased")]
    fn partition_checker_catches_missing_table() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        let mut a = KvSeq::new();
        assert!(arena.ensure(&mut a, 8));
        // `a` holds a block but is withheld from the checked set: the
        // partition no longer covers the pool.
        arena.assert_partition(std::iter::empty());
    }

    #[test]
    #[should_panic(expected = "twice in the free list")]
    fn partition_checker_catches_double_free_entry() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        // Corrupt the free list directly (release() itself would catch the
        // double-release in debug builds before the list is ever corrupted).
        let b = *arena.free.last().unwrap();
        arena.free.push(b);
        arena.assert_partition(std::iter::empty());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "double-released")]
    fn release_catches_stale_table_in_debug() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        let mut a = KvSeq::new();
        assert!(arena.ensure(&mut a, 8));
        // Clone the table, release once, then release the stale copy: the
        // debug occupancy bitmap must flag the second return of the block.
        let mut stale = KvSeq { blocks: a.blocks.clone(), len: a.len };
        arena.release(&mut a);
        arena.release(&mut stale);
    }

    #[test]
    fn blocks_for_rounds_up() {
        let cfg = tiny_cfg();
        let arena = KvArena::new(&cfg, 8, 4);
        assert_eq!(arena.blocks_for(0), 0);
        assert_eq!(arena.blocks_for(1), 1);
        assert_eq!(arena.blocks_for(8), 1);
        assert_eq!(arena.blocks_for(9), 2);
        assert_eq!(KvArena::blocks_for_positions(17, 8), 3);
    }

    #[test]
    fn block_bytes_matches_allocation() {
        let cfg = tiny_cfg();
        let arena = KvArena::new(&cfg, 8, 3);
        assert_eq!(arena.data.len() * 4, 3 * KvArena::block_bytes(&cfg, 8));
        // One full-length sequence in blocks == the contiguous cache bytes.
        let blocks = arena.blocks_for(cfg.max_seq);
        assert_eq!(blocks * KvArena::block_bytes(&cfg, 8), KvCache::size_bytes_for(&cfg));
    }

    #[test]
    fn kv_block_resolution_precedence() {
        // cli > env > fallback > default; zeros and garbage fall through.
        assert_eq!(resolve_kv_block_from(16, Some("8"), 4), 16);
        assert_eq!(resolve_kv_block_from(0, Some("8"), 4), 8);
        assert_eq!(resolve_kv_block_from(0, Some("bogus"), 4), 4);
        assert_eq!(resolve_kv_block_from(0, Some("0"), 4), 4);
        assert_eq!(resolve_kv_block_from(0, None, 4), 4);
        assert_eq!(resolve_kv_block_from(0, None, 0), DEFAULT_KV_BLOCK);
    }

    #[test]
    fn kv_layout_parse_and_resolve() {
        assert_eq!(KvLayout::parse("auto").unwrap(), KvLayout::Auto);
        assert_eq!(KvLayout::parse("contig").unwrap(), KvLayout::Contig);
        assert_eq!(KvLayout::parse("paged").unwrap(), KvLayout::Paged);
        assert!(KvLayout::parse("wat").is_err());
        assert_eq!(KvLayout::Auto.resolve(), KvLayout::Paged);
        assert_eq!(KvLayout::Contig.resolve(), KvLayout::Contig);
        for l in [KvLayout::Auto, KvLayout::Contig, KvLayout::Paged] {
            assert_eq!(KvLayout::parse(l.name()).unwrap(), l);
        }
    }
}
