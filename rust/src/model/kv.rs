//! KV-cache storage for the serving path.
//!
//! Two layouts back the decode attention:
//!
//! * [`KvCache`] — the historical per-sequence contiguous cache
//!   (`n_layers × max_seq × d_model` K and V, eagerly allocated). It remains
//!   the **reference implementation**: simple, provably correct, and the
//!   baseline every paged result is parity-tested against.
//! * [`KvArena`] + [`KvSeq`] — the paged layout. One shared block pool per
//!   server; sequences acquire fixed-size blocks (default
//!   [`DEFAULT_KV_BLOCK`] = 32 positions, all layers' K and V together) on
//!   demand through a per-sequence block table, so KV memory scales with the
//!   tokens actually resident instead of `max_seq` per admitted sequence.
//!   With QTIP weights trellis-compressed to 2–4 bits, the KV cache is the
//!   dominant serving allocation — block-granular accounting is what lets the
//!   continuous batcher admit mixed-length traffic far beyond the
//!   sequence-granular budget.
//!
//! Both layouts store bit-identical rows in the same order, so attention over
//! a block table reproduces the contiguous path's logits exactly (see the
//! parity tests in `transformer.rs` and `tests/paging_parity.rs`).
//!
//! ## Prefix sharing
//!
//! Blocks are **refcounted**: several sequences' tables may alias the same
//! physical block, which is how shared-prompt traffic stops paying for its
//! common prefix twice. The pieces:
//!
//! * [`KvArena::acquire`] pops a free block at refcount 1;
//!   [`KvArena::retain`] aliases an already-resident block onto another
//!   table; [`KvArena::release`] / [`KvArena::release_block`] decrement and
//!   free on zero.
//! * [`PrefixIndex`] maps a **chained hash of full-block token ids**
//!   ([`chain_hash`], FNV-1a seeded per parent block so position matters) to
//!   resident blocks. The continuous batcher consults it at admission: a new
//!   sequence whose leading tokens hash-and-compare equal to registered
//!   blocks aliases those blocks instead of re-prefilling them. Entries
//!   store the actual token ids, so a hash collision degrades to a miss,
//!   never to wrong K/V. The index holds its own reference on every
//!   registered block, keeping hot prefixes resident after their sequence
//!   finishes; [`PrefixIndex::reclaim_one`] releases the least-recently-used
//!   index-only (refcount 1) entry when the scheduler needs blocks back.
//! * [`KvArena::prepare_append`] is the **copy-on-write hook**: before a
//!   sequence writes into a block it shares (refcount ≥ 2), the block is
//!   copied to a private one and swapped into the table. K/V rows depend
//!   only on the token-id prefix, so an aliased read path and a recomputed
//!   write of the same position produce bit-identical rows.
//!
//! ## Soundness tooling
//!
//! The arena is externally synchronized (`&mut self` everywhere — the serve
//! loop owns it), so its correctness story is protocol-level, not `unsafe`:
//! every block is either on the free list (refcount 0) or referenced by
//! exactly `refcount` holders (tables + the prefix index). Three layers
//! machine-check that claim:
//!
//! * the per-block refcount is **always on** (not debug-gated): release of a
//!   refcount-zero block and retain of a free block panic at the faulting
//!   call instead of surfacing as downstream KV corruption;
//! * [`KvArena::assert_partition_with`] checks the full
//!   `free ⊎ uniquely-leased ⊎ shared(refcount ≥ 2) = pool` partition and
//!   that every refcount equals the number of references actually held; the
//!   continuous batcher asserts it at every round boundary (debug builds)
//!   and the paging-parity tests assert it explicitly;
//! * the loom lane (`tests/loom.rs`) exhaustively interleaves
//!   acquire/retain/release from concurrent threads through a `util::sync`
//!   Mutex and re-checks the partition at every join point.

use std::collections::HashMap;
use std::sync::Arc;

use crate::model::config::ModelConfig;
use crate::util::fault::{self, FaultPlan};
use crate::util::matrix::Matrix;

/// Default positions per KV block (tokens per acquired block).
pub const DEFAULT_KV_BLOCK: usize = 32;

/// Resolve the block geometry: `cli` (`--kv-block`, 0 = unset) >
/// `QTIP_KV_BLOCK` env > `fallback` (e.g. the artifact manifest's recorded
/// geometry, 0 = unset) > [`DEFAULT_KV_BLOCK`]. An unparsable env value is
/// ignored rather than aborting a serve.
pub fn resolve_kv_block(cli: usize, fallback: usize) -> usize {
    resolve_kv_block_from(cli, std::env::var("QTIP_KV_BLOCK").ok().as_deref(), fallback)
}

/// Pure precedence rule behind [`resolve_kv_block`] (testable without
/// touching process env).
pub fn resolve_kv_block_from(cli: usize, env: Option<&str>, fallback: usize) -> usize {
    if cli > 0 {
        return cli;
    }
    if let Some(v) = env.and_then(|v| v.trim().parse::<usize>().ok()) {
        if v > 0 {
            return v;
        }
    }
    if fallback > 0 {
        return fallback;
    }
    DEFAULT_KV_BLOCK
}

/// Default prefill chunk: prompt positions fed through one GEMM prefill pass
/// per sequence per round. 1 would degenerate to the token-at-a-time path;
/// matching [`DEFAULT_KV_BLOCK`] keeps a default chunk within one arena block.
pub const DEFAULT_PREFILL_CHUNK: usize = 32;

/// Resolve the prefill chunk size: `cli` (`--prefill-chunk`, 0 = unset) >
/// `QTIP_PREFILL_CHUNK` env > `fallback` (the artifact manifest's recorded
/// chunk, 0 = unset) > [`DEFAULT_PREFILL_CHUNK`]. Same precedence ladder as
/// [`resolve_kv_block`]; chunking never changes output, only TTFT.
pub fn resolve_prefill_chunk(cli: usize, fallback: usize) -> usize {
    resolve_prefill_chunk_from(cli, std::env::var("QTIP_PREFILL_CHUNK").ok().as_deref(), fallback)
}

/// Pure precedence rule behind [`resolve_prefill_chunk`].
pub fn resolve_prefill_chunk_from(cli: usize, env: Option<&str>, fallback: usize) -> usize {
    if cli > 0 {
        return cli;
    }
    if let Some(v) = env.and_then(|v| v.trim().parse::<usize>().ok()) {
        if v > 0 {
            return v;
        }
    }
    if fallback > 0 {
        return fallback;
    }
    DEFAULT_PREFILL_CHUNK
}

/// Resolve the per-round prefill token budget: `cli` (`--round-budget`) >
/// `QTIP_ROUND_BUDGET` env > 0 (unlimited). Unlike the geometry knobs this is
/// deployment policy, not an artifact property, so there is no manifest
/// fallback and 0 is a meaningful value (no budget) rather than "unset".
pub fn resolve_round_budget(cli: usize) -> usize {
    resolve_round_budget_from(cli, std::env::var("QTIP_ROUND_BUDGET").ok().as_deref())
}

/// Pure precedence rule behind [`resolve_round_budget`].
pub fn resolve_round_budget_from(cli: usize, env: Option<&str>) -> usize {
    if cli > 0 {
        return cli;
    }
    if let Some(v) = env.and_then(|v| v.trim().parse::<usize>().ok()) {
        if v > 0 {
            return v;
        }
    }
    0
}

/// Which KV layout the server schedules over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLayout {
    /// Defer to the build's default (currently [`KvLayout::Paged`]).
    Auto,
    /// Per-sequence contiguous caches with sequence-granular admission — the
    /// reference scheduler (static round batching).
    Contig,
    /// Shared block arena with token-granular continuous batching.
    Paged,
}

impl KvLayout {
    /// Parse a CLI/env spelling: `auto` | `contig` | `paged`.
    pub fn parse(s: &str) -> Result<KvLayout, String> {
        match s.trim() {
            "auto" => Ok(KvLayout::Auto),
            "contig" => Ok(KvLayout::Contig),
            "paged" => Ok(KvLayout::Paged),
            other => Err(format!("unknown kv layout '{other}' (expected auto | contig | paged)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvLayout::Auto => "auto",
            KvLayout::Contig => "contig",
            KvLayout::Paged => "paged",
        }
    }

    /// Resolve `Auto` to the concrete layout the server will schedule over.
    /// Both layouts are bit-identical per sequence, so `Auto` simply picks
    /// the one that admits more traffic.
    pub fn resolve(self) -> KvLayout {
        match self {
            KvLayout::Auto => KvLayout::Paged,
            k => k,
        }
    }
}

/// Per-sequence contiguous KV cache (reference layout).
pub struct KvCache {
    /// Per layer: (keys, values), each `max_seq × d_model` with `len` rows valid.
    pub k: Vec<Matrix>,
    pub v: Vec<Matrix>,
    pub len: usize,
    pub capacity: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        KvCache {
            k: (0..cfg.n_layers).map(|_| Matrix::zeros(cfg.max_seq, cfg.d_model)).collect(),
            v: (0..cfg.n_layers).map(|_| Matrix::zeros(cfg.max_seq, cfg.d_model)).collect(),
            len: 0,
            capacity: cfg.max_seq,
        }
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Bytes held (for the server's cache manager accounting).
    pub fn size_bytes(&self) -> usize {
        self.k.iter().chain(self.v.iter()).map(|m| m.data.len() * 4).sum()
    }

    /// Bytes a cache built from `cfg` will hold, without allocating one — the
    /// server's per-round admission check must not allocate full K/V buffers
    /// just to read their size.
    pub fn size_bytes_for(cfg: &ModelConfig) -> usize {
        2 * cfg.n_layers * cfg.max_seq * cfg.d_model * 4
    }
}

/// A sequence's view of arena blocks: the block table plus the number of
/// valid positions. Created empty; the scheduler grows it via
/// [`KvArena::ensure`] / [`KvArena::retain`] and returns it via
/// [`KvArena::release`]. Entries may be aliased (shared with other tables
/// and/or the [`PrefixIndex`]) — the arena's refcounts track that, the table
/// itself is just an ordered list of block ids.
#[derive(Debug, Default)]
pub struct KvSeq {
    blocks: Vec<u32>,
    /// Positions written so far (same meaning as `KvCache::len`).
    pub len: usize,
}

impl KvSeq {
    pub fn new() -> KvSeq {
        KvSeq::default()
    }

    /// Blocks currently on this sequence's table.
    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// The block table itself (position `p` lives in
    /// `blocks()[p / block_positions]`).
    pub fn blocks(&self) -> &[u32] {
        &self.blocks
    }
}

/// The shared paged KV arena: one flat f32 pool carved into fixed-size
/// blocks, a free list, per-block refcounts, and per-block addressing for
/// every layer's K and V rows. A block holds `block_positions` positions for
/// **all** layers (`[layer][K rows | V rows]` inside the block), so one
/// acquired block advances a sequence by `block_positions` tokens everywhere
/// at once.
pub struct KvArena {
    n_layers: usize,
    d_model: usize,
    block_positions: usize,
    n_blocks: usize,
    data: Vec<f32>,
    /// Free block ids (stack: free-on-zero pushes, acquire pops).
    free: Vec<u32>,
    /// Most blocks simultaneously resident over the arena's lifetime.
    high_water: usize,
    /// Per-block reference count: number of block-table entries plus prefix
    /// index entries holding the block. 0 iff the block is on the free list.
    /// Always on (not debug-gated) — the sharing protocol's correctness
    /// hinges on it, and the counts are one `u32` per block.
    rc: Vec<u32>,
    /// Deterministic fault schedule (chaos testing): when set, [`Self::acquire`]
    /// consults the [`fault::KV_ALLOC`] site and reports the free list empty
    /// on a fired draw, exercising every starvation path (reclaim, stall,
    /// evict, re-queue) without needing a genuinely exhausted pool.
    fault: Option<Arc<FaultPlan>>,
}

impl KvArena {
    /// Build an arena of `n_blocks` blocks of `block_positions` positions
    /// each, shaped for `cfg`'s layer count and width.
    pub fn new(cfg: &ModelConfig, block_positions: usize, n_blocks: usize) -> KvArena {
        assert!(block_positions > 0, "KV block must hold at least one position");
        let stride = Self::block_floats(cfg, block_positions);
        KvArena {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            block_positions,
            n_blocks,
            data: vec![0.0; n_blocks * stride],
            free: (0..n_blocks as u32).rev().collect(),
            high_water: 0,
            rc: vec![0; n_blocks],
            fault: None,
        }
    }

    /// Attach a fault-injection plan (see [`crate::util::fault`]); the server
    /// installs the process plan here so block starvation is injectable.
    pub fn set_fault_plan(&mut self, plan: Arc<FaultPlan>) {
        self.fault = Some(plan);
    }

    fn block_floats(cfg: &ModelConfig, block_positions: usize) -> usize {
        2 * cfg.n_layers * block_positions * cfg.d_model
    }

    /// Bytes one block occupies for `cfg` — the unit of the server's KV
    /// budget arithmetic (must not require allocating an arena to compute).
    pub fn block_bytes(cfg: &ModelConfig, block_positions: usize) -> usize {
        Self::block_floats(cfg, block_positions) * 4
    }

    /// Blocks needed to hold `positions` positions at `block_positions`
    /// granularity.
    pub fn blocks_for_positions(positions: usize, block_positions: usize) -> usize {
        positions.div_ceil(block_positions)
    }

    pub fn block_positions(&self) -> usize {
        self.block_positions
    }

    pub fn blocks_total(&self) -> usize {
        self.n_blocks
    }

    pub fn blocks_free(&self) -> usize {
        self.free.len()
    }

    pub fn blocks_in_use(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// Most blocks simultaneously resident since construction.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Blocks this arena needs to hold `positions` positions of one sequence.
    pub fn blocks_for(&self, positions: usize) -> usize {
        Self::blocks_for_positions(positions, self.block_positions)
    }

    /// Positions `seq` can hold with its current table.
    pub fn seq_capacity(&self, seq: &KvSeq) -> usize {
        seq.blocks.len() * self.block_positions
    }

    /// Current reference count of block `b` (0 = free).
    pub fn refcount(&self, b: u32) -> u32 {
        self.rc[b as usize]
    }

    /// True iff block `b` is aliased by more than one holder — writes must go
    /// through [`KvArena::prepare_append`] first.
    pub fn is_shared(&self, b: u32) -> bool {
        self.rc[b as usize] >= 2
    }

    /// Acquire one free block onto `seq`'s table at refcount 1. Returns
    /// false when the free list is empty (the scheduler then reclaims index
    /// entries, stalls, or evicts).
    pub fn acquire(&mut self, seq: &mut KvSeq) -> bool {
        if let Some(plan) = &self.fault {
            if plan.fire(fault::KV_ALLOC) {
                // Injected starvation: indistinguishable from an empty free
                // list, so every caller's relief ladder gets exercised.
                return false;
            }
        }
        match self.free.pop() {
            Some(b) => {
                let rc = &mut self.rc[b as usize];
                assert_eq!(*rc, 0, "block {b} on the free list with nonzero refcount");
                *rc = 1;
                seq.blocks.push(b);
                self.high_water = self.high_water.max(self.blocks_in_use());
                true
            }
            None => false,
        }
    }

    /// Take one more reference on resident block `b` without putting it on a
    /// table — the prefix index's references go through here.
    pub fn retain_block(&mut self, b: u32) {
        let rc = &mut self.rc[b as usize];
        assert!(*rc > 0, "block {b} retained while free (refcount zero)");
        *rc += 1;
    }

    /// Alias resident block `b` onto `seq`'s table (refcount + 1). The
    /// admission path uses this to map a new sequence's leading positions
    /// onto an existing sequence's prefix blocks.
    pub fn retain(&mut self, seq: &mut KvSeq, b: u32) {
        self.retain_block(b);
        seq.blocks.push(b);
    }

    /// Drop one reference on block `b`; on zero the block returns to the
    /// free list.
    pub fn release_block(&mut self, b: u32) {
        let rc = &mut self.rc[b as usize];
        assert!(*rc > 0, "block {b} double-released (refcount already zero)");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(b);
        }
    }

    /// Acquire blocks until `seq` can hold `positions` positions. On failure
    /// the blocks already acquired stay on the table (the scheduler either
    /// evicts another sequence and retries, or releases this one).
    pub fn ensure(&mut self, seq: &mut KvSeq, positions: usize) -> bool {
        while self.seq_capacity(seq) < positions {
            if !self.acquire(seq) {
                return false;
            }
        }
        true
    }

    /// Copy-on-write hook: make `seq` writable at its append cursor
    /// (`seq.len`) and capacious through `positions`.
    ///
    /// If the block containing position `seq.len` is shared (refcount ≥ 2 —
    /// aliased by another table or pinned by the prefix index), it is copied
    /// into a freshly acquired private block which replaces it on `seq`'s
    /// table; the shared original keeps its other holders. Then the table is
    /// grown to hold `positions` positions. Returns `Some(did_cow)` on
    /// success, `None` when the free list ran dry (already-acquired blocks
    /// stay on the table, exactly like [`KvArena::ensure`] failure).
    ///
    /// Rows the copy carries beyond `seq.len` are the donor's — the appends
    /// that follow overwrite them before any read, and rows below `seq.len`
    /// are the shared prefix itself, so the copy is observationally
    /// identical to having prefilled privately.
    pub fn prepare_append(&mut self, seq: &mut KvSeq, positions: usize) -> Option<bool> {
        let mut did_cow = false;
        let bi = seq.len / self.block_positions;
        if bi < seq.blocks.len() && self.is_shared(seq.blocks[bi]) {
            let old = seq.blocks[bi];
            let fresh = self.free.pop()?;
            let rc = &mut self.rc[fresh as usize];
            assert_eq!(*rc, 0, "block {fresh} on the free list with nonzero refcount");
            *rc = 1;
            let stride = 2 * self.n_layers * self.block_positions * self.d_model;
            let src = old as usize * stride;
            self.data.copy_within(src..src + stride, fresh as usize * stride);
            seq.blocks[bi] = fresh;
            self.release_block(old);
            self.high_water = self.high_water.max(self.blocks_in_use());
            did_cow = true;
        }
        if !self.ensure(seq, positions) {
            return None;
        }
        Some(did_cow)
    }

    /// Drop `seq`'s reference on every block it holds (free-on-zero) and
    /// reset it. Blocks aliased elsewhere (other tables, prefix index) stay
    /// resident.
    pub fn release(&mut self, seq: &mut KvSeq) {
        for b in seq.blocks.drain(..) {
            let rc = &mut self.rc[b as usize];
            assert!(*rc > 0, "block {b} double-released (refcount already zero)");
            *rc -= 1;
            if *rc == 0 {
                self.free.push(b);
            }
        }
        seq.len = 0;
    }

    /// Invariant checker for the non-sharing configuration: every reference
    /// comes from a block table. See [`KvArena::assert_partition_with`].
    pub fn assert_partition<'a, I>(&self, tables: I)
    where
        I: IntoIterator<Item = &'a KvSeq>,
    {
        self.assert_partition_with(tables, std::iter::empty());
    }

    /// Invariant checker: given **every** live block table and every block
    /// the prefix index holds a reference on, assert that
    /// `free ⊎ uniquely-leased ⊎ shared(refcount ≥ 2)` is an exact partition
    /// of the pool and that each block's refcount equals the number of
    /// references actually held — no block leaked, none both free and
    /// referenced, no count drift, and no sequence claiming more positions
    /// than its table holds. O(blocks + references); the continuous batcher
    /// calls it at round boundaries in debug builds, and the paging-parity
    /// tests call it unconditionally. Panics on violation.
    pub fn assert_partition_with<'a, I, J>(&self, tables: I, index_blocks: J)
    where
        I: IntoIterator<Item = &'a KvSeq>,
        J: IntoIterator<Item = u32>,
    {
        let mut refs = vec![0u32; self.n_blocks];
        let mut in_free = vec![false; self.n_blocks];
        let mut free_ct = 0usize;
        for &b in &self.free {
            let b = b as usize;
            assert!(b < self.n_blocks, "free list holds out-of-range block {b}");
            assert!(!in_free[b], "block {b} appears twice in the free list");
            in_free[b] = true;
            free_ct += 1;
        }
        for seq in tables {
            assert!(
                seq.len <= self.seq_capacity(seq),
                "sequence claims {} positions but its {} blocks hold only {}",
                seq.len,
                seq.blocks.len(),
                self.seq_capacity(seq)
            );
            for &b in &seq.blocks {
                let b = b as usize;
                assert!(b < self.n_blocks, "table holds out-of-range block {b}");
                refs[b] += 1;
            }
        }
        for b in index_blocks {
            let b = b as usize;
            assert!(b < self.n_blocks, "prefix index holds out-of-range block {b}");
            refs[b] += 1;
        }
        let mut unique_ct = 0usize;
        let mut shared_ct = 0usize;
        for b in 0..self.n_blocks {
            assert_eq!(
                self.rc[b], refs[b],
                "block {b} refcount {} disagrees with the {} references actually held \
                 (a block table or index reference is missing from the checked set, \
                 or a count drifted)",
                self.rc[b], refs[b]
            );
            if in_free[b] {
                assert_eq!(refs[b], 0, "block {b} is both free and referenced");
            } else if refs[b] == 1 {
                unique_ct += 1;
            } else if refs[b] >= 2 {
                shared_ct += 1;
            } else {
                panic!("block {b} leaked: neither free nor referenced by any holder");
            }
        }
        assert_eq!(
            free_ct + unique_ct + shared_ct,
            self.n_blocks,
            "free ⊎ uniquely-leased ⊎ shared must cover the pool exactly"
        );
    }

    #[inline]
    fn row_offset(&self, seq: &KvSeq, layer: usize, pos: usize, is_v: bool) -> usize {
        debug_assert!(pos < self.seq_capacity(seq), "position beyond acquired blocks");
        debug_assert!(layer < self.n_layers);
        let blk = seq.blocks[pos / self.block_positions] as usize;
        let row = pos % self.block_positions;
        let stride = 2 * self.n_layers * self.block_positions * self.d_model;
        blk * stride
            + layer * (2 * self.block_positions * self.d_model)
            + if is_v { self.block_positions * self.d_model } else { 0 }
            + row * self.d_model
    }

    /// Debug write-guard: a row may only be written through a table whose
    /// block is privately held — shared blocks must be privatized by
    /// [`KvArena::prepare_append`] first.
    #[cfg(debug_assertions)]
    fn assert_writable(&self, seq: &KvSeq, pos: usize) {
        let b = seq.blocks[pos / self.block_positions];
        debug_assert_eq!(
            self.rc[b as usize], 1,
            "write to shared block {b} (refcount {}) — copy-on-write must privatize \
             a block before any write lands in it",
            self.rc[b as usize]
        );
    }

    #[inline]
    pub fn k_row(&self, seq: &KvSeq, layer: usize, pos: usize) -> &[f32] {
        let off = self.row_offset(seq, layer, pos, false);
        &self.data[off..off + self.d_model]
    }

    #[inline]
    pub fn v_row(&self, seq: &KvSeq, layer: usize, pos: usize) -> &[f32] {
        let off = self.row_offset(seq, layer, pos, true);
        &self.data[off..off + self.d_model]
    }

    #[inline]
    pub fn k_row_mut(&mut self, seq: &KvSeq, layer: usize, pos: usize) -> &mut [f32] {
        #[cfg(debug_assertions)]
        self.assert_writable(seq, pos);
        let off = self.row_offset(seq, layer, pos, false);
        &mut self.data[off..off + self.d_model]
    }

    #[inline]
    pub fn v_row_mut(&mut self, seq: &KvSeq, layer: usize, pos: usize) -> &mut [f32] {
        #[cfg(debug_assertions)]
        self.assert_writable(seq, pos);
        let off = self.row_offset(seq, layer, pos, true);
        &mut self.data[off..off + self.d_model]
    }
}

/// Root of the prefix hash chain (the FNV-1a offset basis) — the `parent`
/// value for a sequence's first block.
pub const PREFIX_HASH_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Chained FNV-1a over one full block of token ids: `parent` is the hash of
/// the preceding chain (or [`PREFIX_HASH_SEED`] for block 0), so equal block
/// contents at different prefix positions hash differently and a match
/// certifies the **entire** token prefix up to and including this block.
pub fn chain_hash(parent: u64, tokens: &[u16]) -> u64 {
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = parent;
    for &t in tokens {
        for byte in t.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// One registered full block: the chain parent and the exact token ids it
/// covers (collision armor — lookups compare tokens, never trust the hash
/// alone), the resident block, and an LRU stamp.
struct PrefixEntry {
    parent: u64,
    tokens: Vec<u16>,
    block: u32,
    last_used: u64,
}

/// Hashed-block prefix index: `chain_hash(parent, block tokens)` → resident
/// arena blocks. One per model lane (token ids are only meaningful within a
/// tokenizer/model pair). The index owns one arena reference per entry
/// (taken by the caller via [`KvArena::retain_block`] when
/// [`PrefixIndex::insert`] returns true), so registered prefixes survive
/// their originating sequence until [`PrefixIndex::reclaim_one`] evicts
/// them under memory pressure.
#[derive(Default)]
pub struct PrefixIndex {
    entries: HashMap<u64, Vec<PrefixEntry>>,
    /// Logical LRU clock: bumped on every hit/insert.
    clock: u64,
    len: usize,
}

impl PrefixIndex {
    pub fn new() -> PrefixIndex {
        PrefixIndex::default()
    }

    /// Registered entries (== arena references the index holds).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Walk `tokens` a full block at a time and return the longest chain of
    /// registered blocks matching the leading tokens exactly, plus the chain
    /// hash after the matched blocks (the `parent` for the sequence's next
    /// registration). Matched entries' LRU stamps are refreshed. The caller
    /// decides how many of the returned blocks to actually alias (it must
    /// [`KvArena::retain`] each one it takes).
    pub fn match_chain(&mut self, tokens: &[u16], block_positions: usize) -> (Vec<u32>, u64) {
        let mut parent = PREFIX_HASH_SEED;
        let mut blocks = Vec::new();
        for chunk in tokens.chunks_exact(block_positions) {
            let h = chain_hash(parent, chunk);
            let hit = self
                .entries
                .get_mut(&h)
                .and_then(|es| es.iter_mut().find(|e| e.parent == parent && e.tokens == chunk));
            match hit {
                Some(e) => {
                    e.last_used = self.clock;
                    self.clock += 1;
                    blocks.push(e.block);
                    parent = h;
                }
                None => break,
            }
        }
        (blocks, parent)
    }

    /// Register `block` as holding the K/V rows for `tokens` under chain
    /// `parent`. Returns true if a new entry was created — the caller must
    /// then take the index's reference via [`KvArena::retain_block`]. If an
    /// equivalent entry already exists (another sequence registered the same
    /// prefix first), only its LRU stamp is refreshed and false is returned:
    /// the index never holds two entries for one logical prefix.
    pub fn insert(&mut self, parent: u64, tokens: &[u16], block: u32) -> bool {
        let h = chain_hash(parent, tokens);
        let es = self.entries.entry(h).or_default();
        if let Some(e) = es.iter_mut().find(|e| e.parent == parent && e.tokens == tokens) {
            e.last_used = self.clock;
            self.clock += 1;
            return false;
        }
        es.push(PrefixEntry { parent, tokens: tokens.to_vec(), block, last_used: self.clock });
        self.clock += 1;
        self.len += 1;
        true
    }

    /// Evict the least-recently-used entry whose block the index is the
    /// **sole** holder of (refcount 1 — no live sequence aliases it),
    /// releasing the block back to `arena`'s free list. Ties and HashMap
    /// iteration order are broken by `(last_used, block id)`, so eviction is
    /// deterministic. Returns the freed block, or None when every entry is
    /// still aliased by a live sequence (nothing safely evictable).
    pub fn reclaim_one(&mut self, arena: &mut KvArena) -> Option<u32> {
        let mut best: Option<(u64, u32, u64)> = None; // (last_used, block, bucket hash)
        for (&h, es) in &self.entries {
            for e in es {
                let better = best.map_or(true, |(lu, b, _)| (e.last_used, e.block) < (lu, b));
                if arena.refcount(e.block) == 1 && better {
                    best = Some((e.last_used, e.block, h));
                }
            }
        }
        let (lu, block, h) = best?;
        let es = self.entries.get_mut(&h).expect("bucket of chosen entry");
        let i = es
            .iter()
            .position(|e| e.block == block && e.last_used == lu)
            .expect("chosen entry in bucket");
        es.remove(i);
        if es.is_empty() {
            self.entries.remove(&h);
        }
        self.len -= 1;
        arena.release_block(block);
        Some(block)
    }

    /// Every block the index currently holds a reference on (for
    /// [`KvArena::assert_partition_with`]).
    pub fn blocks(&self) -> impl Iterator<Item = u32> + '_ {
        self.entries.values().flatten().map(|e| e.block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 16;
        cfg.n_layers = 2;
        cfg.max_seq = 64;
        cfg
    }

    #[test]
    fn acquire_release_accounting() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        assert_eq!(arena.blocks_total(), 4);
        assert_eq!(arena.blocks_free(), 4);
        let mut a = KvSeq::new();
        let mut b = KvSeq::new();
        assert!(arena.ensure(&mut a, 20)); // 3 blocks of 8
        assert_eq!(a.n_blocks(), 3);
        assert_eq!(arena.blocks_free(), 1);
        assert!(arena.ensure(&mut b, 8));
        assert_eq!(arena.blocks_free(), 0);
        assert_eq!(arena.high_water(), 4);
        // Pool exhausted: the next acquire must fail, not panic.
        assert!(!arena.ensure(&mut b, 16));
        arena.release(&mut a);
        assert_eq!(a.n_blocks(), 0);
        assert_eq!(a.len, 0);
        assert_eq!(arena.blocks_free(), 3);
        // Freed blocks are reusable.
        assert!(arena.ensure(&mut b, 16));
        arena.release(&mut b);
        assert_eq!(arena.blocks_free(), 4);
        assert_eq!(arena.high_water(), 4, "high water survives release");
    }

    #[test]
    fn retain_release_is_free_on_zero() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        let mut a = KvSeq::new();
        let mut b = KvSeq::new();
        assert!(arena.ensure(&mut a, 8));
        let blk = a.blocks()[0];
        assert_eq!(arena.refcount(blk), 1);
        assert!(!arena.is_shared(blk));
        arena.retain(&mut b, blk);
        b.len = 8;
        assert_eq!(arena.refcount(blk), 2);
        assert!(arena.is_shared(blk));
        assert_eq!(arena.blocks_free(), 3, "retain takes no new block");
        arena.assert_partition([&a, &b]);
        // Releasing one holder keeps the block resident for the other.
        arena.release(&mut a);
        assert_eq!(arena.refcount(blk), 1);
        assert_eq!(arena.blocks_free(), 3);
        arena.assert_partition([&b]);
        // Last reference frees it.
        arena.release(&mut b);
        assert_eq!(arena.refcount(blk), 0);
        assert_eq!(arena.blocks_free(), 4);
        arena.assert_partition(std::iter::empty());
    }

    #[test]
    fn prepare_append_copies_shared_block_once() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 4, 4);
        let mut a = KvSeq::new();
        assert!(arena.ensure(&mut a, 4));
        a.len = 4;
        for li in 0..cfg.n_layers {
            for pos in 0..4 {
                for d in 0..cfg.d_model {
                    arena.k_row_mut(&a, li, pos)[d] = (li * 1000 + pos * 10 + d) as f32;
                    arena.v_row_mut(&a, li, pos)[d] = -((li * 1000 + pos * 10 + d) as f32);
                }
            }
        }
        // `b` aliases the block, cursor mid-block (diverges at position 2).
        let mut b = KvSeq::new();
        arena.retain(&mut b, a.blocks()[0]);
        b.len = 2;
        assert_eq!(arena.prepare_append(&mut b, 3), Some(true), "shared block must CoW");
        assert_ne!(b.blocks()[0], a.blocks()[0], "b got a private copy");
        assert_eq!(arena.refcount(a.blocks()[0]), 1);
        assert_eq!(arena.refcount(b.blocks()[0]), 1);
        // The shared prefix rows came along with the copy...
        for li in 0..cfg.n_layers {
            for pos in 0..2 {
                assert_eq!(arena.k_row(&b, li, pos), arena.k_row(&a, li, pos));
                assert_eq!(arena.v_row(&b, li, pos), arena.v_row(&a, li, pos));
            }
        }
        // ...and writes through `b` no longer reach `a`.
        arena.k_row_mut(&b, 0, 2)[0] = 99.0;
        assert_eq!(arena.k_row(&a, 0, 2)[0], 20.0);
        // A second prepare_append is a no-op (already private).
        assert_eq!(arena.prepare_append(&mut b, 3), Some(false));
        arena.assert_partition([&a, &b]);
        arena.release(&mut a);
        arena.release(&mut b);
        assert_eq!(arena.blocks_free(), 4);
    }

    #[test]
    fn prepare_append_private_block_is_noop() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 4, 4);
        let mut a = KvSeq::new();
        assert!(arena.ensure(&mut a, 4));
        a.len = 2;
        assert_eq!(arena.prepare_append(&mut a, 4), Some(false));
        assert_eq!(a.n_blocks(), 1);
        // Cursor at capacity: grows the table, still no CoW.
        a.len = 4;
        assert_eq!(arena.prepare_append(&mut a, 5), Some(false));
        assert_eq!(a.n_blocks(), 2);
        arena.release(&mut a);
    }

    #[test]
    fn prepare_append_reports_starvation() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 4, 1);
        let mut a = KvSeq::new();
        assert!(arena.ensure(&mut a, 4));
        a.len = 4;
        let mut b = KvSeq::new();
        arena.retain(&mut b, a.blocks()[0]);
        b.len = 2;
        // CoW needs a free block and there is none.
        assert_eq!(arena.prepare_append(&mut b, 3), None);
        assert_eq!(b.n_blocks(), 1, "starved prepare_append leaves the table intact");
        assert!(arena.is_shared(b.blocks()[0]));
        arena.release(&mut a);
        arena.release(&mut b);
    }

    #[test]
    fn row_addressing_is_disjoint_and_stable() {
        // Write a unique pattern into every (seq, layer, pos, k/v) row via the
        // mut accessors, then read everything back — any overlap between rows,
        // layers, K/V halves, or sequences would corrupt the pattern.
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 4, 8);
        let mut seqs: Vec<KvSeq> = (0..2).map(|_| KvSeq::new()).collect();
        let positions = 10usize; // crosses block boundaries (4-position blocks)
        for s in seqs.iter_mut() {
            assert!(arena.ensure(s, positions));
        }
        let tag = |si: usize, li: usize, pos: usize, v: bool, d: usize| {
            (si * 100_000 + li * 10_000 + pos * 100 + (v as usize) * 10 + d) as f32
        };
        for (si, s) in seqs.iter().enumerate() {
            for li in 0..cfg.n_layers {
                for pos in 0..positions {
                    for d in 0..cfg.d_model {
                        arena.k_row_mut(s, li, pos)[d] = tag(si, li, pos, false, d);
                        arena.v_row_mut(s, li, pos)[d] = tag(si, li, pos, true, d);
                    }
                }
            }
        }
        for (si, s) in seqs.iter().enumerate() {
            for li in 0..cfg.n_layers {
                for pos in 0..positions {
                    for d in 0..cfg.d_model {
                        assert_eq!(arena.k_row(s, li, pos)[d], tag(si, li, pos, false, d));
                        assert_eq!(arena.v_row(s, li, pos)[d], tag(si, li, pos, true, d));
                    }
                }
            }
        }
    }

    #[test]
    fn partition_checker_accepts_every_acquire_release_state() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        let mut a = KvSeq::new();
        let mut b = KvSeq::new();
        arena.assert_partition(std::iter::empty()); // all free
        assert!(arena.ensure(&mut a, 20));
        assert!(arena.ensure(&mut b, 8));
        arena.assert_partition([&a, &b]);
        arena.release(&mut a);
        arena.assert_partition([&b]);
        arena.release(&mut b);
        arena.assert_partition(std::iter::empty());
    }

    #[test]
    fn partition_checker_accepts_shared_and_index_references() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        let mut a = KvSeq::new();
        let mut b = KvSeq::new();
        assert!(arena.ensure(&mut a, 16));
        let shared = a.blocks()[0];
        arena.retain(&mut b, shared);
        b.len = 8;
        // The prefix index pins a's second block too.
        let pinned = a.blocks()[1];
        arena.retain_block(pinned);
        arena.assert_partition_with([&a, &b], [pinned]);
        // Table release leaves the index reference alive.
        arena.release(&mut a);
        arena.assert_partition_with([&b], [pinned]);
        arena.release_block(pinned);
        arena.release(&mut b);
        arena.assert_partition_with(std::iter::empty(), std::iter::empty());
    }

    #[test]
    #[should_panic(expected = "disagrees with the")]
    fn partition_checker_catches_missing_table() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        let mut a = KvSeq::new();
        assert!(arena.ensure(&mut a, 8));
        // `a` holds a block but is withheld from the checked set: its block's
        // refcount (1) disagrees with the zero references visible.
        arena.assert_partition(std::iter::empty());
    }

    #[test]
    #[should_panic(expected = "twice in the free list")]
    fn partition_checker_catches_double_free_entry() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        // Corrupt the free list directly (release() itself would catch the
        // double-release before the list is ever corrupted).
        let b = *arena.free.last().unwrap();
        arena.free.push(b);
        arena.assert_partition(std::iter::empty());
    }

    #[test]
    #[should_panic(expected = "double-released")]
    fn release_catches_stale_table() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        let mut a = KvSeq::new();
        assert!(arena.ensure(&mut a, 8));
        // Clone the table, release once, then release the stale copy: the
        // always-on refcount must flag the second return of the block.
        let mut stale = KvSeq { blocks: a.blocks.clone(), len: a.len };
        arena.release(&mut a);
        arena.release(&mut stale);
    }

    #[test]
    #[should_panic(expected = "retained while free")]
    fn retain_catches_free_block() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 8, 4);
        let mut a = KvSeq::new();
        assert!(arena.ensure(&mut a, 8));
        let blk = a.blocks()[0];
        arena.release(&mut a);
        arena.retain_block(blk);
    }

    #[test]
    fn blocks_for_rounds_up() {
        let cfg = tiny_cfg();
        let arena = KvArena::new(&cfg, 8, 4);
        assert_eq!(arena.blocks_for(0), 0);
        assert_eq!(arena.blocks_for(1), 1);
        assert_eq!(arena.blocks_for(8), 1);
        assert_eq!(arena.blocks_for(9), 2);
        assert_eq!(KvArena::blocks_for_positions(17, 8), 3);
    }

    #[test]
    fn block_bytes_matches_allocation() {
        let cfg = tiny_cfg();
        let arena = KvArena::new(&cfg, 8, 3);
        assert_eq!(arena.data.len() * 4, 3 * KvArena::block_bytes(&cfg, 8));
        // One full-length sequence in blocks == the contiguous cache bytes.
        let blocks = arena.blocks_for(cfg.max_seq);
        assert_eq!(blocks * KvArena::block_bytes(&cfg, 8), KvCache::size_bytes_for(&cfg));
    }

    #[test]
    fn chain_hash_is_positional() {
        let a = [1u16, 2, 3, 4];
        let b = [1u16, 2, 3, 5];
        let h0 = chain_hash(PREFIX_HASH_SEED, &a);
        assert_eq!(h0, chain_hash(PREFIX_HASH_SEED, &a), "deterministic");
        assert_ne!(h0, chain_hash(PREFIX_HASH_SEED, &b), "content-sensitive");
        // The same block content under a different parent hashes differently:
        // a chain match certifies the whole prefix, not one block in isolation.
        assert_ne!(h0, chain_hash(h0, &a));
    }

    #[test]
    fn prefix_index_match_insert_dedupe() {
        let mut idx = PrefixIndex::new();
        let bp = 4usize;
        let toks: Vec<u16> = (0..12).collect();
        let (m, parent0) = idx.match_chain(&toks, bp);
        assert!(m.is_empty());
        assert_eq!(parent0, PREFIX_HASH_SEED);
        // Register blocks 0 and 1 of the stream.
        let h0 = chain_hash(PREFIX_HASH_SEED, &toks[0..4]);
        assert!(idx.insert(PREFIX_HASH_SEED, &toks[0..4], 7));
        assert!(idx.insert(h0, &toks[4..8], 3));
        assert_eq!(idx.len(), 2);
        // A second registration of the same logical prefix dedupes.
        assert!(!idx.insert(PREFIX_HASH_SEED, &toks[0..4], 9));
        assert_eq!(idx.len(), 2);
        // Full-prefix match walks the chain; a diverging stream stops early.
        let (m, parent) = idx.match_chain(&toks, bp);
        assert_eq!(m, vec![7, 3]);
        assert_eq!(parent, chain_hash(h0, &toks[4..8]));
        let mut div = toks.clone();
        div[5] = 999;
        let (m, _) = idx.match_chain(&div, bp);
        assert_eq!(m, vec![7], "divergence in block 1 keeps only block 0");
        // Fewer than bp tokens can never match a full block.
        let (m, _) = idx.match_chain(&toks[0..3], bp);
        assert!(m.is_empty());
    }

    #[test]
    fn prefix_index_reclaims_lru_index_only_blocks() {
        let cfg = tiny_cfg();
        let mut arena = KvArena::new(&cfg, 4, 4);
        let mut idx = PrefixIndex::new();
        let mut a = KvSeq::new();
        assert!(arena.ensure(&mut a, 12)); // blocks for tokens 0..12
        let toks: Vec<u16> = (100..112).collect();
        let mut parent = PREFIX_HASH_SEED;
        for (i, chunk) in toks.chunks_exact(4).enumerate() {
            assert!(idx.insert(parent, chunk, a.blocks()[i]));
            arena.retain_block(a.blocks()[i]);
            parent = chain_hash(parent, chunk);
        }
        arena.assert_partition_with([&a], idx.blocks());
        // While `a` is live every entry is aliased: nothing reclaimable.
        assert_eq!(idx.reclaim_one(&mut arena), None);
        let blocks: Vec<u32> = a.blocks().to_vec();
        arena.release(&mut a);
        assert_eq!(arena.blocks_free(), 1, "index keeps registered blocks resident");
        arena.assert_partition_with(std::iter::empty(), idx.blocks());
        // Refresh block 1's entry: block 0 is now strictly least recent.
        let (_, _) = idx.match_chain(&toks[0..8], 4);
        // All stamps refreshed in chain order; LRU falls back to insertion
        // order for the unmatched tail, so the untouched block 2 entry goes
        // first, then the chain in match order.
        assert_eq!(idx.reclaim_one(&mut arena), Some(blocks[2]));
        assert_eq!(idx.reclaim_one(&mut arena), Some(blocks[0]));
        assert_eq!(idx.reclaim_one(&mut arena), Some(blocks[1]));
        assert_eq!(idx.reclaim_one(&mut arena), None);
        assert!(idx.is_empty());
        assert_eq!(arena.blocks_free(), 4);
    }

    #[test]
    fn kv_block_resolution_precedence() {
        // cli > env > fallback > default; zeros and garbage fall through.
        assert_eq!(resolve_kv_block_from(16, Some("8"), 4), 16);
        assert_eq!(resolve_kv_block_from(0, Some("8"), 4), 8);
        assert_eq!(resolve_kv_block_from(0, Some("bogus"), 4), 4);
        assert_eq!(resolve_kv_block_from(0, Some("0"), 4), 4);
        assert_eq!(resolve_kv_block_from(0, None, 4), 4);
        assert_eq!(resolve_kv_block_from(0, None, 0), DEFAULT_KV_BLOCK);
    }

    #[test]
    fn prefill_chunk_resolution_precedence() {
        // Same ladder as kv_block: cli > env > fallback > default.
        assert_eq!(resolve_prefill_chunk_from(16, Some("8"), 4), 16);
        assert_eq!(resolve_prefill_chunk_from(0, Some("8"), 4), 8);
        assert_eq!(resolve_prefill_chunk_from(0, Some("bogus"), 4), 4);
        assert_eq!(resolve_prefill_chunk_from(0, Some("0"), 4), 4);
        assert_eq!(resolve_prefill_chunk_from(0, None, 4), 4);
        assert_eq!(resolve_prefill_chunk_from(0, None, 0), DEFAULT_PREFILL_CHUNK);
    }

    #[test]
    fn round_budget_resolution_precedence() {
        // cli > env > unlimited (0); there is deliberately no manifest tier.
        assert_eq!(resolve_round_budget_from(16, Some("8")), 16);
        assert_eq!(resolve_round_budget_from(0, Some("8")), 8);
        assert_eq!(resolve_round_budget_from(0, Some("bogus")), 0);
        assert_eq!(resolve_round_budget_from(0, Some("0")), 0);
        assert_eq!(resolve_round_budget_from(0, None), 0);
    }

    #[test]
    fn kv_layout_parse_and_resolve() {
        assert_eq!(KvLayout::parse("auto").unwrap(), KvLayout::Auto);
        assert_eq!(KvLayout::parse("contig").unwrap(), KvLayout::Contig);
        assert_eq!(KvLayout::parse("paged").unwrap(), KvLayout::Paged);
        assert!(KvLayout::parse("wat").is_err());
        assert_eq!(KvLayout::Auto.resolve(), KvLayout::Paged);
        assert_eq!(KvLayout::Contig.resolve(), KvLayout::Contig);
        for l in [KvLayout::Auto, KvLayout::Contig, KvLayout::Paged] {
            assert_eq!(KvLayout::parse(l.name()).unwrap(), l);
        }
    }
}
