//! The transformer substrate: a Llama-style decoder (RMSNorm, RoPE attention,
//! SwiGLU MLP) with two execution paths per linear layer:
//!
//! * **batch** (`forward_batch`) — full-sequence GEMMs for perplexity evaluation
//!   and Hessian calibration; quantized layers use a dense reconstruction cache
//!   (decode once, GEMM many).
//! * **step** (`decode_step`) — single-token matvec with a KV cache: the serving
//!   hot path, where quantized layers run the fused trellis-decode matvec
//!   (Table 4's regime: batch-1 autoregressive decoding is memory-bound, so the
//!   compressed stream beats fp32 on bandwidth).

use crate::model::config::ModelConfig;
use crate::model::kv::{KvArena, KvCache, KvSeq};
use crate::model::weights::WeightStore;
use crate::quant::{KernelKind, QuantizedMatrix};
use crate::util::matrix::{gemv, gemv_multi_pool, gemv_pool, Matrix};
use crate::util::rng::Rng;
use crate::util::threadpool::ExecPool;

/// A linear layer: dense or QTIP-quantized.
pub enum Linear {
    Dense(Matrix),
    Quantized {
        qm: QuantizedMatrix,
        /// Dense reconstruction for batch paths (built on demand).
        cache: Option<Matrix>,
    },
}

impl Linear {
    pub fn rows(&self) -> usize {
        match self {
            Linear::Dense(w) => w.rows,
            Linear::Quantized { qm, .. } => qm.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Linear::Dense(w) => w.cols,
            Linear::Quantized { qm, .. } => qm.cols,
        }
    }

    /// Bytes this layer needs at inference.
    pub fn size_bytes(&self) -> usize {
        match self {
            Linear::Dense(w) => w.data.len() * 4,
            Linear::Quantized { qm, .. } => qm.size_bytes(),
        }
    }

    /// Build the dense reconstruction cache for quantized layers.
    pub fn ensure_cache(&mut self) {
        if let Linear::Quantized { qm, cache } = self {
            if cache.is_none() {
                *cache = Some(qm.reconstruct_w());
            }
        }
    }

    pub fn drop_cache(&mut self) {
        if let Linear::Quantized { cache, .. } = self {
            *cache = None;
        }
    }

    /// y = W x (single vector; fused decode for quantized layers).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        match self {
            Linear::Dense(w) => w.matvec(x),
            Linear::Quantized { qm, .. } => qm.matvec(x),
        }
    }

    /// Allocation-free `y = W x` with the decode/GEMV striped across `pool`;
    /// `xt` stages the RHT'd activation copy for quantized layers.
    /// Bit-identical to [`Self::matvec`] at any worker count.
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32], xt: &mut Vec<f32>, pool: &ExecPool) {
        match self {
            Linear::Dense(w) => gemv_pool(w, x, y, pool),
            Linear::Quantized { qm, .. } => qm.matvec_into(x, y, xt, pool),
        }
    }

    /// Allocation-free batch-fused `Y = X Ŵᵀ` (one activation row per
    /// sequence); `y` is reshaped in place, `bxt`/`xcol` stage the RHT'd batch
    /// and its transpose for quantized layers. Row `b` is bit-identical to
    /// `matvec(x.row(b))` at any worker count.
    pub fn matvec_multi_into(
        &self,
        x: &Matrix,
        y: &mut Matrix,
        bxt: &mut Matrix,
        xcol: &mut Vec<f32>,
        pool: &ExecPool,
    ) {
        match self {
            Linear::Dense(w) => {
                y.reshape_scratch(x.rows, w.rows);
                // One dispatch for the whole batch — not one per row.
                gemv_multi_pool(w, x, y, pool);
            }
            Linear::Quantized { qm, .. } => qm.matvec_multi_into(x, y, bxt, xcol, pool),
        }
    }

    /// Y = X Ŵᵀ for a B×in batch of single-token activations: the fused batch
    /// decode path. Quantized layers decode each packed weight once and apply
    /// it to all B sequences; dense layers fall back to B independent GEMVs.
    /// Row `b` of the result is bit-identical to `matvec(x.row(b))`.
    pub fn matvec_multi(&self, x: &Matrix) -> Matrix {
        match self {
            Linear::Dense(w) => {
                let mut out = Matrix::zeros(x.rows, w.rows);
                for r in 0..x.rows {
                    gemv(w, x.row(r), out.row_mut(r));
                }
                out
            }
            Linear::Quantized { qm, .. } => qm.matvec_multi(x),
        }
    }

    /// Y = X Wᵀ for a T×in batch (dense path; quantized layers need the cache).
    pub fn forward_batch(&self, x: &Matrix) -> Matrix {
        self.forward_batch_pool(x, &ExecPool::sequential())
    }

    /// [`Self::forward_batch`] with the work striped across `pool`
    /// (bit-identical at any width — each output row accumulates on one
    /// worker in sequential order). Formulated as a batched GEMV
    /// (`out.row(t) = W @ x.row(t)`) so no `Wᵀ` is materialized per call —
    /// the seed's `gemm(x, w.transpose())` re-transposed every weight matrix
    /// on every eval window.
    pub fn forward_batch_pool(&self, x: &Matrix, pool: &ExecPool) -> Matrix {
        let w = match self {
            Linear::Dense(w) => w,
            Linear::Quantized { cache, .. } => cache
                .as_ref()
                .expect("call ensure_cache() before batch forward on quantized layers"),
        };
        let mut out = Matrix::zeros(x.rows, w.rows);
        gemv_multi_pool(w, x, &mut out, pool);
        out
    }
}

pub struct Attention {
    pub q: Linear,
    pub k: Linear,
    pub v: Linear,
    pub o: Linear,
}

pub struct Mlp {
    pub gate: Linear,
    pub up: Linear,
    pub down: Linear,
}

pub struct Layer {
    pub attn_norm: Vec<f32>,
    pub attn: Attention,
    pub mlp_norm: Vec<f32>,
    pub mlp: Mlp,
}

/// Uniform view of the KV storage for one decode round: the attention core is
/// generic over this trait, so the contiguous reference caches
/// ([`KvCache`]) and the paged arena ([`KvArena`] + [`KvSeq`] block tables)
/// run the **same** decode code path. Rows are read and written in the same
/// order either way, so the two layouts are bit-identical by construction.
pub trait KvBatch {
    /// Sequences in the round.
    fn n(&self) -> usize;
    /// Positions already written for sequence `i`.
    fn len(&self, i: usize) -> usize;
    /// Panics when sequence `i` cannot take one more position (the contiguous
    /// cache is full, or the scheduler failed to lease a block).
    fn check_capacity(&self, i: usize);
    fn k_row(&self, i: usize, layer: usize, pos: usize) -> &[f32];
    fn v_row(&self, i: usize, layer: usize, pos: usize) -> &[f32];
    /// Write the new K/V rows for sequence `i` at its current length.
    fn store(&mut self, i: usize, layer: usize, k: &[f32], v: &[f32]);
    /// Sequence `i` advanced one position this round.
    fn advance(&mut self, i: usize);
}

/// [`KvBatch`] over per-sequence contiguous caches (the reference layout).
pub struct ContigKv<'a, 'b>(pub &'a mut [&'b mut KvCache]);

impl KvBatch for ContigKv<'_, '_> {
    fn n(&self) -> usize {
        self.0.len()
    }

    fn len(&self, i: usize) -> usize {
        self.0[i].len
    }

    fn check_capacity(&self, i: usize) {
        assert!(self.0[i].len < self.0[i].capacity, "KV cache full");
    }

    fn k_row(&self, i: usize, layer: usize, pos: usize) -> &[f32] {
        self.0[i].k[layer].row(pos)
    }

    fn v_row(&self, i: usize, layer: usize, pos: usize) -> &[f32] {
        self.0[i].v[layer].row(pos)
    }

    fn store(&mut self, i: usize, layer: usize, k: &[f32], v: &[f32]) {
        let pos = self.0[i].len;
        self.0[i].k[layer].row_mut(pos).copy_from_slice(k);
        self.0[i].v[layer].row_mut(pos).copy_from_slice(v);
    }

    fn advance(&mut self, i: usize) {
        self.0[i].len += 1;
    }
}

/// [`KvBatch`] over the shared paged arena: each sequence reads and writes
/// through its own block table. Reads tolerate aliased (prefix-shared)
/// blocks; writes land at the append cursor, so the scheduler must have run
/// [`KvArena::prepare_append`] (or [`KvArena::ensure`] when sharing is off)
/// before the round — that privatizes a shared tail block (copy-on-write)
/// and acquires capacity for one more position per stepping sequence.
/// [`KvBatch::check_capacity`] enforces the capacity half of that contract;
/// the arena's debug write-guard enforces the privacy half.
pub struct PagedKv<'a, 'b> {
    pub arena: &'a mut KvArena,
    pub seqs: &'a mut [&'b mut KvSeq],
}

impl KvBatch for PagedKv<'_, '_> {
    fn n(&self) -> usize {
        self.seqs.len()
    }

    fn len(&self, i: usize) -> usize {
        self.seqs[i].len
    }

    fn check_capacity(&self, i: usize) {
        let seq = &*self.seqs[i];
        assert!(
            seq.len < self.arena.seq_capacity(seq),
            "paged KV sequence has no block for position {} — the scheduler must \
             KvArena::prepare_append/ensure capacity before the decode round",
            seq.len
        );
    }

    fn k_row(&self, i: usize, layer: usize, pos: usize) -> &[f32] {
        self.arena.k_row(&*self.seqs[i], layer, pos)
    }

    fn v_row(&self, i: usize, layer: usize, pos: usize) -> &[f32] {
        self.arena.v_row(&*self.seqs[i], layer, pos)
    }

    fn store(&mut self, i: usize, layer: usize, k: &[f32], v: &[f32]) {
        let seq = &*self.seqs[i];
        let pos = seq.len;
        self.arena.k_row_mut(seq, layer, pos).copy_from_slice(k);
        self.arena.v_row_mut(seq, layer, pos).copy_from_slice(v);
    }

    fn advance(&mut self, i: usize) {
        self.seqs[i].len += 1;
    }
}

/// Persistent scratch arena for the serving forward pass.
///
/// The seed's `decode_step` allocated ~10 fresh vectors per token per layer
/// (`x.clone()`, q/k/v, attention scores, MLP activations, …) plus a full
/// activation transpose per fused linear — all garbage one round later. The
/// arena owns every buffer the decode paths touch; in the steady state the
/// serving forward pass performs **zero** heap allocations (buffers grow to
/// the high-water batch size once, then are reused). One arena serves both the
/// single-token and batch paths; it is owned by whoever owns the
/// [`crate::util::threadpool::ExecPool`] (the serve loop, a bench, a test).
pub struct DecodeScratch {
    // Single-token path (lengths: d_model unless noted).
    x: Vec<f32>,
    xn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj: Vec<f32>,
    gate: Vec<f32>, // d_ff; SwiGLU activation is computed into it in place
    up: Vec<f32>,   // d_ff
    down: Vec<f32>,
    scores: Vec<f32>, // max_seq
    logits: Vec<f32>, // vocab
    // Shared: RHT'd activation copy for quantized matvecs (max(d, d_ff)).
    xt: Vec<f32>,
    // Batch path (B × ·, reshaped in place as the live batch changes).
    bx: Matrix,
    bxn: Matrix,
    bq: Matrix,
    bk: Matrix,
    bv: Matrix,
    battn: Matrix,
    bproj: Matrix,
    bgate: Matrix,
    bup: Matrix,
    bdown: Matrix,
    blogits: Matrix,
    bxt: Matrix,      // RHT'd batch copy for quantized multi kernels
    xcol: Vec<f32>,   // column-major activations (cols × B)
}

impl DecodeScratch {
    pub fn new(cfg: &ModelConfig) -> DecodeScratch {
        let d = cfg.d_model;
        DecodeScratch {
            x: vec![0.0; d],
            xn: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            attn_out: vec![0.0; d],
            proj: vec![0.0; d],
            gate: vec![0.0; cfg.d_ff],
            up: vec![0.0; cfg.d_ff],
            down: vec![0.0; d],
            scores: vec![0.0; cfg.max_seq],
            logits: vec![0.0; cfg.vocab],
            xt: Vec::with_capacity(d.max(cfg.d_ff)),
            bx: Matrix::zeros(0, 0),
            bxn: Matrix::zeros(0, 0),
            bq: Matrix::zeros(0, 0),
            bk: Matrix::zeros(0, 0),
            bv: Matrix::zeros(0, 0),
            battn: Matrix::zeros(0, 0),
            bproj: Matrix::zeros(0, 0),
            bgate: Matrix::zeros(0, 0),
            bup: Matrix::zeros(0, 0),
            bdown: Matrix::zeros(0, 0),
            blogits: Matrix::zeros(0, 0),
            bxt: Matrix::zeros(0, 0),
            xcol: Vec::new(),
        }
    }
}

pub struct Transformer {
    pub cfg: ModelConfig,
    pub tok_emb: Matrix,
    pub layers: Vec<Layer>,
    pub out_norm: Vec<f32>,
    pub head: Linear,
}

pub(crate) fn rmsnorm_row(x: &mut [f32], gain: &[f32], eps: f32) {
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + eps as f64).sqrt() as f32;
    for (v, &g) in x.iter_mut().zip(gain) {
        *v *= inv * g;
    }
}

/// RoPE rotation of one head-dim vector at `pos` (pairs (2i, 2i+1)).
pub(crate) fn rope_rotate(x: &mut [f32], pos: usize, theta: f32) {
    let dh = x.len();
    let mut i = 0;
    while i + 1 < dh {
        let freq = theta.powf(-(i as f32) / dh as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (x[i], x[i + 1]);
        x[i] = a * cos - b * sin;
        x[i + 1] = a * sin + b * cos;
        i += 2;
    }
}

pub(crate) fn softmax_inplace(x: &mut [f32]) {
    let max = x.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

impl Transformer {
    /// Assemble a dense model from a weight store.
    pub fn from_store(ws: &WeightStore) -> Transformer {
        let cfg = ws.config.clone();
        let layers = (0..cfg.n_layers)
            .map(|i| Layer {
                attn_norm: ws.get(&format!("l{i}.attn_norm")).data.clone(),
                attn: Attention {
                    q: Linear::Dense(ws.get(&format!("l{i}.q")).clone()),
                    k: Linear::Dense(ws.get(&format!("l{i}.k")).clone()),
                    v: Linear::Dense(ws.get(&format!("l{i}.v")).clone()),
                    o: Linear::Dense(ws.get(&format!("l{i}.o")).clone()),
                },
                mlp_norm: ws.get(&format!("l{i}.mlp_norm")).data.clone(),
                mlp: Mlp {
                    gate: Linear::Dense(ws.get(&format!("l{i}.gate")).clone()),
                    up: Linear::Dense(ws.get(&format!("l{i}.up")).clone()),
                    down: Linear::Dense(ws.get(&format!("l{i}.down")).clone()),
                },
            })
            .collect();
        Transformer {
            cfg: cfg.clone(),
            tok_emb: ws.get("tok_emb").clone(),
            layers,
            out_norm: ws.get("out_norm").data.clone(),
            head: Linear::Dense(ws.get("head").clone()),
        }
    }

    /// Immutable view of all quantizable linear layers with canonical names
    /// (same order as [`Self::linears_mut`]; the artifact writer walks this).
    pub fn linears(&self) -> Vec<(String, &Linear)> {
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            out.push((format!("l{i}.q"), &layer.attn.q));
            out.push((format!("l{i}.k"), &layer.attn.k));
            out.push((format!("l{i}.v"), &layer.attn.v));
            out.push((format!("l{i}.o"), &layer.attn.o));
            out.push((format!("l{i}.gate"), &layer.mlp.gate));
            out.push((format!("l{i}.up"), &layer.mlp.up));
            out.push((format!("l{i}.down"), &layer.mlp.down));
        }
        out
    }

    /// Iterate all quantizable linear layers with canonical names.
    pub fn linears_mut(&mut self) -> Vec<(String, &mut Linear)> {
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter_mut().enumerate() {
            out.push((format!("l{i}.q"), &mut layer.attn.q));
            out.push((format!("l{i}.k"), &mut layer.attn.k));
            out.push((format!("l{i}.v"), &mut layer.attn.v));
            out.push((format!("l{i}.o"), &mut layer.attn.o));
            out.push((format!("l{i}.gate"), &mut layer.mlp.gate));
            out.push((format!("l{i}.up"), &mut layer.mlp.up));
            out.push((format!("l{i}.down"), &mut layer.mlp.down));
        }
        out
    }

    /// Total inference bytes of the decoder linears (+embeddings/head, fp32).
    pub fn size_bytes(&self) -> usize {
        let mut total = self.tok_emb.data.len() * 4 + self.head.size_bytes();
        for l in &self.layers {
            total += l.attn.q.size_bytes()
                + l.attn.k.size_bytes()
                + l.attn.v.size_bytes()
                + l.attn.o.size_bytes()
                + l.mlp.gate.size_bytes()
                + l.mlp.up.size_bytes()
                + l.mlp.down.size_bytes();
        }
        total
    }

    /// Build dense caches on all quantized layers (batch-path prerequisite).
    pub fn ensure_caches(&mut self) {
        for (_, lin) in self.linears_mut() {
            lin.ensure_cache();
        }
    }

    /// Decode-kernel family of the quantized layers (`None` when the model is
    /// fully dense). All layers share one selection, so the first quantized
    /// linear is representative; `ServerStats::kernel` reports this.
    pub fn decode_kernel(&self) -> Option<KernelKind> {
        self.linears().iter().find_map(|(_, lin)| match lin {
            Linear::Quantized { qm, .. } => Some(qm.kernel),
            Linear::Dense(_) => None,
        })
    }

    /// Pin every quantized layer onto `kernel` (`Auto` resolves to the
    /// default family). Outputs are bit-identical across families, so this
    /// only changes *how* the hot path decodes — serving tests use it to pin
    /// scalar vs lane kernels on the same loaded artifact.
    pub fn set_decode_kernel(&mut self, kernel: KernelKind) {
        let k = kernel.resolve();
        for (_, lin) in self.linears_mut() {
            if let Linear::Quantized { qm, .. } = lin {
                qm.kernel = k;
            }
        }
    }

    /// Full-sequence forward returning logits (T × vocab). Causal attention.
    pub fn forward_batch(&self, tokens: &[u16]) -> Matrix {
        self.forward_batch_with(tokens, &ExecPool::sequential())
    }

    /// [`Self::forward_batch`] with every layer GEMM striped across `pool`
    /// (bit-identical at any worker count). The eval/calibration batch path's
    /// share of the multi-core budget.
    pub fn forward_batch_with(&self, tokens: &[u16], pool: &ExecPool) -> Matrix {
        let t_len = tokens.len();
        let cfg = &self.cfg;
        assert!(t_len <= cfg.max_seq, "sequence longer than max_seq");
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let dh = cfg.head_dim();

        // Embedding lookup.
        let mut x = Matrix::zeros(t_len, d);
        for (t, &tok) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(self.tok_emb.row(tok as usize));
        }

        for layer in &self.layers {
            // --- Attention block ---
            let mut xn = x.clone();
            for r in 0..t_len {
                rmsnorm_row(xn.row_mut(r), &layer.attn_norm, cfg.rms_eps);
            }
            let mut q = layer.attn.q.forward_batch_pool(&xn, pool);
            let mut k = layer.attn.k.forward_batch_pool(&xn, pool);
            let v = layer.attn.v.forward_batch_pool(&xn, pool);
            // RoPE per position per head.
            for t in 0..t_len {
                for head in 0..h {
                    rope_rotate(&mut q.row_mut(t)[head * dh..(head + 1) * dh], t, cfg.rope_theta);
                    rope_rotate(&mut k.row_mut(t)[head * dh..(head + 1) * dh], t, cfg.rope_theta);
                }
            }
            // Scaled dot-product attention, causal.
            let scale = 1.0 / (dh as f32).sqrt();
            let mut attn_out = Matrix::zeros(t_len, d);
            let mut scores = vec![0.0f32; t_len];
            for head in 0..h {
                let hs = head * dh;
                for tq in 0..t_len {
                    let qrow = &q.row(tq)[hs..hs + dh];
                    for tk in 0..=tq {
                        let krow = &k.row(tk)[hs..hs + dh];
                        scores[tk] = crate::util::matrix::dot(qrow, krow) * scale;
                    }
                    softmax_inplace(&mut scores[..=tq]);
                    let out = &mut attn_out.row_mut(tq)[hs..hs + dh];
                    for tk in 0..=tq {
                        let w = scores[tk];
                        let vrow = &v.row(tk)[hs..hs + dh];
                        for i in 0..dh {
                            out[i] += w * vrow[i];
                        }
                    }
                }
            }
            let proj = layer.attn.o.forward_batch_pool(&attn_out, pool);
            x.axpy(1.0, &proj);

            // --- MLP block ---
            let mut xn = x.clone();
            for r in 0..t_len {
                rmsnorm_row(xn.row_mut(r), &layer.mlp_norm, cfg.rms_eps);
            }
            let gate = layer.mlp.gate.forward_batch_pool(&xn, pool);
            let up = layer.mlp.up.forward_batch_pool(&xn, pool);
            let mut act = gate;
            for (a, &u) in act.data.iter_mut().zip(&up.data) {
                *a = silu(*a) * u;
            }
            let down = layer.mlp.down.forward_batch_pool(&act, pool);
            x.axpy(1.0, &down);
        }

        for r in 0..t_len {
            rmsnorm_row(x.row_mut(r), &self.out_norm, self.cfg.rms_eps);
        }
        self.head.forward_batch_pool(&x, pool)
    }

    /// Single-token decode step with KV cache; returns the logits vector.
    ///
    /// Convenience wrapper over [`Self::decode_step_with`] that pays a fresh
    /// scratch arena and a sequential pool per call — serving paths hold both
    /// persistently instead.
    pub fn decode_step(&self, cache: &mut KvCache, token: u16) -> Vec<f32> {
        let mut scratch = DecodeScratch::new(&self.cfg);
        let pool = ExecPool::sequential();
        self.decode_step_with(cache, token, &mut scratch, &pool).to_vec()
    }

    /// Allocation-free single-token decode: every temporary lives in `scratch`
    /// and every linear runs tile-parallel across `pool`. Returns the logits
    /// slice (borrowed from `scratch`). Bit-identical to the historical
    /// allocating `decode_step` at any worker count.
    pub fn decode_step_with<'s>(
        &self,
        cache: &mut KvCache,
        token: u16,
        scratch: &'s mut DecodeScratch,
        pool: &ExecPool,
    ) -> &'s [f32] {
        let mut one = [cache];
        let mut kv = ContigKv(&mut one);
        self.decode_step_core(&mut kv, 0, token, scratch, pool);
        self.head.matvec_into(&scratch.x, &mut scratch.logits, &mut scratch.xt, pool);
        &scratch.logits
    }

    /// Shared body of the single-token paths: advances sequence `i` of `kv`
    /// and leaves the out-normed final hidden state in `scratch.x` (the
    /// caller applies the head into its own logits target).
    fn decode_step_core<K: KvBatch>(
        &self,
        kv: &mut K,
        i: usize,
        token: u16,
        scratch: &mut DecodeScratch,
        pool: &ExecPool,
    ) {
        let cfg = &self.cfg;
        let pos = kv.len(i);
        kv.check_capacity(i);
        let h = cfg.n_heads;
        let dh = cfg.head_dim();

        let DecodeScratch { x, xn, q, k, v, attn_out, proj, gate, up, down, scores, xt, .. } =
            scratch;
        x.copy_from_slice(self.tok_emb.row(token as usize));
        for (li, layer) in self.layers.iter().enumerate() {
            xn.copy_from_slice(x);
            rmsnorm_row(xn, &layer.attn_norm, cfg.rms_eps);
            layer.attn.q.matvec_into(xn, q, xt, pool);
            layer.attn.k.matvec_into(xn, k, xt, pool);
            layer.attn.v.matvec_into(xn, v, xt, pool);
            for head in 0..h {
                rope_rotate(&mut q[head * dh..(head + 1) * dh], pos, cfg.rope_theta);
                rope_rotate(&mut k[head * dh..(head + 1) * dh], pos, cfg.rope_theta);
            }
            kv.store(i, li, k, v);

            let scale = 1.0 / (dh as f32).sqrt();
            attn_out.fill(0.0);
            let scores = &mut scores[..pos + 1];
            for head in 0..h {
                let hs = head * dh;
                let qh = &q[hs..hs + dh];
                for tk in 0..=pos {
                    scores[tk] =
                        crate::util::matrix::dot(qh, &kv.k_row(i, li, tk)[hs..hs + dh]) * scale;
                }
                softmax_inplace(scores);
                for tk in 0..=pos {
                    let w = scores[tk];
                    let vrow = &kv.v_row(i, li, tk)[hs..hs + dh];
                    for j in 0..dh {
                        attn_out[hs + j] += w * vrow[j];
                    }
                }
            }
            layer.attn.o.matvec_into(attn_out, proj, xt, pool);
            for (xv, &p) in x.iter_mut().zip(proj.iter()) {
                *xv += p;
            }

            xn.copy_from_slice(x);
            rmsnorm_row(xn, &layer.mlp_norm, cfg.rms_eps);
            layer.mlp.gate.matvec_into(xn, gate, xt, pool);
            layer.mlp.up.matvec_into(xn, up, xt, pool);
            for (g, &u) in gate.iter_mut().zip(up.iter()) {
                *g = silu(*g) * u;
            }
            layer.mlp.down.matvec_into(gate, down, xt, pool);
            for (xv, &dn) in x.iter_mut().zip(down.iter()) {
                *xv += dn;
            }
        }
        kv.advance(i);
        rmsnorm_row(x, &self.out_norm, cfg.rms_eps);
    }

    /// Allocation-free fused decode round over contiguous caches: one row of
    /// returned logits per sequence, every temporary staged in `scratch`,
    /// every linear striped across `pool`. A 1-sequence round takes the
    /// tighter single-column kernels (no activation transpose); outputs are
    /// bit-identical either way, and bit-identical to per-sequence
    /// [`Self::decode_step`] calls. (The historical `decode_step_batch`
    /// convenience wrapper — fresh scratch, sequential pool, and a
    /// `Vec<Vec<f32>>` logits copy per call — is gone; hold a
    /// [`DecodeScratch`] and read rows off the returned matrix instead.)
    pub fn decode_step_batch_with<'s>(
        &self,
        caches: &mut [&mut KvCache],
        tokens: &[u16],
        scratch: &'s mut DecodeScratch,
        pool: &ExecPool,
    ) -> &'s Matrix {
        let mut kv = ContigKv(caches);
        self.decode_step_batch_kv(&mut kv, tokens, scratch, pool)
    }

    /// [`Self::decode_step_batch_with`] over the paged KV arena: each
    /// sequence attends through its own block table. The scheduler must have
    /// leased capacity for one more position per sequence
    /// ([`KvArena::ensure`]). Bit-identical to the contiguous path — same
    /// rows, same order, different addressing.
    pub fn decode_step_batch_paged<'s>(
        &self,
        arena: &mut KvArena,
        seqs: &mut [&mut KvSeq],
        tokens: &[u16],
        scratch: &'s mut DecodeScratch,
        pool: &ExecPool,
    ) -> &'s Matrix {
        let mut kv = PagedKv { arena, seqs };
        self.decode_step_batch_kv(&mut kv, tokens, scratch, pool)
    }

    /// One decode round for a whole serving batch: advance every sequence by
    /// one token, decoding each packed weight tile **once** for all B
    /// sequences. Sequences are independent — each attends over its own KV
    /// state at its own position (heterogeneous lengths are fine); only the
    /// weight decode is shared.
    fn decode_step_batch_kv<'s, K: KvBatch>(
        &self,
        kv: &mut K,
        tokens: &[u16],
        scratch: &'s mut DecodeScratch,
        pool: &ExecPool,
    ) -> &'s Matrix {
        let b = tokens.len();
        assert_eq!(kv.n(), b, "one KV sequence per token");
        let cfg = &self.cfg;
        if b == 0 {
            scratch.blogits.reshape_scratch(0, cfg.vocab);
            return &scratch.blogits;
        }
        if b == 1 {
            self.decode_step_core(kv, 0, tokens[0], scratch, pool);
            scratch.blogits.reshape_scratch(1, cfg.vocab);
            self.head.matvec_into(
                &scratch.x,
                scratch.blogits.row_mut(0),
                &mut scratch.xt,
                pool,
            );
            return &scratch.blogits;
        }
        let h = cfg.n_heads;
        let dh = cfg.head_dim();
        for i in 0..b {
            kv.check_capacity(i);
        }

        let DecodeScratch {
            scores, xcol, bx, bxn, bq, bk, bv, battn, bproj, bgate, bup, bdown, blogits, bxt, ..
        } = &mut *scratch;
        bx.reshape_scratch(b, cfg.d_model);
        for (bi, &tok) in tokens.iter().enumerate() {
            bx.row_mut(bi).copy_from_slice(self.tok_emb.row(tok as usize));
        }
        let x = bx;

        for (li, layer) in self.layers.iter().enumerate() {
            // --- Attention block (shared weight decode, per-sequence state) ---
            bxn.reshape_scratch(b, cfg.d_model);
            bxn.data.copy_from_slice(&x.data);
            for r in 0..b {
                rmsnorm_row(bxn.row_mut(r), &layer.attn_norm, cfg.rms_eps);
            }
            layer.attn.q.matvec_multi_into(bxn, bq, bxt, xcol, pool);
            layer.attn.k.matvec_multi_into(bxn, bk, bxt, xcol, pool);
            layer.attn.v.matvec_multi_into(bxn, bv, bxt, xcol, pool);
            for bi in 0..b {
                let pos = kv.len(bi);
                let theta = cfg.rope_theta;
                for head in 0..h {
                    rope_rotate(&mut bq.row_mut(bi)[head * dh..(head + 1) * dh], pos, theta);
                    rope_rotate(&mut bk.row_mut(bi)[head * dh..(head + 1) * dh], pos, theta);
                }
                kv.store(bi, li, bk.row(bi), bv.row(bi));
            }

            let scale = 1.0 / (dh as f32).sqrt();
            battn.reshape_scratch(b, cfg.d_model);
            battn.data.fill(0.0);
            for bi in 0..b {
                let pos = kv.len(bi);
                let out = battn.row_mut(bi);
                let scores = &mut scores[..pos + 1];
                for head in 0..h {
                    let hs = head * dh;
                    let qh = &bq.row(bi)[hs..hs + dh];
                    for tk in 0..=pos {
                        scores[tk] =
                            crate::util::matrix::dot(qh, &kv.k_row(bi, li, tk)[hs..hs + dh])
                                * scale;
                    }
                    softmax_inplace(scores);
                    for tk in 0..=pos {
                        let w = scores[tk];
                        let vrow = &kv.v_row(bi, li, tk)[hs..hs + dh];
                        for i in 0..dh {
                            out[hs + i] += w * vrow[i];
                        }
                    }
                }
            }
            layer.attn.o.matvec_multi_into(battn, bproj, bxt, xcol, pool);
            x.axpy(1.0, bproj);

            // --- MLP block ---
            bxn.data.copy_from_slice(&x.data);
            for r in 0..b {
                rmsnorm_row(bxn.row_mut(r), &layer.mlp_norm, cfg.rms_eps);
            }
            layer.mlp.gate.matvec_multi_into(bxn, bgate, bxt, xcol, pool);
            layer.mlp.up.matvec_multi_into(bxn, bup, bxt, xcol, pool);
            for (a, &u) in bgate.data.iter_mut().zip(&bup.data) {
                *a = silu(*a) * u;
            }
            layer.mlp.down.matvec_multi_into(bgate, bdown, bxt, xcol, pool);
            x.axpy(1.0, bdown);
        }

        for i in 0..b {
            kv.advance(i);
        }
        for r in 0..b {
            rmsnorm_row(x.row_mut(r), &self.out_norm, cfg.rms_eps);
        }
        self.head.matvec_multi_into(x, blogits, bxt, xcol, pool);
        &scratch.blogits
    }

    /// GEMM prefill of one paged sequence: append `tokens` (a chunk of C
    /// prompt positions) in a single pass, decoding each packed weight tile
    /// **once** for all C positions via the batched `matvec_multi` kernels —
    /// the same amortization the fused decode round applies across sequences,
    /// applied here across positions of one sequence. Returns the logits of
    /// the chunk's **last** position (borrowed from `scratch`); earlier
    /// positions' logits are never formed (prefill discards them anyway).
    ///
    /// Bit-identical to feeding the chunk token-at-a-time through
    /// [`Self::decode_step_batch_paged`]: per-row `matvec_multi` output equals
    /// the single-column `matvec` (the PR-1 kernel contract), each position's
    /// K/V rows are stored before any in-chunk position attends to them, and
    /// the per-position attention (RoPE at absolute position, score order,
    /// softmax, V accumulation) is the same code shape in the same order — so
    /// every f32 op sequence matches the reference path exactly.
    ///
    /// Contract: the scheduler has leased capacity for the whole chunk
    /// (`KvArena::prepare_append(seq, seq.len + tokens.len())`), which also
    /// privatized any shared cursor block; blocks past the cursor are freshly
    /// acquired and thus always private. Steady state is allocation-free: the
    /// batch matrices in `scratch` are reshaped in place.
    pub fn prefill_chunk_paged<'s>(
        &self,
        arena: &mut KvArena,
        seq: &mut KvSeq,
        tokens: &[u16],
        scratch: &'s mut DecodeScratch,
        pool: &ExecPool,
    ) -> &'s [f32] {
        let cfg = &self.cfg;
        let c = tokens.len();
        assert!(c > 0, "prefill chunk must be non-empty");
        let base = seq.len;
        assert!(
            arena.seq_capacity(seq) >= base + c,
            "paged KV sequence has no block for positions {}..{} — the scheduler must \
             KvArena::prepare_append the whole chunk before the prefill pass",
            base,
            base + c
        );
        if c == 1 {
            // A 1-token chunk is exactly a single-token decode step; route it
            // through the shared core so the degenerate case cannot drift.
            let mut one = [seq];
            let mut kv = PagedKv { arena, seqs: &mut one };
            self.decode_step_core(&mut kv, 0, tokens[0], scratch, pool);
            self.head.matvec_into(&scratch.x, &mut scratch.logits, &mut scratch.xt, pool);
            return &scratch.logits;
        }
        let h = cfg.n_heads;
        let dh = cfg.head_dim();

        let DecodeScratch {
            scores,
            xcol,
            bx,
            bxn,
            bq,
            bk,
            bv,
            battn,
            bproj,
            bgate,
            bup,
            bdown,
            bxt,
            logits,
            xt,
            ..
        } = &mut *scratch;
        bx.reshape_scratch(c, cfg.d_model);
        for (r, &tok) in tokens.iter().enumerate() {
            bx.row_mut(r).copy_from_slice(self.tok_emb.row(tok as usize));
        }
        let x = bx;

        for (li, layer) in self.layers.iter().enumerate() {
            // --- Attention block (shared weight decode, per-position state) ---
            bxn.reshape_scratch(c, cfg.d_model);
            bxn.data.copy_from_slice(&x.data);
            for r in 0..c {
                rmsnorm_row(bxn.row_mut(r), &layer.attn_norm, cfg.rms_eps);
            }
            layer.attn.q.matvec_multi_into(bxn, bq, bxt, xcol, pool);
            layer.attn.k.matvec_multi_into(bxn, bk, bxt, xcol, pool);
            layer.attn.v.matvec_multi_into(bxn, bv, bxt, xcol, pool);
            // Store every chunk position's K/V before any attention: row r
            // attends causally over 0..=base+r, which includes earlier rows of
            // this same chunk at this same layer.
            for r in 0..c {
                let pos = base + r;
                let theta = cfg.rope_theta;
                for head in 0..h {
                    rope_rotate(&mut bq.row_mut(r)[head * dh..(head + 1) * dh], pos, theta);
                    rope_rotate(&mut bk.row_mut(r)[head * dh..(head + 1) * dh], pos, theta);
                }
                arena.k_row_mut(seq, li, pos).copy_from_slice(bk.row(r));
                arena.v_row_mut(seq, li, pos).copy_from_slice(bv.row(r));
            }

            let scale = 1.0 / (dh as f32).sqrt();
            battn.reshape_scratch(c, cfg.d_model);
            battn.data.fill(0.0);
            for r in 0..c {
                let pos = base + r;
                let out = battn.row_mut(r);
                let scores = &mut scores[..pos + 1];
                for head in 0..h {
                    let hs = head * dh;
                    let qh = &bq.row(r)[hs..hs + dh];
                    for tk in 0..=pos {
                        scores[tk] =
                            crate::util::matrix::dot(qh, &arena.k_row(seq, li, tk)[hs..hs + dh])
                                * scale;
                    }
                    softmax_inplace(scores);
                    for tk in 0..=pos {
                        let w = scores[tk];
                        let vrow = &arena.v_row(seq, li, tk)[hs..hs + dh];
                        for i in 0..dh {
                            out[hs + i] += w * vrow[i];
                        }
                    }
                }
            }
            layer.attn.o.matvec_multi_into(battn, bproj, bxt, xcol, pool);
            x.axpy(1.0, bproj);

            // --- MLP block ---
            bxn.data.copy_from_slice(&x.data);
            for r in 0..c {
                rmsnorm_row(bxn.row_mut(r), &layer.mlp_norm, cfg.rms_eps);
            }
            layer.mlp.gate.matvec_multi_into(bxn, bgate, bxt, xcol, pool);
            layer.mlp.up.matvec_multi_into(bxn, bup, bxt, xcol, pool);
            for (a, &u) in bgate.data.iter_mut().zip(&bup.data) {
                *a = silu(*a) * u;
            }
            layer.mlp.down.matvec_multi_into(bgate, bdown, bxt, xcol, pool);
            x.axpy(1.0, bdown);
        }

        seq.len = base + c;
        // Only the last position's logits are observable (prefill discards
        // earlier rows), so only that row is out-normed and headed — the
        // single-column head matvec is bit-identical to the multi kernel's
        // per-row output.
        rmsnorm_row(x.row_mut(c - 1), &self.out_norm, cfg.rms_eps);
        self.head.matvec_into(x.row(c - 1), logits, xt, pool);
        &scratch.logits
    }

    /// Sample a token from logits (temperature + top-k; greedy if temp == 0).
    ///
    /// NaN-tolerant by construction: comparisons use a total order with NaN
    /// ranked below every finite logit, so one poisoned logit degrades to "that
    /// token is never picked" instead of panicking the serving thread.
    pub fn sample(logits: &[f32], temp: f32, top_k: usize, rng: &mut Rng) -> u16 {
        let key = |v: f32| if v.is_nan() { f32::NEG_INFINITY } else { v };
        if temp <= 0.0 {
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (i, &v) in logits.iter().enumerate() {
                if key(v) > best_v {
                    best = i;
                    best_v = key(v);
                }
            }
            return best as u16;
        }
        let k = top_k.max(1).min(logits.len());
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| key(logits[b]).total_cmp(&key(logits[a])));
        idx.truncate(k);
        // key() again: a NaN that survives into the top-k (top_k ≥ #finite
        // logits) must weight as exp(-inf) = 0, not poison the whole softmax.
        let mut probs: Vec<f32> = idx.iter().map(|&i| key(logits[i]) / temp).collect();
        softmax_inplace(&mut probs);
        let mut r = rng.uniform() as f32;
        for (j, &p) in probs.iter().enumerate() {
            r -= p;
            if r <= 0.0 {
                return idx[j] as u16;
            }
        }
        idx[k - 1] as u16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tiny_model(seed: u64) -> Transformer {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 2;
        cfg.max_seq = 32;
        cfg.name = "tiny".into();
        let ws = WeightStore::random(&cfg, seed);
        Transformer::from_store(&ws)
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_model(1);
        let logits = m.forward_batch(&[1, 2, 3, 4]);
        assert_eq!(logits.rows, 4);
        assert_eq!(logits.cols, 256);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_matches_batch_forward() {
        // Token-by-token decode must reproduce the full-sequence logits.
        let m = tiny_model(2);
        let tokens = [10u16, 200, 37, 99, 5];
        let batch = m.forward_batch(&tokens);
        let mut cache = KvCache::new(&m.cfg);
        for (t, &tok) in tokens.iter().enumerate() {
            let logits = m.decode_step(&mut cache, tok);
            for (a, b) in logits.iter().zip(batch.row(t)) {
                assert!((a - b).abs() < 1e-3, "pos {t}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn causality() {
        // Changing a future token must not affect earlier logits.
        let m = tiny_model(3);
        let a = m.forward_batch(&[1, 2, 3, 4]);
        let b = m.forward_batch(&[1, 2, 3, 250]);
        for t in 0..3 {
            for c in 0..256 {
                assert_eq!(a.at(t, c), b.at(t, c), "t={t}");
            }
        }
    }

    #[test]
    fn rope_is_position_sensitive() {
        // Permuting the prefix must change the last position's logits: a
        // position-free (bag-of-prefix) attention would produce identical rows.
        let m = tiny_model(4);
        let a = m.forward_batch(&[9, 7, 7]);
        let b = m.forward_batch(&[7, 9, 7]);
        let ra: Vec<f32> = a.row(2).to_vec();
        let rb: Vec<f32> = b.row(2).to_vec();
        assert!(ra.iter().zip(&rb).any(|(x, y)| (x - y).abs() > 1e-4));
    }

    #[test]
    fn sample_greedy_picks_argmax() {
        let mut logits = vec![0.0f32; 256];
        logits[42] = 10.0;
        let mut rng = Rng::new(1);
        assert_eq!(Transformer::sample(&logits, 0.0, 1, &mut rng), 42);
    }

    #[test]
    fn sample_topk_restricts_support() {
        let mut logits = vec![-100.0f32; 256];
        logits[10] = 5.0;
        logits[11] = 4.9;
        logits[12] = 4.8;
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let t = Transformer::sample(&logits, 1.0, 3, &mut rng);
            assert!([10, 11, 12].contains(&t));
        }
    }

    #[test]
    fn kv_cache_accounting() {
        let m = tiny_model(5);
        let cache = KvCache::new(&m.cfg);
        assert_eq!(cache.size_bytes(), 2 * 2 * 32 * 32 * 4);
        // The allocation-free size must agree with the allocated one.
        assert_eq!(KvCache::size_bytes_for(&m.cfg), cache.size_bytes());
    }

    #[test]
    fn decode_step_batch_matches_decode_step() {
        // Heterogeneous cache lengths: three sequences with different prefixes
        // must produce logits *bit-identical* to per-sequence decode_step.
        let m = tiny_model(6);
        let streams: [&[u16]; 3] = [&[10, 200, 37, 99, 5], &[7, 7, 42], &[250]];

        // Reference: per-sequence decode.
        let mut ref_logits: Vec<Vec<Vec<f32>>> = Vec::new();
        for s in &streams {
            let mut cache = KvCache::new(&m.cfg);
            ref_logits.push(s.iter().map(|&t| m.decode_step(&mut cache, t)).collect());
        }

        // Fused: one decode_step_batch_with round per position through one
        // persistent scratch, dropping sequences as they run out of tokens
        // (so batch composition changes mid-flight). Logits are read straight
        // off the returned matrix rows — no per-round Vec<Vec<f32>> copies.
        let mut scratch = DecodeScratch::new(&m.cfg);
        let pool = ExecPool::sequential();
        let mut caches: Vec<KvCache> = (0..3).map(|_| KvCache::new(&m.cfg)).collect();
        let max_len = streams.iter().map(|s| s.len()).max().unwrap();
        for pos in 0..max_len {
            let mut tokens = Vec::new();
            let mut idxs = Vec::new();
            for (i, s) in streams.iter().enumerate() {
                if pos < s.len() {
                    tokens.push(s[pos]);
                    idxs.push(i);
                }
            }
            let mut refs: Vec<&mut KvCache> = Vec::new();
            for (i, c) in caches.iter_mut().enumerate() {
                if idxs.contains(&i) {
                    refs.push(c);
                }
            }
            let logits = m.decode_step_batch_with(&mut refs, &tokens, &mut scratch, &pool);
            for (j, &i) in idxs.iter().enumerate() {
                assert_eq!(
                    logits.row(j),
                    &ref_logits[i][pos][..],
                    "seq {i} pos {pos}: fused logits diverged from decode_step"
                );
            }
        }
        for (c, s) in caches.iter().zip(&streams) {
            assert_eq!(c.len, s.len());
        }
    }

    #[test]
    fn decode_step_batch_empty_is_noop() {
        let m = tiny_model(7);
        let mut scratch = DecodeScratch::new(&m.cfg);
        let pool = ExecPool::sequential();
        let mut caches: Vec<&mut KvCache> = Vec::new();
        let logits = m.decode_step_batch_with(&mut caches, &[], &mut scratch, &pool);
        assert_eq!(logits.rows, 0);
    }

    #[test]
    fn paged_decode_bit_identical_to_contiguous() {
        // The paged arena must reproduce the contiguous reference caches
        // bit-for-bit at every position, for block sizes that divide the
        // stream length, don't, and degenerate to one position per block —
        // each geometry exercises different block-table boundaries.
        let m = tiny_model(9);
        let streams: [&[u16]; 3] = [&[10, 200, 37, 99, 5, 7], &[7, 7, 42], &[250, 1]];
        let mut scratch = DecodeScratch::new(&m.cfg);
        let pool = ExecPool::sequential();

        // Reference: contiguous fused rounds.
        let mut ref_rounds: Vec<Vec<Vec<f32>>> = Vec::new();
        let mut caches: Vec<KvCache> = (0..3).map(|_| KvCache::new(&m.cfg)).collect();
        let max_len = streams.iter().map(|s| s.len()).max().unwrap();
        for pos in 0..max_len {
            let (mut tokens, mut idxs) = (Vec::new(), Vec::new());
            for (i, s) in streams.iter().enumerate() {
                if pos < s.len() {
                    tokens.push(s[pos]);
                    idxs.push(i);
                }
            }
            let mut refs: Vec<&mut KvCache> = Vec::new();
            for (i, c) in caches.iter_mut().enumerate() {
                if idxs.contains(&i) {
                    refs.push(c);
                }
            }
            let logits = m.decode_step_batch_with(&mut refs, &tokens, &mut scratch, &pool);
            ref_rounds.push((0..tokens.len()).map(|r| logits.row(r).to_vec()).collect());
        }

        for block in [1usize, 3, 4, 32] {
            let n_blocks = 3 * m.cfg.max_seq.div_ceil(block);
            let mut arena = KvArena::new(&m.cfg, block, n_blocks);
            let mut seqs: Vec<KvSeq> = (0..3).map(|_| KvSeq::new()).collect();
            for pos in 0..max_len {
                let (mut tokens, mut idxs) = (Vec::new(), Vec::new());
                for (i, s) in streams.iter().enumerate() {
                    if pos < s.len() {
                        tokens.push(s[pos]);
                        idxs.push(i);
                    }
                }
                let mut refs: Vec<&mut KvSeq> = Vec::new();
                for (i, s) in seqs.iter_mut().enumerate() {
                    if idxs.contains(&i) {
                        let need = s.len + 1;
                        assert!(arena.ensure(&mut *s, need));
                        refs.push(s);
                    }
                }
                let logits =
                    m.decode_step_batch_paged(&mut arena, &mut refs, &tokens, &mut scratch, &pool);
                for (j, _) in idxs.iter().enumerate() {
                    assert_eq!(
                        logits.row(j),
                        &ref_rounds[pos][j][..],
                        "block={block} pos={pos}: paged logits diverged from contiguous"
                    );
                }
            }
            for (s, stream) in seqs.iter().zip(&streams) {
                assert_eq!(s.len, stream.len());
                assert_eq!(s.n_blocks(), stream.len().div_ceil(block));
            }
        }
    }

    #[test]
    fn sample_survives_nan_logits() {
        // Regression: a NaN logit used to panic via partial_cmp().unwrap(),
        // killing the serving thread. NaN now ranks below every finite logit.
        let mut logits = vec![0.0f32; 256];
        logits[3] = f32::NAN;
        logits[42] = 10.0;
        let mut rng = Rng::new(1);
        assert_eq!(Transformer::sample(&logits, 0.0, 1, &mut rng), 42);
        for _ in 0..50 {
            let t = Transformer::sample(&logits, 0.9, 4, &mut rng);
            assert!((t as usize) < 256);
            assert_ne!(t, 3, "NaN logit must never be sampled");
        }
        // NaN inside the top-k window must weight as zero, not win by default.
        let pair = vec![1.0f32, f32::NAN];
        for _ in 0..20 {
            assert_eq!(Transformer::sample(&pair, 1.0, 2, &mut rng), 0);
        }
        // All-NaN logits: still no panic.
        let all_nan = vec![f32::NAN; 8];
        let t = Transformer::sample(&all_nan, 1.0, 4, &mut rng);
        assert!((t as usize) < 8);
        let _ = Transformer::sample(&all_nan, 0.0, 1, &mut rng);
    }

    #[test]
    fn scratch_reuse_is_bit_identical_across_mixed_calls() {
        // One persistent arena serving interleaved single-token and batch
        // rounds (the serve-loop pattern) must reproduce the allocating
        // wrappers bit-for-bit, including after the batch width changes.
        let m = tiny_model(8);
        let mut scratch = DecodeScratch::new(&m.cfg);
        let pool = ExecPool::new(2);

        // Reference: allocating wrappers.
        let mut c1 = KvCache::new(&m.cfg);
        let r1: Vec<Vec<f32>> =
            [5u16, 9, 200].iter().map(|&t| m.decode_step(&mut c1, t)).collect();
        let mut c2 = KvCache::new(&m.cfg);
        let r2: Vec<Vec<f32>> = [17u16, 3].iter().map(|&t| m.decode_step(&mut c2, t)).collect();

        // Same streams through one scratch: batch round (B=2), then single
        // rounds (B=1 path), then batch again.
        let mut a = KvCache::new(&m.cfg);
        let mut b = KvCache::new(&m.cfg);
        {
            let mut refs: Vec<&mut KvCache> = vec![&mut a, &mut b];
            let logits = m.decode_step_batch_with(&mut refs, &[5, 17], &mut scratch, &pool);
            assert_eq!(logits.row(0), &r1[0][..]);
            assert_eq!(logits.row(1), &r2[0][..]);
        }
        let logits = m.decode_step_with(&mut a, 9, &mut scratch, &pool);
        assert_eq!(logits, &r1[1][..]);
        {
            let mut refs: Vec<&mut KvCache> = vec![&mut a, &mut b];
            let logits = m.decode_step_batch_with(&mut refs, &[200, 3], &mut scratch, &pool);
            assert_eq!(logits.row(0), &r1[2][..]);
            assert_eq!(logits.row(1), &r2[1][..]);
        }
        assert_eq!(a.len, 3);
        assert_eq!(b.len, 2);
    }

    #[test]
    fn rmsnorm_unit_gain_preserves_rms() {
        let mut x = vec![3.0f32, -4.0, 0.0, 1.0];
        let gain = vec![1.0f32; 4];
        rmsnorm_row(&mut x, &gain, 1e-6);
        let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-3);
    }
}
