//! The transformer substrate: a Llama-style decoder (RMSNorm, RoPE attention,
//! SwiGLU MLP) with two execution paths per linear layer:
//!
//! * **batch** (`forward_batch`) — full-sequence GEMMs for perplexity evaluation
//!   and Hessian calibration; quantized layers use a dense reconstruction cache
//!   (decode once, GEMM many).
//! * **step** (`decode_step`) — single-token matvec with a KV cache: the serving
//!   hot path, where quantized layers run the fused trellis-decode matvec
//!   (Table 4's regime: batch-1 autoregressive decoding is memory-bound, so the
//!   compressed stream beats fp32 on bandwidth).

use crate::model::config::ModelConfig;
use crate::model::weights::WeightStore;
use crate::quant::QuantizedMatrix;
use crate::util::matrix::{gemm, gemv, Matrix};
use crate::util::rng::Rng;

/// A linear layer: dense or QTIP-quantized.
pub enum Linear {
    Dense(Matrix),
    Quantized {
        qm: QuantizedMatrix,
        /// Dense reconstruction for batch paths (built on demand).
        cache: Option<Matrix>,
    },
}

impl Linear {
    pub fn rows(&self) -> usize {
        match self {
            Linear::Dense(w) => w.rows,
            Linear::Quantized { qm, .. } => qm.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Linear::Dense(w) => w.cols,
            Linear::Quantized { qm, .. } => qm.cols,
        }
    }

    /// Bytes this layer needs at inference.
    pub fn size_bytes(&self) -> usize {
        match self {
            Linear::Dense(w) => w.data.len() * 4,
            Linear::Quantized { qm, .. } => qm.size_bytes(),
        }
    }

    /// Build the dense reconstruction cache for quantized layers.
    pub fn ensure_cache(&mut self) {
        if let Linear::Quantized { qm, cache } = self {
            if cache.is_none() {
                *cache = Some(qm.reconstruct_w());
            }
        }
    }

    pub fn drop_cache(&mut self) {
        if let Linear::Quantized { cache, .. } = self {
            *cache = None;
        }
    }

    /// y = W x (single vector; fused decode for quantized layers).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        match self {
            Linear::Dense(w) => w.matvec(x),
            Linear::Quantized { qm, .. } => qm.matvec(x),
        }
    }

    /// Y = X Ŵᵀ for a B×in batch of single-token activations: the fused batch
    /// decode path. Quantized layers decode each packed weight once and apply
    /// it to all B sequences; dense layers fall back to B independent GEMVs.
    /// Row `b` of the result is bit-identical to `matvec(x.row(b))`.
    pub fn matvec_multi(&self, x: &Matrix) -> Matrix {
        match self {
            Linear::Dense(w) => {
                let mut out = Matrix::zeros(x.rows, w.rows);
                for r in 0..x.rows {
                    gemv(w, x.row(r), out.row_mut(r));
                }
                out
            }
            Linear::Quantized { qm, .. } => qm.matvec_multi(x),
        }
    }

    /// Y = X Wᵀ for a T×in batch (dense path; quantized layers need the cache).
    pub fn forward_batch(&self, x: &Matrix) -> Matrix {
        let w = match self {
            Linear::Dense(w) => w,
            Linear::Quantized { cache, .. } => cache
                .as_ref()
                .expect("call ensure_cache() before batch forward on quantized layers"),
        };
        let mut out = Matrix::zeros(x.rows, w.rows);
        let wt = w.transpose();
        gemm(x, &wt, &mut out);
        out
    }
}

pub struct Attention {
    pub q: Linear,
    pub k: Linear,
    pub v: Linear,
    pub o: Linear,
}

pub struct Mlp {
    pub gate: Linear,
    pub up: Linear,
    pub down: Linear,
}

pub struct Layer {
    pub attn_norm: Vec<f32>,
    pub attn: Attention,
    pub mlp_norm: Vec<f32>,
    pub mlp: Mlp,
}

/// Per-sequence KV cache.
pub struct KvCache {
    /// Per layer: (keys, values), each `max_seq × d_model` with `len` rows valid.
    pub k: Vec<Matrix>,
    pub v: Vec<Matrix>,
    pub len: usize,
    pub capacity: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> Self {
        KvCache {
            k: (0..cfg.n_layers)
                .map(|_| Matrix::zeros(cfg.max_seq, cfg.d_model))
                .collect(),
            v: (0..cfg.n_layers)
                .map(|_| Matrix::zeros(cfg.max_seq, cfg.d_model))
                .collect(),
            len: 0,
            capacity: cfg.max_seq,
        }
    }

    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Bytes held (for the server's cache manager accounting).
    pub fn size_bytes(&self) -> usize {
        self.k
            .iter()
            .chain(self.v.iter())
            .map(|m| m.data.len() * 4)
            .sum()
    }

    /// Bytes a cache built from `cfg` will hold, without allocating one — the
    /// server's per-round admission check must not allocate full K/V buffers
    /// just to read their size.
    pub fn size_bytes_for(cfg: &ModelConfig) -> usize {
        2 * cfg.n_layers * cfg.max_seq * cfg.d_model * 4
    }
}

pub struct Transformer {
    pub cfg: ModelConfig,
    pub tok_emb: Matrix,
    pub layers: Vec<Layer>,
    pub out_norm: Vec<f32>,
    pub head: Linear,
}

pub(crate) fn rmsnorm_row(x: &mut [f32], gain: &[f32], eps: f32) {
    let ms = x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / x.len() as f64;
    let inv = 1.0 / (ms + eps as f64).sqrt() as f32;
    for (v, &g) in x.iter_mut().zip(gain) {
        *v *= inv * g;
    }
}

/// RoPE rotation of one head-dim vector at `pos` (pairs (2i, 2i+1)).
pub(crate) fn rope_rotate(x: &mut [f32], pos: usize, theta: f32) {
    let dh = x.len();
    let mut i = 0;
    while i + 1 < dh {
        let freq = theta.powf(-(i as f32) / dh as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let (a, b) = (x[i], x[i + 1]);
        x[i] = a * cos - b * sin;
        x[i + 1] = a * sin + b * cos;
        i += 2;
    }
}

pub(crate) fn softmax_inplace(x: &mut [f32]) {
    let max = x.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

impl Transformer {
    /// Assemble a dense model from a weight store.
    pub fn from_store(ws: &WeightStore) -> Transformer {
        let cfg = ws.config.clone();
        let layers = (0..cfg.n_layers)
            .map(|i| Layer {
                attn_norm: ws.get(&format!("l{i}.attn_norm")).data.clone(),
                attn: Attention {
                    q: Linear::Dense(ws.get(&format!("l{i}.q")).clone()),
                    k: Linear::Dense(ws.get(&format!("l{i}.k")).clone()),
                    v: Linear::Dense(ws.get(&format!("l{i}.v")).clone()),
                    o: Linear::Dense(ws.get(&format!("l{i}.o")).clone()),
                },
                mlp_norm: ws.get(&format!("l{i}.mlp_norm")).data.clone(),
                mlp: Mlp {
                    gate: Linear::Dense(ws.get(&format!("l{i}.gate")).clone()),
                    up: Linear::Dense(ws.get(&format!("l{i}.up")).clone()),
                    down: Linear::Dense(ws.get(&format!("l{i}.down")).clone()),
                },
            })
            .collect();
        Transformer {
            cfg: cfg.clone(),
            tok_emb: ws.get("tok_emb").clone(),
            layers,
            out_norm: ws.get("out_norm").data.clone(),
            head: Linear::Dense(ws.get("head").clone()),
        }
    }

    /// Immutable view of all quantizable linear layers with canonical names
    /// (same order as [`Self::linears_mut`]; the artifact writer walks this).
    pub fn linears(&self) -> Vec<(String, &Linear)> {
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter().enumerate() {
            out.push((format!("l{i}.q"), &layer.attn.q));
            out.push((format!("l{i}.k"), &layer.attn.k));
            out.push((format!("l{i}.v"), &layer.attn.v));
            out.push((format!("l{i}.o"), &layer.attn.o));
            out.push((format!("l{i}.gate"), &layer.mlp.gate));
            out.push((format!("l{i}.up"), &layer.mlp.up));
            out.push((format!("l{i}.down"), &layer.mlp.down));
        }
        out
    }

    /// Iterate all quantizable linear layers with canonical names.
    pub fn linears_mut(&mut self) -> Vec<(String, &mut Linear)> {
        let mut out = Vec::new();
        for (i, layer) in self.layers.iter_mut().enumerate() {
            out.push((format!("l{i}.q"), &mut layer.attn.q));
            out.push((format!("l{i}.k"), &mut layer.attn.k));
            out.push((format!("l{i}.v"), &mut layer.attn.v));
            out.push((format!("l{i}.o"), &mut layer.attn.o));
            out.push((format!("l{i}.gate"), &mut layer.mlp.gate));
            out.push((format!("l{i}.up"), &mut layer.mlp.up));
            out.push((format!("l{i}.down"), &mut layer.mlp.down));
        }
        out
    }

    /// Total inference bytes of the decoder linears (+embeddings/head, fp32).
    pub fn size_bytes(&self) -> usize {
        let mut total = self.tok_emb.data.len() * 4 + self.head.size_bytes();
        for l in &self.layers {
            total += l.attn.q.size_bytes()
                + l.attn.k.size_bytes()
                + l.attn.v.size_bytes()
                + l.attn.o.size_bytes()
                + l.mlp.gate.size_bytes()
                + l.mlp.up.size_bytes()
                + l.mlp.down.size_bytes();
        }
        total
    }

    /// Build dense caches on all quantized layers (batch-path prerequisite).
    pub fn ensure_caches(&mut self) {
        for (_, lin) in self.linears_mut() {
            lin.ensure_cache();
        }
    }

    /// Full-sequence forward returning logits (T × vocab). Causal attention.
    pub fn forward_batch(&self, tokens: &[u16]) -> Matrix {
        let t_len = tokens.len();
        let cfg = &self.cfg;
        assert!(t_len <= cfg.max_seq, "sequence longer than max_seq");
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let dh = cfg.head_dim();

        // Embedding lookup.
        let mut x = Matrix::zeros(t_len, d);
        for (t, &tok) in tokens.iter().enumerate() {
            x.row_mut(t).copy_from_slice(self.tok_emb.row(tok as usize));
        }

        for layer in &self.layers {
            // --- Attention block ---
            let mut xn = x.clone();
            for r in 0..t_len {
                rmsnorm_row(xn.row_mut(r), &layer.attn_norm, cfg.rms_eps);
            }
            let mut q = layer.attn.q.forward_batch(&xn);
            let mut k = layer.attn.k.forward_batch(&xn);
            let v = layer.attn.v.forward_batch(&xn);
            // RoPE per position per head.
            for t in 0..t_len {
                for head in 0..h {
                    rope_rotate(&mut q.row_mut(t)[head * dh..(head + 1) * dh], t, cfg.rope_theta);
                    rope_rotate(&mut k.row_mut(t)[head * dh..(head + 1) * dh], t, cfg.rope_theta);
                }
            }
            // Scaled dot-product attention, causal.
            let scale = 1.0 / (dh as f32).sqrt();
            let mut attn_out = Matrix::zeros(t_len, d);
            let mut scores = vec![0.0f32; t_len];
            for head in 0..h {
                let hs = head * dh;
                for tq in 0..t_len {
                    let qrow = &q.row(tq)[hs..hs + dh];
                    for tk in 0..=tq {
                        let krow = &k.row(tk)[hs..hs + dh];
                        scores[tk] = crate::util::matrix::dot(qrow, krow) * scale;
                    }
                    softmax_inplace(&mut scores[..=tq]);
                    let out = &mut attn_out.row_mut(tq)[hs..hs + dh];
                    for tk in 0..=tq {
                        let w = scores[tk];
                        let vrow = &v.row(tk)[hs..hs + dh];
                        for i in 0..dh {
                            out[i] += w * vrow[i];
                        }
                    }
                }
            }
            let proj = layer.attn.o.forward_batch(&attn_out);
            x.axpy(1.0, &proj);

            // --- MLP block ---
            let mut xn = x.clone();
            for r in 0..t_len {
                rmsnorm_row(xn.row_mut(r), &layer.mlp_norm, cfg.rms_eps);
            }
            let gate = layer.mlp.gate.forward_batch(&xn);
            let up = layer.mlp.up.forward_batch(&xn);
            let mut act = gate;
            for (a, &u) in act.data.iter_mut().zip(&up.data) {
                *a = silu(*a) * u;
            }
            let down = layer.mlp.down.forward_batch(&act);
            x.axpy(1.0, &down);
        }

        for r in 0..t_len {
            rmsnorm_row(x.row_mut(r), &self.out_norm, self.cfg.rms_eps);
        }
        self.head.forward_batch(&x)
    }

    /// Single-token decode step with KV cache; returns the logits vector.
    pub fn decode_step(&self, cache: &mut KvCache, token: u16) -> Vec<f32> {
        let cfg = &self.cfg;
        let pos = cache.len;
        assert!(pos < cache.capacity, "KV cache full");
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let dh = cfg.head_dim();

        let mut x = self.tok_emb.row(token as usize).to_vec();
        for (li, layer) in self.layers.iter().enumerate() {
            let mut xn = x.clone();
            rmsnorm_row(&mut xn, &layer.attn_norm, cfg.rms_eps);
            let mut q = layer.attn.q.matvec(&xn);
            let mut k = layer.attn.k.matvec(&xn);
            let v = layer.attn.v.matvec(&xn);
            for head in 0..h {
                rope_rotate(&mut q[head * dh..(head + 1) * dh], pos, cfg.rope_theta);
                rope_rotate(&mut k[head * dh..(head + 1) * dh], pos, cfg.rope_theta);
            }
            cache.k[li].row_mut(pos).copy_from_slice(&k);
            cache.v[li].row_mut(pos).copy_from_slice(&v);

            let scale = 1.0 / (dh as f32).sqrt();
            let mut attn_out = vec![0.0f32; d];
            let mut scores = vec![0.0f32; pos + 1];
            for head in 0..h {
                let hs = head * dh;
                let qh = &q[hs..hs + dh];
                for tk in 0..=pos {
                    scores[tk] =
                        crate::util::matrix::dot(qh, &cache.k[li].row(tk)[hs..hs + dh]) * scale;
                }
                softmax_inplace(&mut scores);
                for tk in 0..=pos {
                    let w = scores[tk];
                    let vrow = &cache.v[li].row(tk)[hs..hs + dh];
                    for i in 0..dh {
                        attn_out[hs + i] += w * vrow[i];
                    }
                }
            }
            let proj = layer.attn.o.matvec(&attn_out);
            for (xv, &p) in x.iter_mut().zip(&proj) {
                *xv += p;
            }

            let mut xn = x.clone();
            rmsnorm_row(&mut xn, &layer.mlp_norm, cfg.rms_eps);
            let gate = layer.mlp.gate.matvec(&xn);
            let up = layer.mlp.up.matvec(&xn);
            let act: Vec<f32> = gate
                .iter()
                .zip(&up)
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = layer.mlp.down.matvec(&act);
            for (xv, &dn) in x.iter_mut().zip(&down) {
                *xv += dn;
            }
        }
        cache.len = pos + 1;
        rmsnorm_row(&mut x, &self.out_norm, cfg.rms_eps);
        self.head.matvec(&x)
    }

    /// One decode round for a whole serving batch: advance every sequence by one
    /// token, decoding each packed weight tile **once** for all B sequences.
    ///
    /// Sequences are independent — each attends over its own KV cache at its own
    /// position (heterogeneous lengths are fine); only the weight decode is
    /// shared. Per-sequence logits are bit-identical to calling [`decode_step`]
    /// on each (cache, token) pair separately: the fused linear kernels keep the
    /// per-row accumulation order, and everything else (norms, RoPE, attention,
    /// residuals) is computed per sequence.
    ///
    /// Returns one logits vector per sequence, in input order.
    pub fn decode_step_batch(
        &self,
        caches: &mut [&mut KvCache],
        tokens: &[u16],
    ) -> Vec<Vec<f32>> {
        let b = tokens.len();
        assert_eq!(caches.len(), b, "one cache per token");
        if b == 0 {
            return Vec::new();
        }
        let cfg = &self.cfg;
        let d = cfg.d_model;
        let h = cfg.n_heads;
        let dh = cfg.head_dim();
        let positions: Vec<usize> = caches.iter().map(|c| c.len).collect();
        for c in caches.iter() {
            assert!(c.len < c.capacity, "KV cache full");
        }

        let mut x = Matrix::zeros(b, d);
        for (bi, &tok) in tokens.iter().enumerate() {
            x.row_mut(bi).copy_from_slice(self.tok_emb.row(tok as usize));
        }

        for (li, layer) in self.layers.iter().enumerate() {
            // --- Attention block (shared weight decode, per-sequence state) ---
            let mut xn = x.clone();
            for r in 0..b {
                rmsnorm_row(xn.row_mut(r), &layer.attn_norm, cfg.rms_eps);
            }
            let mut q = layer.attn.q.matvec_multi(&xn);
            let mut k = layer.attn.k.matvec_multi(&xn);
            let v = layer.attn.v.matvec_multi(&xn);
            for bi in 0..b {
                let pos = positions[bi];
                for head in 0..h {
                    rope_rotate(&mut q.row_mut(bi)[head * dh..(head + 1) * dh], pos, cfg.rope_theta);
                    rope_rotate(&mut k.row_mut(bi)[head * dh..(head + 1) * dh], pos, cfg.rope_theta);
                }
                caches[bi].k[li].row_mut(pos).copy_from_slice(k.row(bi));
                caches[bi].v[li].row_mut(pos).copy_from_slice(v.row(bi));
            }

            let scale = 1.0 / (dh as f32).sqrt();
            let mut attn_out = Matrix::zeros(b, d);
            for bi in 0..b {
                let pos = positions[bi];
                let cache = &*caches[bi];
                let out = attn_out.row_mut(bi);
                let mut scores = vec![0.0f32; pos + 1];
                for head in 0..h {
                    let hs = head * dh;
                    let qh = &q.row(bi)[hs..hs + dh];
                    for tk in 0..=pos {
                        scores[tk] =
                            crate::util::matrix::dot(qh, &cache.k[li].row(tk)[hs..hs + dh])
                                * scale;
                    }
                    softmax_inplace(&mut scores);
                    for tk in 0..=pos {
                        let w = scores[tk];
                        let vrow = &cache.v[li].row(tk)[hs..hs + dh];
                        for i in 0..dh {
                            out[hs + i] += w * vrow[i];
                        }
                    }
                }
            }
            let proj = layer.attn.o.matvec_multi(&attn_out);
            x.axpy(1.0, &proj);

            // --- MLP block ---
            let mut xn = x.clone();
            for r in 0..b {
                rmsnorm_row(xn.row_mut(r), &layer.mlp_norm, cfg.rms_eps);
            }
            let gate = layer.mlp.gate.matvec_multi(&xn);
            let up = layer.mlp.up.matvec_multi(&xn);
            let mut act = gate;
            for (a, &u) in act.data.iter_mut().zip(&up.data) {
                *a = silu(*a) * u;
            }
            let down = layer.mlp.down.matvec_multi(&act);
            x.axpy(1.0, &down);
        }

        for (bi, cache) in caches.iter_mut().enumerate() {
            cache.len = positions[bi] + 1;
        }
        for r in 0..b {
            rmsnorm_row(x.row_mut(r), &self.out_norm, cfg.rms_eps);
        }
        let logits = self.head.matvec_multi(&x);
        (0..b).map(|r| logits.row(r).to_vec()).collect()
    }

    /// Sample a token from logits (temperature + top-k; greedy if temp == 0).
    ///
    /// NaN-tolerant by construction: comparisons use a total order with NaN
    /// ranked below every finite logit, so one poisoned logit degrades to "that
    /// token is never picked" instead of panicking the serving thread.
    pub fn sample(logits: &[f32], temp: f32, top_k: usize, rng: &mut Rng) -> u16 {
        let key = |v: f32| if v.is_nan() { f32::NEG_INFINITY } else { v };
        if temp <= 0.0 {
            let mut best = 0usize;
            let mut best_v = f32::NEG_INFINITY;
            for (i, &v) in logits.iter().enumerate() {
                if key(v) > best_v {
                    best = i;
                    best_v = key(v);
                }
            }
            return best as u16;
        }
        let k = top_k.max(1).min(logits.len());
        let mut idx: Vec<usize> = (0..logits.len()).collect();
        idx.sort_by(|&a, &b| key(logits[b]).total_cmp(&key(logits[a])));
        idx.truncate(k);
        // key() again: a NaN that survives into the top-k (top_k ≥ #finite
        // logits) must weight as exp(-inf) = 0, not poison the whole softmax.
        let mut probs: Vec<f32> = idx.iter().map(|&i| key(logits[i]) / temp).collect();
        softmax_inplace(&mut probs);
        let mut r = rng.uniform() as f32;
        for (j, &p) in probs.iter().enumerate() {
            r -= p;
            if r <= 0.0 {
                return idx[j] as u16;
            }
        }
        idx[k - 1] as u16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tiny_model(seed: u64) -> Transformer {
        let mut cfg = ModelConfig::nano();
        cfg.d_model = 32;
        cfg.n_heads = 2;
        cfg.d_ff = 64;
        cfg.n_layers = 2;
        cfg.max_seq = 32;
        cfg.name = "tiny".into();
        let ws = WeightStore::random(&cfg, seed);
        Transformer::from_store(&ws)
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_model(1);
        let logits = m.forward_batch(&[1, 2, 3, 4]);
        assert_eq!(logits.rows, 4);
        assert_eq!(logits.cols, 256);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_matches_batch_forward() {
        // Token-by-token decode must reproduce the full-sequence logits.
        let m = tiny_model(2);
        let tokens = [10u16, 200, 37, 99, 5];
        let batch = m.forward_batch(&tokens);
        let mut cache = KvCache::new(&m.cfg);
        for (t, &tok) in tokens.iter().enumerate() {
            let logits = m.decode_step(&mut cache, tok);
            for (a, b) in logits.iter().zip(batch.row(t)) {
                assert!((a - b).abs() < 1e-3, "pos {t}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn causality() {
        // Changing a future token must not affect earlier logits.
        let m = tiny_model(3);
        let a = m.forward_batch(&[1, 2, 3, 4]);
        let b = m.forward_batch(&[1, 2, 3, 250]);
        for t in 0..3 {
            for c in 0..256 {
                assert_eq!(a.at(t, c), b.at(t, c), "t={t}");
            }
        }
    }

    #[test]
    fn rope_is_position_sensitive() {
        // Permuting the prefix must change the last position's logits: a
        // position-free (bag-of-prefix) attention would produce identical rows.
        let m = tiny_model(4);
        let a = m.forward_batch(&[9, 7, 7]);
        let b = m.forward_batch(&[7, 9, 7]);
        let ra: Vec<f32> = a.row(2).to_vec();
        let rb: Vec<f32> = b.row(2).to_vec();
        assert!(ra.iter().zip(&rb).any(|(x, y)| (x - y).abs() > 1e-4));
    }

    #[test]
    fn sample_greedy_picks_argmax() {
        let mut logits = vec![0.0f32; 256];
        logits[42] = 10.0;
        let mut rng = Rng::new(1);
        assert_eq!(Transformer::sample(&logits, 0.0, 1, &mut rng), 42);
    }

    #[test]
    fn sample_topk_restricts_support() {
        let mut logits = vec![-100.0f32; 256];
        logits[10] = 5.0;
        logits[11] = 4.9;
        logits[12] = 4.8;
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let t = Transformer::sample(&logits, 1.0, 3, &mut rng);
            assert!([10, 11, 12].contains(&t));
        }
    }

    #[test]
    fn kv_cache_accounting() {
        let m = tiny_model(5);
        let cache = KvCache::new(&m.cfg);
        assert_eq!(cache.size_bytes(), 2 * 2 * 32 * 32 * 4);
        // The allocation-free size must agree with the allocated one.
        assert_eq!(KvCache::size_bytes_for(&m.cfg), cache.size_bytes());
    }

    #[test]
    fn decode_step_batch_matches_decode_step() {
        // Heterogeneous cache lengths: three sequences with different prefixes
        // must produce logits *bit-identical* to per-sequence decode_step.
        let m = tiny_model(6);
        let streams: [&[u16]; 3] = [&[10, 200, 37, 99, 5], &[7, 7, 42], &[250]];

        // Reference: per-sequence decode.
        let mut ref_logits: Vec<Vec<Vec<f32>>> = Vec::new();
        for s in &streams {
            let mut cache = KvCache::new(&m.cfg);
            ref_logits.push(s.iter().map(|&t| m.decode_step(&mut cache, t)).collect());
        }

        // Fused: one decode_step_batch round per position, dropping sequences
        // as they run out of tokens (so batch composition changes mid-flight).
        let mut caches: Vec<KvCache> = (0..3).map(|_| KvCache::new(&m.cfg)).collect();
        let max_len = streams.iter().map(|s| s.len()).max().unwrap();
        for pos in 0..max_len {
            let mut tokens = Vec::new();
            let mut idxs = Vec::new();
            for (i, s) in streams.iter().enumerate() {
                if pos < s.len() {
                    tokens.push(s[pos]);
                    idxs.push(i);
                }
            }
            let mut refs: Vec<&mut KvCache> = Vec::new();
            for (i, c) in caches.iter_mut().enumerate() {
                if idxs.contains(&i) {
                    refs.push(c);
                }
            }
            let logits = m.decode_step_batch(&mut refs, &tokens);
            for (j, &i) in idxs.iter().enumerate() {
                assert_eq!(
                    logits[j], ref_logits[i][pos],
                    "seq {i} pos {pos}: fused logits diverged from decode_step"
                );
            }
        }
        for (c, s) in caches.iter().zip(&streams) {
            assert_eq!(c.len, s.len());
        }
    }

    #[test]
    fn decode_step_batch_empty_is_noop() {
        let m = tiny_model(7);
        let mut caches: Vec<&mut KvCache> = Vec::new();
        assert!(m.decode_step_batch(&mut caches, &[]).is_empty());
    }

    #[test]
    fn sample_survives_nan_logits() {
        // Regression: a NaN logit used to panic via partial_cmp().unwrap(),
        // killing the serving thread. NaN now ranks below every finite logit.
        let mut logits = vec![0.0f32; 256];
        logits[3] = f32::NAN;
        logits[42] = 10.0;
        let mut rng = Rng::new(1);
        assert_eq!(Transformer::sample(&logits, 0.0, 1, &mut rng), 42);
        for _ in 0..50 {
            let t = Transformer::sample(&logits, 0.9, 4, &mut rng);
            assert!((t as usize) < 256);
            assert_ne!(t, 3, "NaN logit must never be sampled");
        }
        // NaN inside the top-k window must weight as zero, not win by default.
        let pair = vec![1.0f32, f32::NAN];
        for _ in 0..20 {
            assert_eq!(Transformer::sample(&pair, 1.0, 2, &mut rng), 0);
        }
        // All-NaN logits: still no panic.
        let all_nan = vec![f32::NAN; 8];
        let t = Transformer::sample(&all_nan, 1.0, 4, &mut rng);
        assert!((t as usize) < 8);
        let _ = Transformer::sample(&all_nan, 0.0, 1, &mut rng);
    }

    #[test]
    fn rmsnorm_unit_gain_preserves_rms() {
        let mut x = vec![3.0f32, -4.0, 0.0, 1.0];
        let gain = vec![1.0f32; 4];
        rmsnorm_row(&mut x, &gain, 1e-6);
        let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-3);
    }
}
