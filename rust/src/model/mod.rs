//! The LLM substrate QTIP quantizes and serves: config presets, byte tokenizer +
//! offline corpus, weight I/O (shared format with `python/compile/train.py`), and a
//! Llama-style decoder with dense/quantized linear layers.

pub mod config;
pub mod kv;
pub mod tokenizer;
pub mod transformer;
pub mod weights;

pub use config::ModelConfig;
pub use kv::{
    chain_hash, resolve_kv_block, resolve_prefill_chunk, resolve_round_budget, KvArena, KvCache,
    KvLayout, KvSeq, PrefixIndex, DEFAULT_KV_BLOCK, DEFAULT_PREFILL_CHUNK, PREFIX_HASH_SEED,
};
pub use tokenizer::{calibration_split, eval_split, load_corpus, split_corpus, ByteTokenizer};
pub use transformer::{DecodeScratch, Linear, Transformer};
pub use weights::WeightStore;
