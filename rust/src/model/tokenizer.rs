//! Byte-level tokenizer and offline corpus loading.
//!
//! The training/eval corpus is the repository's own source text (plus any
//! directories the user points at) — real data that is always available offline.
//! DESIGN.md §4 documents this as the substitute for Wikitext2/C4/RedPajama.

use std::path::Path;

/// Trivial byte-level tokenizer: token id == byte value (vocab 256).
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn vocab(&self) -> usize {
        256
    }

    pub fn encode(&self, text: &str) -> Vec<u16> {
        text.as_bytes().iter().map(|&b| b as u16).collect()
    }

    pub fn decode(&self, tokens: &[u16]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Recursively gather text from source files under `roots`, filtered by extension.
pub fn load_corpus(roots: &[&Path], max_bytes: usize) -> Vec<u8> {
    let mut out = Vec::new();
    let exts = ["rs", "py", "md", "toml", "txt"];
    let mut stack: Vec<std::path::PathBuf> = roots.iter().map(|p| p.to_path_buf()).collect();
    // Deterministic traversal order.
    while let Some(dir) = stack.pop() {
        let mut entries: Vec<_> = match std::fs::read_dir(&dir) {
            Ok(rd) => rd.flatten().map(|e| e.path()).collect(),
            Err(_) => continue,
        };
        entries.sort();
        for p in entries {
            if out.len() >= max_bytes {
                return out;
            }
            if p.is_dir() {
                let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
                if name != "target" && name != ".git" && name != "artifacts" {
                    stack.push(p);
                }
            } else if p
                .extension()
                .and_then(|e| e.to_str())
                .map(|e| exts.contains(&e))
                .unwrap_or(false)
            {
                if let Ok(bytes) = std::fs::read(&p) {
                    out.extend_from_slice(&bytes);
                    out.push(b'\n');
                }
            }
        }
    }
    out.truncate(max_bytes);
    out
}

/// Deterministic train/held-out split: the final `holdout_frac` of the corpus is
/// reserved for evaluation (the same convention `python/compile/train.py` uses).
pub fn split_corpus(corpus: &[u8], holdout_frac: f64) -> (&[u8], &[u8]) {
    let cut = ((corpus.len() as f64) * (1.0 - holdout_frac)) as usize;
    corpus.split_at(cut)
}

/// The calibration half of a corpus file: the **first** half. Hessian
/// calibration must draw only from here so the perplexity numbers in
/// `qtip eval` are measured on bytes the quantizer never saw.
pub fn calibration_split(corpus: &[u8]) -> &[u8] {
    split_corpus(corpus, 0.5).0
}

/// The evaluation half: the **second** half, byte-disjoint from
/// [`calibration_split`] by construction.
pub fn eval_split(corpus: &[u8]) -> &[u8] {
    split_corpus(corpus, 0.5).1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let t = ByteTokenizer;
        let s = "hello QTIP! 123\n";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn encode_is_bytes() {
        let t = ByteTokenizer;
        assert_eq!(t.encode("AB"), vec![65, 66]);
    }

    #[test]
    fn corpus_loads_this_repo() {
        let corpus = load_corpus(&[Path::new(env!("CARGO_MANIFEST_DIR"))], 1 << 16);
        assert!(corpus.len() > 10_000, "repo source should provide text");
        // Should contain recognizable Rust source.
        let text = String::from_utf8_lossy(&corpus);
        assert!(text.contains("fn "));
    }

    #[test]
    fn split_is_disjoint_cover() {
        let data: Vec<u8> = (0..=255).collect();
        let (train, hold) = split_corpus(&data, 0.25);
        assert_eq!(train.len(), 192);
        assert_eq!(hold.len(), 64);
        assert_eq!([train, hold].concat(), data);
    }

    #[test]
    fn calibration_and_eval_byte_ranges_never_overlap() {
        // Regression: `qtip eval` used to measure perplexity over the full
        // holdout file while calibration drew from its first half — a direct
        // train/eval leak. The named splits must partition the corpus with no
        // shared bytes.
        let data: Vec<u8> = (0u16..1001).map(|i| (i % 251) as u8).collect();
        let calib = calibration_split(&data);
        let eval = eval_split(&data);
        assert!(!calib.is_empty() && !eval.is_empty());
        assert_eq!(calib.len() + eval.len(), data.len(), "splits must cover the corpus");
        assert_eq!(calib, &data[..calib.len()]);
        assert_eq!(eval, &data[calib.len()..]);
        // Address-level disjointness: the calibration range ends at or before
        // the eval range begins.
        let calib_end = calib.as_ptr() as usize + calib.len();
        assert!(calib_end <= eval.as_ptr() as usize, "byte ranges overlap");
    }
}
