//! Transformer configuration. Dimensions are chosen to be Hadamard-transformable
//! (powers of two, or 12/20·2^a) and divisible by the 16×16 QTIP tile, so every
//! linear layer is quantizable without padding.

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Byte-level vocab (256) — keeps the tokenizer trivial and offline.
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f32,
    pub rms_eps: f32,
    /// Human-readable preset name.
    pub name: String,
}

impl ModelConfig {
    /// ~0.8M parameters: trained to convergence at build time (`make artifacts`).
    pub fn nano() -> Self {
        ModelConfig {
            vocab: 256,
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 512,
            max_seq: 256,
            rope_theta: 10_000.0,
            rms_eps: 1e-5,
            name: "nano".into(),
        }
    }

    /// ~6.3M parameters: the primary evaluation model (briefly trained).
    pub fn small() -> Self {
        ModelConfig {
            vocab: 256,
            d_model: 256,
            n_layers: 6,
            n_heads: 8,
            d_ff: 1024,
            max_seq: 256,
            rope_theta: 10_000.0,
            rms_eps: 1e-5,
            name: "small".into(),
        }
    }

    /// ~33M parameters: random weights, throughput experiments only (Table 4).
    pub fn medium() -> Self {
        ModelConfig {
            vocab: 256,
            d_model: 512,
            n_layers: 8,
            n_heads: 8,
            d_ff: 2048,
            max_seq: 256,
            rope_theta: 10_000.0,
            rms_eps: 1e-5,
            name: "medium".into(),
        }
    }

    pub fn by_name(name: &str) -> Self {
        match name {
            "nano" => Self::nano(),
            "small" => Self::small(),
            "medium" => Self::medium(),
            other => panic!("unknown model preset '{other}'"),
        }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parameters in the decoder weights (the quantizable part).
    pub fn decoder_params(&self) -> usize {
        // attn: q,k,v,o (d×d each); mlp: gate,up (d_ff×d), down (d×d_ff).
        self.n_layers * (4 * self.d_model * self.d_model + 3 * self.d_model * self.d_ff)
    }

    /// Total parameters including embedding + head + norms.
    pub fn total_params(&self) -> usize {
        self.decoder_params()
            + 2 * self.vocab * self.d_model
            + (2 * self.n_layers + 1) * self.d_model
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("vocab", Json::Num(self.vocab as f64)),
            ("d_model", Json::Num(self.d_model as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("d_ff", Json::Num(self.d_ff as f64)),
            ("max_seq", Json::Num(self.max_seq as f64)),
            ("rope_theta", Json::Num(self.rope_theta as f64)),
            ("rms_eps", Json::Num(self.rms_eps as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Self {
        ModelConfig {
            vocab: j.req_usize("vocab"),
            d_model: j.req_usize("d_model"),
            n_layers: j.req_usize("n_layers"),
            n_heads: j.req_usize("n_heads"),
            d_ff: j.req_usize("d_ff"),
            max_seq: j.req_usize("max_seq"),
            rope_theta: j.get("rope_theta").and_then(|v| v.as_f64()).unwrap_or(10_000.0)
                as f32,
            rms_eps: j.get("rms_eps").and_then(|v| v.as_f64()).unwrap_or(1e-5) as f32,
            name: j.req_str("name").to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_quantizable() {
        for cfg in [ModelConfig::nano(), ModelConfig::small(), ModelConfig::medium()] {
            assert_eq!(cfg.d_model % 16, 0);
            assert_eq!(cfg.d_ff % 16, 0);
            assert_eq!(cfg.d_model % cfg.n_heads, 0);
            assert!(crate::util::hadamard::supported(cfg.d_model));
            assert!(crate::util::hadamard::supported(cfg.d_ff));
        }
    }

    #[test]
    fn param_counts() {
        let nano = ModelConfig::nano();
        assert!((500_000..700_000).contains(&nano.total_params()), "{}", nano.total_params());
        let small = ModelConfig::small();
        assert!((6_000_000..7_000_000).contains(&small.total_params()));
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ModelConfig::small();
        let j = cfg.to_json();
        let back = ModelConfig::from_json(&Json::parse(&j.to_string()).unwrap());
        assert_eq!(cfg, back);
    }
}
