//! Model weight I/O: a raw little-endian f32 blob plus a JSON manifest
//! (`model_<name>.json` / `model_<name>.bin`), written by `python/compile/train.py`
//! and read here. Rust can also write the format (used by tests and the
//! quantization pipeline's dense export).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::model::config::ModelConfig;
use crate::util::json::Json;
use crate::util::matrix::Matrix;
use anyhow::{bail, Context, Result};

/// Encode f32s as the little-endian byte blob shared by the model-weight and
/// quantized-artifact (`crate::io`) formats.
pub fn f32s_to_le_bytes(vals: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 4);
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_le_bytes`]; errors if the byte count isn't 4-aligned.
pub fn le_bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("f32 blob not a multiple of 4 bytes ({} bytes)", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// A named collection of tensors with its model config.
#[derive(Clone, Debug)]
pub struct WeightStore {
    pub config: ModelConfig,
    pub tensors: BTreeMap<String, Matrix>,
    /// Training metadata (loss curve etc.) passed through from the manifest.
    pub meta: Json,
}

impl WeightStore {
    pub fn get(&self, name: &str) -> &Matrix {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing tensor '{name}'"))
    }

    /// Canonical tensor names for a config (must match python/compile/train.py).
    pub fn expected_names(cfg: &ModelConfig) -> Vec<String> {
        let mut names = vec!["tok_emb".to_string()];
        for i in 0..cfg.n_layers {
            for t in ["attn_norm", "q", "k", "v", "o", "mlp_norm", "gate", "up", "down"] {
                names.push(format!("l{i}.{t}"));
            }
        }
        names.push("out_norm".into());
        names.push("head".into());
        names
    }

    pub fn expected_shape(cfg: &ModelConfig, name: &str) -> (usize, usize) {
        let d = cfg.d_model;
        let f = cfg.d_ff;
        if name == "tok_emb" || name == "head" {
            return (cfg.vocab, d);
        }
        if name == "out_norm" {
            return (1, d);
        }
        let part = name.split('.').nth(1).expect("layer tensor name");
        match part {
            "attn_norm" | "mlp_norm" => (1, d),
            "q" | "k" | "v" | "o" => (d, d),
            "gate" | "up" => (f, d),
            "down" => (d, f),
            other => panic!("unknown tensor part '{other}'"),
        }
    }

    /// Load `<dir>/model_<name>.json` + `.bin`.
    pub fn load(dir: &Path, name: &str) -> Result<WeightStore> {
        let manifest_path = dir.join(format!("model_{name}.json"));
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let config = ModelConfig::from_json(j.get("config").context("manifest.config")?);
        let bin_path = dir.join(j.req_str("weights_file"));
        let mut bytes = Vec::new();
        std::fs::File::open(&bin_path)
            .with_context(|| format!("opening {bin_path:?}"))?
            .read_to_end(&mut bytes)?;
        let floats =
            le_bytes_to_f32s(&bytes).with_context(|| format!("weight blob {bin_path:?}"))?;

        let mut tensors = BTreeMap::new();
        for t in j.get("tensors").context("manifest.tensors")?.as_arr().unwrap() {
            let tname = t.req_str("name").to_string();
            let shape = t.get("shape").unwrap().as_arr().unwrap();
            let (rows, cols) = match shape.len() {
                1 => (1, shape[0].as_usize().unwrap()),
                2 => (shape[0].as_usize().unwrap(), shape[1].as_usize().unwrap()),
                _ => bail!("tensor '{tname}' has unsupported rank"),
            };
            let offset = t.req_usize("offset"); // in floats
            let n = rows * cols;
            if offset + n > floats.len() {
                bail!("tensor '{tname}' out of range");
            }
            tensors.insert(
                tname,
                Matrix::from_vec(rows, cols, floats[offset..offset + n].to_vec()),
            );
        }
        // Validate completeness and shapes.
        for name in Self::expected_names(&config) {
            let t = tensors
                .get(&name)
                .with_context(|| format!("manifest missing tensor '{name}'"))?;
            let (r, c) = Self::expected_shape(&config, &name);
            if (t.rows, t.cols) != (r, c) {
                bail!("tensor '{name}' shape {:?} != expected {:?}", (t.rows, t.cols), (r, c));
            }
        }
        let meta = j.get("meta").cloned().unwrap_or(Json::Null);
        Ok(WeightStore { config, tensors, meta })
    }

    /// Write the manifest + blob (same format train.py emits).
    pub fn save(&self, dir: &Path, name: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut offset = 0usize;
        let mut tensor_entries = Vec::new();
        let mut blob: Vec<u8> = Vec::new();
        for tname in Self::expected_names(&self.config) {
            let t = self.get(&tname);
            let shape = if t.rows == 1 && !tname.contains('.') && tname.ends_with("norm")
                || tname.ends_with("norm")
            {
                Json::Arr(vec![Json::Num(t.cols as f64)])
            } else {
                Json::Arr(vec![Json::Num(t.rows as f64), Json::Num(t.cols as f64)])
            };
            tensor_entries.push(Json::obj(vec![
                ("name", Json::Str(tname.clone())),
                ("shape", shape),
                ("offset", Json::Num(offset as f64)),
            ]));
            blob.extend_from_slice(&f32s_to_le_bytes(&t.data));
            offset += t.data.len();
        }
        let manifest = Json::obj(vec![
            ("config", self.config.to_json()),
            ("weights_file", Json::Str(format!("model_{name}.bin"))),
            ("tensors", Json::Arr(tensor_entries)),
            ("meta", self.meta.clone()),
        ]);
        std::fs::write(dir.join(format!("model_{name}.json")), manifest.to_string())?;
        let mut f = std::fs::File::create(dir.join(format!("model_{name}.bin")))?;
        f.write_all(&blob)?;
        Ok(())
    }

    /// Random-initialized weights (throughput benches, tests).
    pub fn random(cfg: &ModelConfig, seed: u64) -> WeightStore {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut tensors = BTreeMap::new();
        for name in Self::expected_names(cfg) {
            let (r, c) = Self::expected_shape(cfg, &name);
            let m = if name.ends_with("norm") {
                Matrix::from_vec(r, c, vec![1.0; r * c])
            } else {
                // Scaled init ~ N(0, 1/sqrt(fan_in)).
                let std = 1.0 / (c as f32).sqrt();
                Matrix::gaussian(r, c, std, &mut rng)
            };
            tensors.insert(name, m);
        }
        WeightStore { config: cfg.clone(), tensors, meta: Json::Null }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn expected_names_cover_model() {
        let cfg = ModelConfig::nano();
        let names = WeightStore::expected_names(&cfg);
        assert_eq!(names.len(), 1 + cfg.n_layers * 9 + 2);
        assert!(names.contains(&"l1.down".to_string()));
    }

    #[test]
    fn random_store_has_valid_shapes() {
        let cfg = ModelConfig::nano();
        let ws = WeightStore::random(&cfg, 1);
        for name in WeightStore::expected_names(&cfg) {
            let t = ws.get(&name);
            assert_eq!((t.rows, t.cols), WeightStore::expected_shape(&cfg, &name));
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::nano();
        let ws = WeightStore::random(&cfg, 2);
        let dir = std::env::temp_dir().join("qtip_test_weights");
        ws.save(&dir, "roundtrip").unwrap();
        let back = WeightStore::load(&dir, "roundtrip").unwrap();
        assert_eq!(back.config, cfg);
        for name in WeightStore::expected_names(&cfg) {
            assert_eq!(back.get(&name).data, ws.get(&name).data, "{name}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn le_blob_roundtrip_is_bit_exact() {
        let vals = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, 3.0e38, -7.25e-12];
        let back = le_bytes_to_f32s(&f32s_to_le_bytes(&vals)).unwrap();
        for (a, b) in vals.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(le_bytes_to_f32s(&[1, 2, 3]).is_err(), "misaligned blob must error");
    }

    #[test]
    fn load_missing_fails() {
        let err = WeightStore::load(Path::new("/nonexistent"), "nope");
        assert!(err.is_err());
    }
}
