//! Artifact registry: parses `artifacts/aot_manifest.json` and hands out
//! compiled executables by name.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::pjrt::{PjrtRuntime, QuantizedMatvecExe};
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    pub rows: usize,
    pub cols: usize,
    pub l: u32,
    pub k: u32,
    pub v: u32,
    pub code: String,
    pub padded_len: usize,
}

pub struct Registry {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Registry {
    /// Parse `<dir>/aot_manifest.json`.
    pub fn open(dir: &Path) -> Result<Registry> {
        let manifest_path = dir.join("aot_manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").context("manifest.artifacts")?.as_arr().unwrap() {
            artifacts.push(ArtifactInfo {
                name: a.req_str("name").to_string(),
                path: dir.join(a.req_str("path")),
                kind: a.req_str("kind").to_string(),
                rows: a.get("rows").and_then(|v| v.as_usize()).unwrap_or(0),
                cols: a.get("cols").and_then(|v| v.as_usize()).unwrap_or(0),
                l: a.get("l").and_then(|v| v.as_usize()).unwrap_or(0) as u32,
                k: a.get("k").and_then(|v| v.as_usize()).unwrap_or(0) as u32,
                v: a.get("v").and_then(|v| v.as_usize()).unwrap_or(1) as u32,
                code: a
                    .get("code")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                padded_len: a.get("padded_len").and_then(|v| v.as_usize()).unwrap_or(0),
            });
        }
        Ok(Registry { dir: dir.to_path_buf(), artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find a decode-matvec artifact matching a shape/code/k, if one was lowered.
    pub fn find_decode_matvec(
        &self,
        rows: usize,
        cols: usize,
        code: &str,
        k: u32,
    ) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| {
            a.kind == "decode_matvec"
                && a.rows == rows
                && a.cols == cols
                && a.code == code
                && a.k == k
        })
    }

    /// Compile a decode-matvec artifact into an executable wrapper.
    pub fn load_decode_matvec(
        &self,
        rt: &PjrtRuntime,
        info: &ArtifactInfo,
    ) -> Result<QuantizedMatvecExe> {
        let exe = rt.load_hlo(&info.path)?;
        Ok(QuantizedMatvecExe {
            exe,
            rows: info.rows,
            cols: info.cols,
            tiles_r: info.rows / 16,
            row_words: (info.cols / 16) * info.padded_len,
            code: info.code.clone(),
            k: info.k,
            l: info.l,
        })
    }

    /// Load the shared HYB LUT contract (`hyb_lut_q{q}.json`).
    pub fn load_hyb_lut(&self, q: u32) -> Result<Vec<f32>> {
        let text = std::fs::read_to_string(self.dir.join(format!("hyb_lut_q{q}.json")))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("lut: {e}"))?;
        Ok(j.get("lut")
            .context("lut field")?
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn registry_parses_manifest() {
        let dir = artifacts_dir();
        if !dir.join("aot_manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let reg = Registry::open(&dir).unwrap();
        assert!(!reg.artifacts.is_empty());
        let a = reg
            .find_decode_matvec(128, 128, "3inst", 2)
            .expect("3inst 128x128 k2 artifact");
        assert_eq!(a.l, 16);
        assert!(a.padded_len > 0);
    }

    #[test]
    fn hyb_lut_loads() {
        let dir = artifacts_dir();
        if !dir.join("hyb_lut_q9.json").exists() {
            return;
        }
        let reg = Registry::open(&dir).unwrap();
        let lut = reg.load_hyb_lut(9).unwrap();
        assert_eq!(lut.len(), 512 * 2);
    }
}
