//! The XLA/PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from Rust. Python is never on this
//! path — the artifacts are compiled once per process and cached in a registry.

pub mod pjrt;
pub mod registry;

pub use pjrt::{PjrtRuntime, QuantizedMatvecExe};
pub use registry::{ArtifactInfo, Registry};
