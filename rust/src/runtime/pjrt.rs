//! PJRT client wrapper (the `xla` crate): HLO text → compile → execute.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serializes HloModuleProto with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; `HloModuleProto::from_text_file`
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §7).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::quant::QuantizedMatrix;

/// A process-wide PJRT CPU client with a compiled-executable cache.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text artifact (cached per path).
    pub fn load_hlo(&self, path: &Path) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(exe.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {path:?}"))?,
        );
        self.cache
            .lock()
            .unwrap()
            .insert(path.to_path_buf(), exe.clone());
        Ok(exe)
    }

    /// Execute with f32/u32 literal inputs; returns the first tuple element as f32s.
    pub fn run_to_f32(
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<f32>> {
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// A compiled fused decode-matvec artifact bound to its geometry, executable on
/// any `QuantizedMatrix` with matching shape/code.
pub struct QuantizedMatvecExe {
    pub exe: std::sync::Arc<xla::PjRtLoadedExecutable>,
    pub rows: usize,
    pub cols: usize,
    pub tiles_r: usize,
    pub row_words: usize,
    pub code: String,
    pub k: u32,
    pub l: u32,
}

impl QuantizedMatvecExe {
    /// Execute ỹ = Ŵ̃ x̃ through PJRT (incoherent space, like `matvec_tilde`).
    pub fn matvec_tilde(&self, qm: &QuantizedMatrix, xt: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(qm.rows == self.rows && qm.cols == self.cols, "shape mismatch");
        anyhow::ensure!(qm.code.name() == self.code, "code mismatch");
        anyhow::ensure!(qm.trellis.k == self.k && qm.trellis.l == self.l, "trellis mismatch");
        anyhow::ensure!(
            qm.tile_words * qm.tiles_c() == self.row_words,
            "packed layout mismatch: {} vs {}",
            qm.tile_words * qm.tiles_c(),
            self.row_words
        );
        let packed = xla::Literal::vec1(&qm.packed)
            .reshape(&[self.tiles_r as i64, self.row_words as i64])?;
        let x = xla::Literal::vec1(xt);
        let scale = xla::Literal::from(qm.scale);
        PjrtRuntime::run_to_f32(&self.exe, &[packed, x, scale])
    }

    /// Full path including the RHT sandwich (parity with `QuantizedMatrix::matvec`).
    pub fn matvec(&self, qm: &QuantizedMatrix, x: &[f32]) -> Result<Vec<f32>> {
        let mut xt = x.to_vec();
        qm.rht.forward_activations(&mut xt);
        let mut y = self.matvec_tilde(qm, &xt)?;
        qm.rht.restore_outputs(&mut y);
        Ok(y)
    }
}
